"""Pure-jnp oracle for the fused profile-cube kernel.

The *profile cube* is the paper's "synthetic understanding of file systems
contents" as one dense tensor: count / volume / spc_used histograms
bucketed by profile group (a dense code for one (owner, group, type,
hsm_state) combination) × size-profile bucket × age bucket. One columnar
pass bucketizes every row and segment-reduces the three measures — the
on-device replacement for N scalar dict folds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

N_MEASURES = 3       # count, volume (bytes), spc_used (allocated bytes)
S_BUCKETS = 10       # size-profile buckets (core.types.SIZE_PROFILE_EDGES)
A_BUCKETS = 7        # age-profile buckets (core.types.AGE_PROFILE_EDGES)

# bucket edges — static mirrors of core.types.SIZE_PROFILE_EDGES /
# AGE_PROFILE_EDGES (kernels capture python floats, not arrays)
SIZE_EDGE_VALS = (0.0, 1.0, 32.0, float(1 << 10), float(32 << 10),
                  float(1 << 20), float(32 << 20), float(1 << 30),
                  float(32 << 30), float(1 << 40))
AGE_EDGE_VALS = (0.0, 3600.0, 86400.0, 7 * 86400.0, 30 * 86400.0,
                 90 * 86400.0, 365 * 86400.0)


def size_buckets(size: jax.Array) -> jax.Array:
    """(N,) f32 sizes -> (N,) i32 size-profile bucket indices."""
    b = sum((size >= e).astype(jnp.int32) for e in SIZE_EDGE_VALS) - 1
    return jnp.clip(b, 0, S_BUCKETS - 1)


def age_buckets(age: jax.Array) -> jax.Array:
    """(N,) f32 ages (seconds) -> (N,) i32 age-profile bucket indices."""
    b = sum((age >= e).astype(jnp.int32) for e in AGE_EDGE_VALS) - 1
    return jnp.clip(b, 0, A_BUCKETS - 1)


def profile_cube_ref(cols: jax.Array, n_groups: int, gid_col: int = 0,
                     size_col: int = 1, blocks_col: int = 2,
                     age_col: int = 3, valid_col: int = -1,
                     sb_col: int = -1, ab_col: int = -1) -> jax.Array:
    """Oracle: (N_MEASURES, n_groups, S_BUCKETS, A_BUCKETS) f32 cube.

    cols: (n_cols, N) f32 with rows [gid, size, blocks, age(, valid)].
    Invalid rows contribute nothing (their gid may be garbage — the 0
    weight masks them out of the scatter). ``sb_col``/``ab_col`` point at
    precomputed bucket-index columns (exact host bucketization); -1
    bucketizes from the raw size/age columns.
    """
    gid = cols[gid_col].astype(jnp.int32)
    size = cols[size_col]
    blocks = cols[blocks_col]
    age = cols[age_col]
    valid = cols[valid_col] if valid_col >= 0 \
        else jnp.ones_like(size)
    sb = cols[sb_col].astype(jnp.int32) if sb_col >= 0 else size_buckets(size)
    sb = jnp.clip(sb, 0, S_BUCKETS - 1)
    ab = cols[ab_col].astype(jnp.int32) if ab_col >= 0 else age_buckets(age)
    ab = jnp.clip(ab, 0, A_BUCKETS - 1)
    flat = (jnp.clip(gid, 0, n_groups - 1) * S_BUCKETS + sb) * A_BUCKETS + ab
    k = n_groups * S_BUCKETS * A_BUCKETS
    count = jnp.zeros((k,), jnp.float32).at[flat].add(valid)
    volume = jnp.zeros((k,), jnp.float32).at[flat].add(valid * size)
    spc = jnp.zeros((k,), jnp.float32).at[flat].add(valid * blocks)
    return jnp.stack([count, volume, spc]).reshape(
        N_MEASURES, n_groups, S_BUCKETS, A_BUCKETS)
