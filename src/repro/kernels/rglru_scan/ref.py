"""Pure-jnp oracle for the RG-LRU recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = exp(log_a_t) * h_{t-1} + b_t, sequentially.

    log_a, b: (B, S, R) f32; h0: (B, R). Returns h: (B, S, R).
    """
    def step(h, inp):
        la, bt = inp
        h = jnp.exp(la) * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (log_a.transpose(1, 0, 2),
                                    b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
