"""The paper's `rbh-report` tables from the on-device profile cube.

Simulates a catalog (users, groups, sizes, ages, HSM states), builds the
incremental :class:`ProfileCube`, and prints the ownership / type / HSM /
size-profile / age-profile tables — every table a masked reduction over
one (measure, group, size_bucket, age_bucket) tensor, never a catalog
scan. Then mutates the catalog and re-queries: the cube absorbs the
deltas as signed bucket updates instead of recomputing.

    PYTHONPATH=src python examples/fs_profiles.py
"""
import time

import numpy as np

from repro.core import (Catalog, Entry, FsType, HsmState, ProfileCube,
                        Reports, format_size)


def build_catalog(n: int = 50_000) -> Catalog:
    rng = np.random.default_rng(42)
    now = time.time()
    cat = Catalog(n_shards=4)
    users = ["alice", "bob", "carol", "dave"]
    groups = ["physics", "bio", "ops"]
    for lo in range(0, n, 10_000):
        entries = []
        for i in range(lo, min(lo + 10_000, n)):
            kind = FsType.FILE if i % 10 else FsType.DIR
            entries.append(Entry(
                fid=i + 1, name=f"f{i}", path=f"/proj/d{i % 37}/f{i}",
                type=kind,
                size=int(rng.lognormal(9, 3)) if kind == FsType.FILE else 0,
                blocks=int(rng.lognormal(9, 3)),
                owner=users[i % len(users)], group=groups[i % len(groups)],
                hsm_state=HsmState(int(rng.choice(
                    [0, 0, 0, 1, 3, 4], p=[.4, .1, .1, .1, .2, .1]))),
                atime=now - float(rng.uniform(0, 500 * 86400))))
        cat.upsert_batch(entries)
    return cat


def show(title: str, lines) -> None:
    print(f"\n== {title} " + "=" * max(1, 60 - len(title)))
    for ln in lines:
        print(ln)


def main() -> None:
    cat = build_catalog()
    t0 = time.perf_counter()
    cube = ProfileCube(cat).attach()          # per-shard vectorized build
    print(f"profile cube over {len(cat)} entries built in "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
    rep = Reports(cat, profiles=cube)

    # rbh-report -u alice
    show("rbh-report -u alice", [rep.format_user_report("alice")])

    # per-type + per-HSM-state tables
    show("entry types", (f"  {t:8s} count={d['count']:<8d} "
                         f"volume={format_size(d['volume'])}"
                         for t, d in rep.report_types().items()))
    show("HSM states", (f"  {s:10s} count={d['count']:<8d} "
                        f"volume={format_size(d['volume'])}"
                        for s, d in rep.report_hsm().items()))

    # the paper's size + age profiles
    show("size profile (alice, files)",
         (f"  {lbl:>8s}: {n}" for lbl, n in
          rep.user_size_profile("alice").items() if n))
    show("age profile (all users)",
         (f"  {lbl:>8s}: count={d['count']:<8d} "
          f"volume={format_size(d['volume'])}"
          for lbl, d in rep.age_profile().items() if d["count"]))
    show("top users by volume",
         (f"  {d['user']:8s} {format_size(d['volume'])}"
          for d in rep.top_users(k=3)))

    # churn: the cube absorbs deltas as signed bucket updates — verify the
    # incrementally-maintained state against a from-scratch rebuild
    before = rep.report_user("bob")
    for fid in range(1, 2001):
        cat.update_fields(fid, size=0, blocks=0)
    for fid in range(2001, 3001):
        cat.remove(fid)
    after = rep.report_user("bob")
    fresh = ProfileCube(cat)
    fresh.rebuild()
    assert after == fresh.report_user("bob"), "incremental != recompute"
    show("after churn (2000 truncates + 1000 unlinks)", [
        f"  bob files before: {before[0]['count']}",
        f"  bob files after:  {after[0]['count']}",
        "  incremental cube == fresh rebuild: verified",
    ])


if __name__ == "__main__":
    main()
