"""Alerts (C5 §II-B2): detect 'abnormal or toxic' entries at ingest time.

Alert rules are policy criteria checked against every entry as it flows into
the catalog (entry hook) — no scan. Matching entries trigger a configurable
action: append to an alert log file, collect in memory, or call back.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

from .policy import Expr, parse_expr
from .types import Entry


class AlertRule:
    def __init__(self, name: str, criteria: str,
                 action: Optional[Callable[[str, Entry], None]] = None,
                 cooldown: float = 0.0) -> None:
        self.name = name
        self.expr: Expr = parse_expr(criteria)
        self.action = action
        self.cooldown = cooldown          # per-fid re-alert suppression
        self._last_fired = {}

    def check(self, e: Entry, now: float) -> bool:
        if not self.expr.evaluate(e, now):
            return False
        last = self._last_fired.get(e.fid, 0.0)
        if self.cooldown and now - last < self.cooldown:
            return False
        self._last_fired[e.fid] = now
        return True


class AlertManager:
    def __init__(self, log_path: Optional[str] = None,
                 clock: Callable[[], float] = time.time) -> None:
        self.rules: List[AlertRule] = []
        self.fired: List[dict] = []
        self.log_path = log_path
        self.clock = clock
        self._lock = threading.Lock()

    def add_rule(self, rule: AlertRule) -> None:
        self.rules.append(rule)

    def on_entry(self, e: Entry) -> None:
        """Wire as ``catalog.add_entry_hook(mgr.on_entry)``."""
        now = self.clock()
        for rule in self.rules:
            if rule.check(e, now):
                rec = {"alert": rule.name, "fid": e.fid, "path": e.path,
                       "owner": e.owner, "size": e.size, "time": now}
                with self._lock:
                    self.fired.append(rec)
                    if self.log_path:
                        with open(self.log_path, "a", encoding="utf-8") as f:
                            f.write(f"{now:.3f} ALERT {rule.name} "
                                    f"path={e.path} owner={e.owner} "
                                    f"size={e.size}\n")
                if rule.action is not None:
                    rule.action(rule.name, e)
