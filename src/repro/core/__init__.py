"""Robinhood Policy Engine core — the paper's contribution.

Collect (scanner/changelog/pipeline) -> store (catalog) -> exploit
(stats/reports/policies/alerts/HSM).
"""
from .types import (ChangelogRecord, ChangelogType, Entry, FsType, HsmState,
                    format_size, parse_duration, parse_size)
from .catalog import Catalog, CatalogShard, ColumnBatch, StringTable
from .changelog import ChangelogHub, ChangelogStream
from .scanner import Scanner, multi_client_scan, prune_missing
from .pipeline import EventPipeline, PipelineConfig
from .policy import (ALWAYS, And, Cmp, Const, Expr, Not, Or, PolicyError,
                     compile_program, parse_expr, KERNEL_COLUMNS)
from .policy_engine import (PolicyDefinition, PolicyEngine, Rule, RunReport,
                            UsageWatermarkTrigger)
from .stats import ChangelogCounters, DirUsage, StatsAggregator
from .reports import Reports
from .alerts import AlertManager, AlertRule
from .hsm import HsmCoordinator
from .plugins import PLUGIN_REGISTRY, register_plugin

__all__ = [
    "ChangelogRecord", "ChangelogType", "Entry", "FsType", "HsmState",
    "format_size", "parse_duration", "parse_size",
    "Catalog", "CatalogShard", "ColumnBatch", "StringTable",
    "ChangelogHub", "ChangelogStream",
    "Scanner", "multi_client_scan", "prune_missing",
    "EventPipeline", "PipelineConfig",
    "ALWAYS", "And", "Cmp", "Const", "Expr", "Not", "Or", "PolicyError",
    "compile_program", "parse_expr", "KERNEL_COLUMNS",
    "PolicyDefinition", "PolicyEngine", "Rule", "RunReport",
    "UsageWatermarkTrigger",
    "ChangelogCounters", "DirUsage", "StatsAggregator",
    "Reports", "AlertManager", "AlertRule", "HsmCoordinator",
    "PLUGIN_REGISTRY", "register_plugin",
]
