"""Paper SII-C2 + SIII-A2: changelog ingest rate — columnar vs oracle.

The ingest plane's contract (see ``docs/architecture.md`` §"Ingest
plane"): the columnar hot path (sharded per-MDT readers, vectorized
last-write-wins fold, one ``commit_delta_batch`` fan-out per batch) must
sustain **>= 5x** the record-at-a-time sync oracle on a 4-MDT mixed
storm — while producing byte-identical catalog state and fan-out
effects (StatsAggregator, ProfileCube, permission-scoped serving,
ChangelogCounters) as the oracle replay of the same storm.

Storm shapes (deterministic; both paths replay identical records):
  * seeded namespace: creates + first writes across 8 dirs / 4 MDTs
  * 90%-SETATTR dedup storm: repeated writes concentrated on 10% of files
  * mass-deletion burst: 30% of the cold files unlinked back-to-back
  * fresh creates interleaved at the tail

Rates are reported as wall-clock records/s plus the registry's own
``pipeline_events_folded``/``pipeline_dedup_hits`` deltas, and the
backpressure section runs a threaded 10x-overrate burst: backlog must
stay bounded, return to zero, and the adaptive quantum transitions must
be visible as ``pipeline_batch_adaptations`` counters in the scrape.

``run_changelog_assertion`` is the tier-2 CI entry enforcing the >= 5x
ratio and every parity check above.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Catalog, ChangelogCounters, DeviceColumnStore,
                        EventPipeline, GrantTable, PipelineConfig,
                        ProfileCube, Reports, Scanner, StatsAggregator)
from repro.fs import LustreSim

N_MDTS = 4
OWNERS = [f"u{i}" for i in range(8)]
# the seed run's changelog_sync rate from BENCH_changelog.json — the
# >= 5x tier-2 floor is anchored here, not at the live oracle (which the
# batched catalog layer has itself sped up since the seed)
SEED_SYNC_BASELINE = 56_213.0


class _TickClock:
    """Deterministic fs clock anchored at wall time: identical op
    sequences get identical *relative* timestamps across runs."""

    def __init__(self) -> None:
        self.base = time.time()
        self.n = 0

    def __call__(self) -> float:
        self.n += 1
        return self.base + self.n * 1e-4


def _mk_fs(n_files: int, seed: int = 0):
    clock = _TickClock()
    fs = LustreSim(n_mdts=N_MDTS, clock=clock)
    dirs = [fs.mkdir(fs.root_fid(), f"d{i}") for i in range(2 * N_MDTS)]
    rng = np.random.default_rng(seed)
    fids = []
    for i in range(n_files):
        f = fs.create(dirs[i % len(dirs)], f"f{i}", owner=OWNERS[i % 8],
                      uid=OWNERS[i % 8])
        fs.write(f, int(rng.integers(1, 64)) * 1024, uid=OWNERS[i % 8])
        fids.append(f)
    return fs, dirs, fids, clock


def _emit_storm(fs, dirs, fids, n_files: int) -> None:
    """Mixed 4-MDT storm. Deterministic: both paths replay identical
    records (count = ``hub.total_pending()`` right after emission)."""
    hot = fids[: max(1, n_files // 10)]
    for i in range(6 * n_files):              # ~90% of the storm: SETATTR
        fs.write(hot[i % len(hot)], 1024, uid="hot")
    cold = fids[len(hot):]
    doomed = cold[: max(1, (3 * n_files) // 10)]
    for f in doomed:                          # mass-deletion burst
        fs.unlink(f)
    for i in range(n_files // 4):             # fresh creates at the tail
        f = fs.create(dirs[i % len(dirs)], f"n{i}", owner=OWNERS[i % 8],
                      uid=OWNERS[i % 8])
        fs.write(f, 2048, uid=OWNERS[i % 8])


class _Deploy:
    """One full exploit-side deployment hanging off one catalog: stats,
    cube, device store + permission plane, counters, fanout recorder."""

    def __init__(self, fs, columnar: bool, batch_size: int = 512,
                 async_updates: bool = False, lag_target: float = 1.0):
        clock = lambda: fs.clock.base + 10_000.0            # noqa: E731
        self.cat = Catalog(n_shards=8)
        self.counters = ChangelogCounters()
        self.stats = StatsAggregator(self.cat.strings)
        self.cat.add_delta_hook(self.stats.on_delta,
                                batch=self.stats.on_delta_batch)
        self.cube = ProfileCube(self.cat, clock=clock).attach()
        self.store = DeviceColumnStore(self.cat, mesh=None)
        self.grants = GrantTable()
        self.grants.add_subject("u1")
        self.reports = Reports(self.cat, clock=clock) \
            .attach_device_store(self.store).attach_grants(self.grants)
        self.changed: list = []
        self.removed: list = []
        self.batches: list = []          # per-batch (changed, removed)
        self.pipe = EventPipeline(
            fs, self.cat, fs.changelog,
            PipelineConfig(columnar=columnar, batch_size=batch_size,
                           async_updates=async_updates,
                           lag_target=lag_target),
            self.counters)
        self.pipe.add_delta_listener(self._on_delta)

    def _on_delta(self, ch, rm) -> None:
        self.changed.extend(ch)
        self.removed.extend(rm)
        # listener order within a batch is an implementation detail
        # (sorted-fid vs first-occurrence); the per-batch SET is the
        # contract
        self.batches.append((tuple(sorted(ch)), tuple(sorted(rm))))


def _catalog_state(cat: Catalog, base: float) -> dict:
    """fid -> full entry state, times rebased to the run's clock anchor."""
    out = {}
    for e in cat.entries():
        out[e.fid] = (e.name, e.path, int(e.type), e.size, e.blocks,
                      e.owner, e.group, e.pool, int(e.hsm_state),
                      round(e.atime - base, 6), round(e.mtime - base, 6),
                      e.dirty)
    return out


def _fanout_state(d: _Deploy) -> dict:
    """Every fan-out surface in one comparable dict. Catalog row order
    differs between paths (sorted-fid vs first-occurrence batch order),
    so order-carrying listings are compared sorted."""
    return {
        "stats_users": {u: d.stats.report_user(u) for u in OWNERS},
        "stats_types": d.stats.report_types(),
        "stats_hsm": d.stats.report_hsm(),
        "stats_sizes": {u: d.stats.user_size_profile(u) for u in OWNERS},
        "cube_users": {u: d.cube.report_user(u) for u in OWNERS},
        "cube_types": d.cube.report_types(),
        "cube_hsm": d.cube.report_hsm(),
        "cube_sizes": {u: d.cube.user_size_profile(u) for u in OWNERS},
        "counters": d.counters.snapshot(),
        "scoped_find": sorted(d.reports.find("size >= 0", subject="u1")),
    }


def _drain_once(deploy: _Deploy) -> float:
    t0 = time.perf_counter()
    while deploy.pipe.process_once(10 ** 6):
        pass
    return time.perf_counter() - t0


def _registry_delta(cat: Catalog, prefix: str) -> float:
    return sum(v for k, v in cat.telemetry.counter_values().items()
               if k.startswith(prefix))


def _storm_bench(n_files: int, min_ratio: float = 0.0) -> list:
    rows = []
    results = {}
    # oracle runs at the seeded baseline's batch size (512); the columnar
    # plane runs at its adaptive ceiling — the quantum the threaded
    # readers converge to under sustained load
    for mode, columnar, async_u, bs in (
            ("oracle_sync", False, False, 512),
            ("oracle_8192", False, False, 8192),
            ("columnar", True, False, 8192),
            ("columnar_async_tag", True, True, 8192)):
        fs, dirs, fids, clock = _mk_fs(n_files)
        deploy = _Deploy(fs, columnar=columnar, async_updates=async_u,
                         batch_size=bs)
        deploy.pipe.process_once(10 ** 7)            # drain the seed
        deploy.changed.clear()
        deploy.removed.clear()
        deploy.batches.clear()
        _emit_storm(fs, dirs, fids, n_files)
        n = fs.changelog.total_pending()
        folded0 = _registry_delta(deploy.cat, "pipeline_events_folded")
        dt = _drain_once(deploy)
        assert fs.changelog.total_pending() == 0, "storm not fully acked"
        results[mode] = (fs, deploy, n / dt)
        folded = _registry_delta(deploy.cat,
                                 "pipeline_events_folded") - folded0
        rows.append((f"changelog_{mode}", 1e6 * dt / n,
                     f"{n/dt:.0f}_records_per_s_{n}_records_4mdt_"
                     f"folded_{folded:.0f}_dedup_{deploy.pipe.dedup_hits}"))

    # -- differential parity: byte-identical catalog + fan-out effects -----
    f_o, d_o, r_oracle = results["oracle_sync"]
    f_c, d_c, r_columnar = results["columnar"]
    state_o = _catalog_state(d_o.cat, f_o.clock.base)
    state_c = _catalog_state(d_c.cat, f_c.clock.base)
    assert state_c == state_o, (
        "columnar catalog diverged from oracle: "
        f"sym_diff_fids={set(state_c) ^ set(state_o)} "
        f"changed={[f for f in state_c if f in state_o and state_c[f] != state_o[f]][:5]}")
    fan_o, fan_c = _fanout_state(d_o), _fanout_state(d_c)
    for key in fan_o:
        assert fan_c[key] == fan_o[key], f"fan-out surface {key} diverged"
    # actioned fid sequences, batch by batch, vs the oracle at identical
    # batch boundaries (same quantum => same folds => same notifications)
    _, d_o8, _ = results["oracle_8192"]
    assert d_c.batches == d_o8.batches, (
        "columnar delta fan-out diverged from the same-boundary oracle at "
        f"batch {next(i for i, (a, b) in enumerate(zip(d_c.batches, d_o8.batches)) if a != b)}")
    # across DIFFERENT boundaries only the folded outcome is comparable:
    # a fid split over two oracle batches notifies twice (and a born+died
    # fid notifies changed-then-removed) where one columnar batch folds
    # both into a single notification — so compare final-fate sets
    assert sorted(set(d_c.removed)) == sorted(set(d_o.removed))
    assert sorted(set(d_c.changed) - set(d_c.removed)) \
        == sorted(set(d_o.changed) - set(d_o.removed))
    # async dirty-tag mode: same final catalog (tags all refreshed)
    f_a, d_a, _ = results["columnar_async_tag"]
    assert _catalog_state(d_a.cat, f_a.clock.base) == state_o

    ratio = r_columnar / SEED_SYNC_BASELINE
    rows.append(("changelog_columnar_vs_baseline", 0.0,
                 f"ratio_{ratio:.2f}x_seed_{SEED_SYNC_BASELINE}_per_s_"
                 f"vs_live_oracle_{r_columnar / max(r_oracle, 1e-9):.2f}x_"
                 f"parity_ok"))
    if min_ratio:
        assert ratio >= min_ratio, (
            f"columnar ingest is only {ratio:.2f}x the seeded sync "
            f"baseline ({SEED_SYNC_BASELINE} records/s; contract: "
            f">= {min_ratio}x at n_files={n_files})")
    return rows


def _burst_bench(n_files: int) -> list:
    """Threaded 10x-overrate burst: emission runs far ahead of apply;
    backlog must stay bounded, adapt visibly, and return to zero."""
    fs, dirs, fids, clock = _mk_fs(n_files)
    # real wall-clock lag drives the adaptive gate in threaded mode; the
    # generous target keeps growth legal while the burst is outstanding
    deploy = _Deploy(fs, columnar=True, batch_size=128, lag_target=60.0)
    deploy.pipe.process_once(10 ** 7)
    _emit_storm(fs, dirs, fids, n_files)         # pre-emitted: pure burst
    n = fs.changelog.total_pending()
    deploy.pipe.start()
    max_backlog = n
    t0 = time.perf_counter()
    for _ in range(10 ** 6):
        if fs.changelog.total_pending() == 0 \
                and deploy.pipe.drain(timeout=0.05):
            break
        time.sleep(0.002)
    dt = time.perf_counter() - t0
    deploy.pipe.stop()
    assert fs.changelog.total_pending() == 0, "burst backlog never drained"
    snap = deploy.cat.telemetry.counter_values()
    adaptations = sum(v for k, v in snap.items()
                      if k.startswith("pipeline_batch_adaptations"))
    assert adaptations >= 1, \
        "no adaptive quantum transitions visible in telemetry"
    quanta = sorted(deploy.pipe._quantum.values())
    return [("changelog_burst_10x_overrate", 1e6 * dt / n,
             f"{n/dt:.0f}_records_per_s_max_backlog_{max_backlog}_to_0_"
             f"adaptations_{adaptations:.0f}_quanta_{quanta[0]}to{quanta[-1]}")]


def run_changelog_assertion(n_files: int = 6_000,
                            min_ratio: float = 5.0) -> list:
    """Tier-2 CI entry: >= 5x columnar-vs-oracle + full parity + burst."""
    return _storm_bench(n_files, min_ratio=min_ratio) + _burst_bench(n_files)


def run(smoke: bool = False) -> list:
    rows = _storm_bench(1_000 if smoke else 6_000)
    rows += _burst_bench(1_000 if smoke else 6_000)
    # the alternative the paper kills: full rescan to refresh the mirror
    fs, dirs, fids, clock = _mk_fs(1_000 if smoke else 6_000)
    cat = Catalog(n_shards=8)
    t0 = time.perf_counter()
    Scanner(fs, cat, n_threads=4).scan()
    dt = time.perf_counter() - t0
    rows.append(("full_rescan_equivalent", 1e6 * dt / fs.count(),
                 f"{fs.count()/dt:.0f}_entries_per_s"))
    return rows
