"""Benchmark harness: one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (harness contract). Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "bench_scan",        # Fig. 3: parallel DFS + multi-client scan
    "bench_changelog",   # SII-C2/SIII-A2: changelog rates, async dirty-tag
    "bench_stats",       # SII-B3: O(1) pre-aggregated reports
    "bench_policy",      # SII-B1: policy matching (4 evaluators)
    "bench_find_du",     # SII-B4: find/du clones vs POSIX walk
    "bench_kvtier",      # adapted C7/C8: KV-page tiering + paged serving
    "roofline_report",   # SRoofline summary rows from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name in MODULES:
        if args.only and args.only != name:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}", flush=True)
        except Exception as e:
            failed += 1
            print(f"{name},NaN,ERROR_{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
