"""Paper SII-B1: policy-criteria matching throughput over the catalog.

Four evaluators of the same expression over N entries: per-entry python
(MySQL-row analogue), vectorized numpy masks, the pure-jnp kernel oracle,
and the Pallas ``policy_scan`` kernel in interpret mode (the TPU path;
interpret mode measures correctness not speed — on-TPU it fuses the scan
with aggregation in one HBM pass).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Catalog, Entry, FsType, parse_expr
from repro.core.policy import KERNEL_COLUMNS, compile_program
from repro.kernels.policy_scan.ops import policy_scan

EXPR = "(size > 1GB or owner == 'user3') and not last_access > 30d"
N = 120_000


def _catalog(n):
    rng = np.random.default_rng(1)
    now = time.time()
    cat = Catalog(n_shards=4)
    entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                     type=FsType.FILE, size=int(rng.integers(0, 2 << 30)),
                     blocks=100, owner=f"user{int(rng.integers(0, 8))}",
                     atime=now - float(rng.integers(0, 90 * 86400)))
               for i in range(n)]
    cat.upsert_batch(entries)
    return cat


def run() -> list:
    cat = _catalog(N)
    now = time.time()
    expr = parse_expr(EXPR)
    rows = []

    t0 = time.perf_counter()
    n_match = sum(1 for e in cat.entries() if expr.evaluate(e, now))
    dt_py = time.perf_counter() - t0
    rows.append(("policy_per_entry_python", 1e6 * dt_py / N,
                 f"{N/dt_py:.0f}_entries_per_s_match_{n_match}"))

    cols = cat.arrays()
    t0 = time.perf_counter()
    for _ in range(5):
        mask = expr.mask(cols, cat.strings, now)
    dt_np = (time.perf_counter() - t0) / 5
    rows.append(("policy_numpy_mask", 1e6 * dt_np / N,
                 f"{N/dt_np:.0f}_entries_per_s_speedup_{dt_py/dt_np:.0f}x"))

    ops, ci, opr = compile_program(expr, cat.strings, now)
    kcols = jnp.stack([jnp.asarray(cols[c], jnp.float32)
                       for c in KERNEL_COLUMNS])
    args = (kcols, jnp.asarray(ops), jnp.asarray(ci), jnp.asarray(opr))
    kw = dict(size_col=KERNEL_COLUMNS.index("size"),
              blocks_col=KERNEL_COLUMNS.index("blocks"))
    m, agg = policy_scan(*args, use_kernel=False, **kw)   # warm + check
    # f32 kernel columns hold epoch seconds at ~64 s resolution; entries
    # within that window of the 30d age cutoff may flip vs the f64 path
    assert abs(int(agg[0]) - n_match) <= 8, (int(agg[0]), n_match)
    t0 = time.perf_counter()
    for _ in range(5):
        m, agg = policy_scan(*args, use_kernel=False, **kw)
        m.block_until_ready()
    dt_jnp = (time.perf_counter() - t0) / 5
    rows.append(("policy_jnp_oracle_fused_agg", 1e6 * dt_jnp / N,
                 f"{N/dt_jnp:.0f}_entries_per_s"))

    m, agg = policy_scan(*args, use_kernel=True, **kw)
    assert abs(int(agg[0]) - n_match) <= 8, (int(agg[0]), n_match)
    t0 = time.perf_counter()
    m, agg = policy_scan(*args, use_kernel=True, **kw)
    m.block_until_ready()
    dt_k = time.perf_counter() - t0
    rows.append(("policy_pallas_interpret", 1e6 * dt_k / N,
                 "correctness_path_TPU_target"))
    return rows
