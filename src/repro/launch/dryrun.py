import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Two compiles per cell:

* **production** — the deployable program (lax.scan over layer superblocks,
  chunked attention, real grad-accum). Proves sharding coherence and gives
  ``memory_analysis()`` (per-device fit) and compile time. XLA's
  ``cost_analysis()`` counts while-loop bodies ONCE (verified in
  EXPERIMENTS.md SDry-run), so its FLOPs are NOT usable for the roofline.
* **analysis** (single-pod roofline cells only) — the same math with every
  loop unrolled (layers via a Python loop, attention/rwkv chunk scans via
  ``lax.scan(unroll=True)``) and accum=1, so every FLOP/byte/collective is
  counted. A separately-lowered optimizer-update program isolates the
  once-per-step cost; the full step is then
      step = (analysis - opt) * accum + opt.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import Model, shapes_for
from repro.models.config import ALL_SHAPES, ShapeSpec
from repro.optim import AdamW
from repro.runtime.sharding import ShardingRules, profile_for
from repro.serve import make_prefill, make_serve_step
from repro.train import init_train_state, make_train_step

DEFAULT_ACCUM = 4
ACCUM_OVERRIDES = {
    "mixtral_8x22b": 8,
    "llama4_maverick_400b_a17b": 8,
    "deepseek_coder_33b": 8,
}
# bf16 adam moments for the 400B model (single-pod HBM fit; DESIGN SS5)
BF16_MOMENTS = {"llama4_maverick_400b_a17b"}


def _canon(arch: str) -> str:
    return ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")


def batch_specs(cfg, shape: ShapeSpec, accum: int,
                train: bool = False) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    mb = B // accum
    # train batches always carry the leading accum dim (scan-consumed)
    lead = (accum,) if (train or accum > 1) else ()
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct(lead + (mb, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (mb, S), jnp.int32),
    }
    extras = {}
    if cfg.encoder is not None:
        extras["frames"] = jax.ShapeDtypeStruct(
            lead + (mb, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.n_img_tokens:
        extras["img"] = jax.ShapeDtypeStruct(
            lead + (mb, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    if extras:
        out["extras"] = extras
    return out


def _logits_pspec(cfg, rules: ShardingRules, shape: ShapeSpec):
    dp = rules._dp_if(shape.global_batch if shape.kind != "train"
                      else shape.global_batch // 1)
    vcol = rules._col(cfg.vocab)
    if vcol is not None:
        return P(dp, None, vcol)
    if shape.kind != "decode" and shape.seq_len % rules.tp_size == 0:
        return P(dp, rules.axes.tp, None)      # sequence-shard the loss
    return P(dp, None, None)


REMAT_POLICY = {"value": "full"}   # overridable via --remat (SPerf)


def _make_model(cfg, rules, shape, analysis: bool, kv_chunk: int) -> Model:
    return Model(
        cfg, kv_chunk=kv_chunk,
        unroll_layers=analysis, inner_unroll=True if analysis else 1,
        logits_pspec=_logits_pspec(cfg, rules, shape),
        remat_policy=REMAT_POLICY["value"])


def lower_cell(arch: str, shape: ShapeSpec, multi_pod: bool,
               accum: Optional[int] = None, kv_chunk: int = 1024,
               profile: Optional[str] = None, analysis: bool = False,
               cfg_override=None, moe_groups: int = 0,
               kv_int8: bool = False):
    """Lower one cell; returns (lowered, context dict)."""
    arch = _canon(arch)
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if moe_groups or kv_int8:
        import dataclasses as _dc0
        if moe_groups:
            cfg = _dc0.replace(cfg, moe_groups=moe_groups)
        if kv_int8:
            cfg = _dc0.replace(cfg, kv_cache_dtype="int8")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(cfg, mesh, profile or profile_for(cfg))
    if cfg.moe is not None and cfg.moe_groups > 1 and cfg.moe_pspec is None:
        import dataclasses as _dc1
        dp = rules.axes.dp if len(rules.axes.dp) > 1 else rules.axes.dp[0]
        cfg = _dc1.replace(cfg, moe_pspec=P(dp, None, None, None))
    model = _make_model(cfg, rules, shape, analysis, kv_chunk)
    ctx = {"cfg": cfg, "mesh": mesh, "rules": rules}

    if shape.kind == "train":
        acc = 1 if analysis else (
            accum or ACCUM_OVERRIDES.get(arch, DEFAULT_ACCUM))
        ctx["accum"] = acc
        opt = AdamW(moment_dtype=jnp.bfloat16 if arch in BF16_MOMENTS
                    else jnp.float32)
        ctx["opt"] = opt
        state_specs = jax.eval_shape(
            lambda: init_train_state(model, opt, jax.random.PRNGKey(0)))
        ctx["state_specs"] = state_specs
        pspecs = {
            "params": rules.param_pspecs(state_specs["params"]),
            "opt": {"m": rules.opt_state_pspecs(state_specs["params"]),
                    "v": rules.opt_state_pspecs(state_specs["params"]),
                    "count": P()},
            "step": P(),
        }
        ctx["state_pspecs"] = pspecs
        state_sh = rules.to_shardings(pspecs)
        batch = batch_specs(cfg, shape, acc, train=True)
        batch_sh = rules.to_shardings(rules.batch_pspecs(batch))
        step_fn = make_train_step(
            model, opt,
            grad_pspecs=rules.opt_state_pspecs(state_specs["params"]))
        metrics_sh = {k: NamedSharding(mesh, P())
                      for k in ("loss", "ce", "aux")}
        with mesh:
            lowered = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, metrics_sh),
                              donate_argnums=0).lower(state_specs, batch)
        return lowered, ctx

    param_specs = model.param_specs()
    param_sh = rules.to_shardings(rules.param_pspecs(param_specs))
    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, accum=1)
        batch_sh = rules.to_shardings(rules.batch_pspecs(batch))
        prefill_fn = make_prefill(model, cache_len=shape.seq_len)
        cache_specs = model.init_cache(shape.global_batch, shape.seq_len,
                                       abstract=True)
        cache_sh = rules.to_shardings(rules.cache_pspecs(cache_specs))
        logits_sh = NamedSharding(
            mesh, P(rules._dp_if(shape.global_batch), None))
        args = (param_specs, batch["tokens"])
        in_sh = (param_sh, batch_sh["tokens"])
        if "extras" in batch:
            args = args + (batch["extras"],)
            in_sh = in_sh + (batch_sh["extras"],)
        with mesh:
            lowered = jax.jit(prefill_fn, in_shardings=in_sh,
                              out_shardings=(logits_sh, cache_sh)
                              ).lower(*args)
        return lowered, ctx

    # decode
    cache_specs = model.init_cache(shape.global_batch, shape.seq_len,
                                   abstract=True)
    cache_sh = rules.to_shardings(rules.cache_pspecs(cache_specs))
    B = shape.global_batch
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(rules._dp_if(B), None))
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    serve_fn = make_serve_step(model)
    with mesh:
        lowered = jax.jit(
            serve_fn,
            in_shardings=(param_sh, cache_sh, tok_sh,
                          NamedSharding(mesh, P())),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=1,               # cache is updated in place
        ).lower(param_specs, cache_specs, tok_spec, pos_spec)
    return lowered, ctx


def _opt_cost(ctx) -> Dict[str, float]:
    """Cost of the once-per-step optimizer update, lowered standalone."""
    rules, mesh, opt = ctx["rules"], ctx["mesh"], ctx["opt"]
    state_specs = ctx["state_specs"]
    pspecs = ctx["state_pspecs"]
    params = state_specs["params"]
    grad_specs = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16), params)
    param_sh = rules.to_shardings(pspecs["params"])
    opt_sh = rules.to_shardings(pspecs["opt"])
    with mesh:
        lowered = jax.jit(
            opt.update,
            in_shardings=(param_sh, opt_sh, param_sh),
            out_shardings=(param_sh, opt_sh),
        ).lower(grad_specs, state_specs["opt"], params)
    compiled = lowered.compile()
    a = roofline.analyze(compiled)
    return {"flops": a["flops_per_device"],
            "bytes": a["bytes_accessed_per_device"],
            "wire": a["collective_wire_bytes"]}


def build_cell(arch: str, shape: ShapeSpec, multi_pod: bool,
               accum: Optional[int] = None, kv_chunk: int = 1024,
               profile: Optional[str] = None,
               with_analysis: bool = True,
               moe_groups: int = 0, kv_int8: bool = False) -> Dict[str, Any]:
    arch = _canon(arch)
    cfg = get_config(arch)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }

    # ---- production compile: sharding coherence + memory fit -------------
    t0 = time.perf_counter()
    lowered, ctx = lower_cell(arch, shape, multi_pod, accum=accum,
                              kv_chunk=kv_chunk, profile=profile,
                              moe_groups=moe_groups, kv_int8=kv_int8)
    rec["lower_s"] = time.perf_counter() - t0
    rec["profile"] = ctx["rules"].profile
    rec["accum"] = ctx.get("accum", 1)
    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = time.perf_counter() - t0
    prod = roofline.analyze(compiled)
    rec["memory"] = prod["memory"]
    rec["production_collectives"] = prod["collectives"]
    rec["production_flops_once_counted"] = prod["flops_per_device"]

    # ---- analysis compiles: loop-corrected roofline -----------------------
    # Two reduced-depth, fully-unrolled lowerings (1 and 2 pattern periods,
    # + the real tail) give exact base and per-superblock marginal costs;
    # the full-depth step extrapolates linearly (flops/bytes/collectives
    # are all linear in the repeated-superblock count — embed/head/loss/
    # optimizer fixed costs live in the base). The accum=1 analysis
    # program covers one full semantic step (all tokens, one grad reduce,
    # one optimizer update).
    if with_analysis:
        import dataclasses as _dc
        t0 = time.perf_counter()
        p = len(cfg.pattern)
        tail = cfg.n_layers % p

        def reduced(n_periods: int):
            c = _dc.replace(cfg, n_layers=n_periods * p + tail)
            if moe_groups:
                c = _dc.replace(c, moe_groups=moe_groups)
            if kv_int8:
                c = _dc.replace(c, kv_cache_dtype="int8")
            if cfg.encoder is not None:
                c = _dc.replace(c, encoder=_dc.replace(
                    cfg.encoder, n_layers=n_periods))
            return c

        results = []
        for n_periods in (1, 2):
            lowered_a, _ = lower_cell(arch, shape, multi_pod, accum=accum,
                                      kv_chunk=kv_chunk, profile=profile,
                                      analysis=True,
                                      cfg_override=reduced(n_periods),
                                      moe_groups=moe_groups,
                                      kv_int8=kv_int8)
            results.append(roofline.analyze(lowered_a.compile()))
        rec["analysis_compile_s"] = time.perf_counter() - t0
        a1, a2 = results
        mult = cfg.n_super - 1

        def extrap(key):
            return a1[key] + (a2[key] - a1[key]) * mult

        flops = extrap("flops_per_device")
        nbytes = extrap("bytes_accessed_per_device")
        wire = extrap("collective_wire_bytes")
        rec["flops_per_device"] = flops
        rec["bytes_accessed_per_device"] = nbytes
        rec["collective_wire_bytes"] = wire
        rec["analysis_base"] = {k: a1[k] for k in
                                ("flops_per_device",
                                 "bytes_accessed_per_device",
                                 "collective_wire_bytes")}
        rec["collectives_per_superblock"] = {
            op: {kk: a2["collectives"][op][kk]
                 - a1["collectives"].get(op, {}).get(kk, 0)
                 for kk in ("count", "bytes", "wire_bytes")}
            for op in a2["collectives"]}
        rec.update(roofline.roofline_terms(flops, nbytes, wire))
        rec.update(roofline.model_flops(cfg, shape, rec["devices"]))
        if flops:
            rec["model_vs_hlo_flops"] = (rec["model_flops_per_device"]
                                         / flops)
    return rec


def iter_cells(archs, shapes, pods):
    for arch in archs:
        cfg = get_config(arch)
        arch_shapes = [s.name for s in shapes_for(cfg)]
        for sname in shapes:
            if sname not in arch_shapes:
                continue
            for multi_pod in pods:
                yield arch, ALL_SHAPES[sname], multi_pod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--profile", default=None, choices=[None, "tp", "fsdp"])
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="MoE dispatch groups (0 = config default; set to "
                         "the dp degree for local dispatch — SPerf)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized decode KV cache (SPerf)")
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the loop-unrolled roofline compile")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [_canon(args.arch)]
    shapes = list(ALL_SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    REMAT_POLICY["value"] = args.remat
    os.makedirs(args.out_dir, exist_ok=True)
    ok = fail = 0
    for arch, shape, multi_pod in iter_cells(archs, shapes, pods):
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        name = f"{arch}__{shape.name}__{mesh_tag}"
        if args.tag:
            name += f"__{args.tag}"
        out_path = os.path.join(args.out_dir, name + ".json")
        t0 = time.perf_counter()
        try:
            # roofline analysis is a single-pod deliverable; multi-pod cells
            # prove sharding + memory only
            rec = build_cell(arch, shape, multi_pod,
                             accum=args.accum or None,
                             kv_chunk=args.kv_chunk, profile=args.profile,
                             with_analysis=not args.no_analysis
                             and not multi_pod,
                             moe_groups=args.moe_groups,
                             kv_int8=args.kv_int8)
            rec["status"] = "ok"
            ok += 1
            extra = ""
            if "bottleneck" in rec:
                extra = (f" flops/dev={rec['flops_per_device']:.3e}"
                         f" bottleneck={rec['bottleneck']}")
            print(f"[OK]   {name}: compile={rec['compile_s']:.1f}s"
                  f" peak_mem="
                  f"{rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
                  + extra, flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape.name, "mesh": mesh_tag,
                   "status": "fail", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            fail += 1
            print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
        rec["wall_s"] = time.perf_counter() - t0
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    print(f"dry-run complete: {ok} ok, {fail} failed", flush=True)
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
