"""Device-resident sharded column store for mesh-parallel policy matching.

The paper's core scaling claim (SII-B1, SIII-B) is that policy runs over
billions of entries must never re-read the namespace. The engine's kernel
path used to violate that in two ways every run: ``Catalog.arrays()``
concatenated every shard's columns on the host, and ``match_programs``
re-stacked and re-uploaded the full f32 column stack host→device — all of
it landing on ONE device even though the catalog is already sharded. This
module keeps the kernel's column stacks *resident* on a device mesh and
maintains them by deltas, so a warm policy run uploads only the rows that
actually churned.

Residency model
---------------
Catalog shards are folded onto the 1-D ``("shards",)`` mesh (see
``launch.mesh.make_shards_mesh``): shard ``s`` belongs to **shard group**
``s % D`` for a D-device mesh, and each group's rows (the concatenation of
its member shards' valid-row snapshots) live on exactly one device as an
``(n_cols+1, Rp)`` float32 block — ``KERNEL_COLUMNS`` in kernel order plus
a trailing 0/1 row-validity column. Every group is padded to the same
``Rp`` (a kernel-tile multiple, allocated with growth headroom) so the
per-device blocks assemble zero-copy into one global ``(D, n_cols+1, Rp)``
array sharded along ``"shards"`` — the operand
:func:`~repro.kernels.policy_scan.ops.mesh_policy_scan_batch` consumes
under ``shard_map``. Matching therefore moves **no column data at all**:
only the (R, P) programs go up, and only the program-0 mask, the
first-match-wins rule attribution, and the psum-combined (R, N_AGG)
aggregates come back.

Beside each device block the store keeps a **host mirror** of the group:
the row-aligned ``fid`` array plus every kernel column in its native dtype.
The mirror is what translates matched local row indices back to fids and
serves exact int64/float64 ``size``/sort-key values to the engine's
planner — it is maintained by the same deltas as the device block, so no
post-match catalog gather is needed.

Version keying and refresh
--------------------------
Freshness is keyed by the existing per-shard change ticks
(:attr:`CatalogShard.version`): a group is *stale* when any member shard's
tick moved past the value recorded at its last upload, or when delta hooks
flagged pending changes. The store registers a
:meth:`Catalog.add_delta_hook` at attach time and classifies every delta:

* in-place update (old and new both present)  -> the fid joins the group's
  **dirty set**; refresh scatters just those rows — one
  :meth:`Catalog.gather_rows` host gather, one vectorized
  ``block.at[:, rows].set(vals)`` on the owning device (row positions are
  stable under pure updates, so the scatter is exact);
* insert or remove (``old is None`` / ``new is None``) -> the group is
  flagged **structural** and falls back to a full re-upload (snapshot →
  restack → ``device_put``), because row positions shift;
* dirty set larger than ``refresh_frac`` of the group's rows -> full
  re-upload too (documented churn threshold: past it one contiguous upload
  beats that many scattered rows);
* shard tick moved with *no* recorded deltas (store attached late, hooks
  bypassed) -> full re-upload, never a stale serve.

Version ticks are read *before* the snapshot/gather (the catalog's own
``_bump`` discipline), so a racing mutation can only make the next refresh
redundant, never leave the device block stale. A group whose row count
outgrows ``Rp`` re-pads the mesh capacity, but only the grown group
re-uploads: every other clean block is widened *on-device* with a donated
zero-pad (``device_pads`` counts these; untouched groups keep their
buffers).

Tiered residency (out-of-core catalogs)
---------------------------------------
With ``hbm_budget_rows`` set, the full column stack no longer needs to
fit in device memory. A placement pass at the top of every refresh ranks
shard groups by decayed delta churn (``heat``) and the profile cube's
hot-volume fraction (recently-accessed bytes), and keeps the hottest
prefix resident under the budget (`2*D*window_rows` reserved for the
streaming window when anything is demoted; residents win exact ties, so
placement has hysteresis). The rest **demote**: the group's column stack
is packed into a compact host :class:`~repro.core.segments.PackedSegment`
(dict/delta-encoded ints, raw floats/paths — exact round-trip), persisted
as an mmap-able ``.npz`` beside the catalog's sqlite mirror when one
exists, its device buffers freed and its host mirrors dropped — the
segment *is* the warm copy. Demotion can run asynchronously
(``demote_async=True``): the pack is built from a shadow snapshot off the
store lock while the group keeps serving resident, and the commit
re-validates catalog versions (a raced pack is discarded —
``demote_races``). Hot-again groups **promote** by decoding the segment
back into host mirrors and staging through the normal upload path.

Queries keep working over the whole catalog, byte-identical to the host
oracles. Resident groups assemble over a cached *sub-mesh* of their
devices and run exactly the pre-tiering launches. Demoted groups
**stream**: the segment decodes into a cached f32 row stack that walks
the full mesh in ``(D, n_rows, Rw)`` windows through two host staging
buffers — batch k+1 is staged and dispatched while batch k computes
(async dispatch overlaps copy with compute; ``window_stalls`` counts the
batches whose compute was not hidden), and per-window partial aggregates
merge with the resident results (sum for additive slots, max for
``any_match`` — the host-side analogue of the in-launch psum/pmax).
Unscoped profile-cube queries never stream at all: each demoted group
carries an exact int64 **frozen partial cube** captured at demote time
and refrozen from the segment only when a scheduled age flip passes.
``RunReport.tiering`` surfaces the demotion/promotion/streaming counters
per policy run.

Analytics planes (mesh-resident reports + profile cube)
-------------------------------------------------------
Beyond the kernel columns, each device block can carry extra **analytics
rows** maintained by the very same upload/scatter paths:

* **reports plane** (:meth:`DeviceColumnStore.enable_reports_plane`):
  one ``ord`` row — each row's rank in its group's *sorted-path* order.
  ``rbh-du`` becomes two host binary searches into the group's sorted
  path mirror plus one fused on-device range aggregate
  (:func:`~repro.kernels.policy_scan.ops.mesh_range_aggregate`);
  ``rbh-find`` is a mesh program match whose winners translate to paths
  through the mirror; top-N listings run a two-pass on-device top-k
  (:func:`~repro.kernels.policy_scan.ops.mesh_column_topk` to find the
  exact k-th-best threshold, then a threshold mask to recover every
  boundary tie). A *rename* (path change on a pure update) shifts the
  sorted order, so it degrades that group to a full re-upload exactly
  like a structural change.
* **cube plane** (:meth:`DeviceColumnStore.enable_cube_plane`): three
  rows — dense profile group id (``core.profiles.GroupIndex``), size
  bucket and age bucket (bucketized exactly on the host at scatter
  time). Each device additionally keeps a flat **partial profile cube**
  of its resident rows, built in one
  :func:`~repro.kernels.profile_cube.ops.mesh_profile_cube` launch and
  maintained by O(dirty) *signed* scatter-adds from the same delta
  batches that refresh the columns; queries psum-combine the resident
  partials (:func:`~repro.kernels.profile_cube.ops.mesh_cube_combine`)
  — after the cold build no profile query re-reads host columns. Age
  buckets reference the store-wide ``_cube_ref`` instant; per-row flip
  schedules (mirroring ``core.profiles._ShardCube``) advance only the
  due rows when queries move ``now`` forward.
* **permissions plane**
  (:meth:`DeviceColumnStore.enable_permissions_plane`): per-subject
  visibility pre-materialized as packed ``uint32`` bitsets over local
  row ids — one ``(1, Sp, Rp/32)`` buffer per device beside the column
  block (bit ``b`` of word ``w``, LSB first, covers local row
  ``w*32+b``). Visibility comes from a
  :class:`~repro.core.grants.GrantTable`: uid/gid ownership via the
  interned owner/group codes, directory-subtree grants resolved through
  the reports plane's sorted-path mirrors (the same rank-range shape as
  ``du`` — enabling this plane forces the reports plane on). Scoped
  queries (``subject=`` on :meth:`match` / :meth:`find_paths` /
  :meth:`top_files` / :meth:`du` / :meth:`analytics_cube`) assemble the
  sharded perm array and pass a traced subject id; the kernels unpack
  that one subject's bitset and AND it into the match mask — tenant
  scoping is one fused AND, never a second scan. Maintenance follows
  the column contract: pure updates re-derive only the dirty rows'
  visibility and scatter just the *changed packed words* into the
  resident buffer; structural churn / renames / re-pads invalidate the
  group's bitset alongside its block, and any
  :attr:`~repro.core.grants.GrantTable.version` tick (new subject or
  grant change) re-materializes on the next scoped query.

Shared delta fan-out contract
-----------------------------
One catalog mutation fans out to every derived structure through
*independent* :meth:`Catalog.add_delta_hook` subscriptions, and each
consumer must apply it **exactly once**:

* this store's hook feeds the per-group dirty sets; a refresh drains a
  dirty *set* (duplicate updates to one fid collapse) and applies the
  column scatter, the analytics-row scatter and the signed cube move in
  the same drain — never separately;
* the cube's signed move subtracts the *mirror* state (what the resident
  cube actually holds) and adds the freshly gathered state, so collapsed
  multi-updates net out exactly;
* a :class:`~repro.core.profiles.ProfileCube` that attached this store
  (``ProfileCube.attach_device_store``) claims the cube's single delta
  feed and makes its own ``on_delta`` a no-op — wiring both its host
  hook and the store plane would double-count every mutation (the same
  single-feed contract as ``ProfileCube.attach`` vs a cube-backed
  ``StatsAggregator``);
* the policy engine's incremental state consumes the same deltas via
  ``note_touched``; a mesh full scan primes that cache through
  :meth:`MeshMatch.cache_arrays` (mirror-served, no catalog re-read).

f32 envelope
------------
Device blocks are float32, exactly like the single-device kernel path:
sizes above 2**24 bytes land on the nearest representable f32 (~one part
in 16M — entries within one ulp of a size cutoff may flip vs the int64
numpy path) and epoch-second timestamps carry ~64 s resolution. The host
mirror keeps native dtypes, so fids, budget sizes and sort keys returned
to the planner are exact; only predicate evaluation lives in the f32
envelope. The same envelope bounds the analytics planes: partial-cube
cells and ``du`` aggregates accumulate in f32 (exact for integer sums
below 2**24 times the value granularity), and path ranks are exact below
2**24 rows per group. Differential tests pin the envelope with f32-exact
catalogs; the host folds remain the differential oracles.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog, Delta
from .policy import KERNEL_COLUMNS, PolicyError, compile_programs
from .segments import PackedSegment
from .telemetry import counter_attr

_VALID_COL = len(KERNEL_COLUMNS)          # trailing 0/1 row-validity column

# analytics rows appended after the validity row when a plane is enabled
# (all four are allocated together; a disabled plane's rows stay zero)
_ORD_COL = _VALID_COL + 1                 # sorted-path rank (reports plane)
_GID_COL = _VALID_COL + 2                 # dense profile group id (cube)
_SB_COL = _VALID_COL + 3                  # size-profile bucket (cube)
_AB_COL = _VALID_COL + 4                  # age bucket as of _cube_ref (cube)
_N_ANALYTICS = 4

# columns the host mirror serves to the planner (fids + kernel columns);
# a policy sorting by anything else (e.g. parent_fid) cannot plan from the
# store and raises PolicyError -> the engine falls back to a host scan
PLAN_COLUMNS = ("fid",) + KERNEL_COLUMNS


class _RepadNeeded(Exception):
    """Internal: a group's snapshot outgrew the padded row capacity
    mid-refresh (concurrent inserts); refresh() re-pads and retries."""

    def __init__(self, rows: int) -> None:
        super().__init__(rows)
        self.rows = rows

_SCATTER_FN = None                        # lazily-jitted dirty-row scatter


def _scatter_rows(buf, rows: np.ndarray, vals: np.ndarray):
    """Scatter (C, k) dirty-row values into a resident (1, C+1, Rp) block.

    Jitted with the block donated (in-place on its own device) and k
    padded to power-of-two buckets by the caller, so XLA compiles one
    executable per (bucket, device) instead of one per distinct dirty-row
    count — the scatter itself is O(k), never O(Rp).
    """
    global _SCATTER_FN
    if _SCATTER_FN is None:
        import jax

        def fn(buf, rows, vals):
            return buf.at[0, : vals.shape[0], rows].set(vals.T)

        _SCATTER_FN = jax.jit(fn, donate_argnums=(0,))
    return _SCATTER_FN(buf, rows, vals)


def _pad_bucket(rows: np.ndarray, vals: np.ndarray, min_bucket: int = 64
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a scatter to the next power-of-two size with idempotent
    duplicates of row 0 (same index, same values -> deterministic).

    Safe for scatter-SET only: duplicated (index, value) pairs write the
    same value twice. A scatter-ADD must pad with *zero-valued* deltas
    instead (:func:`_pad_zero`) or padding would double-apply.
    """
    bucket = min_bucket
    while bucket < rows.size:
        bucket *= 2
    pad = bucket - rows.size
    if not pad:
        return rows, vals
    return (np.concatenate([rows, np.full(pad, rows[0], rows.dtype)]),
            np.concatenate([vals, np.repeat(vals[:, :1], pad, axis=1)],
                           axis=1))


def _pad_zero(flat: np.ndarray, vals: np.ndarray, min_bucket: int = 64
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Power-of-two padding for scatter-ADD: pad cells target index 0
    with all-zero deltas (adding 0 is the idempotent no-op here)."""
    bucket = min_bucket
    while bucket < flat.size:
        bucket *= 2
    pad = bucket - flat.size
    if not pad:
        return flat, vals
    return (np.concatenate([flat, np.zeros(pad, flat.dtype)]),
            np.concatenate([vals, np.zeros((vals.shape[0], pad),
                                           vals.dtype)], axis=1))


_PAD_BLOCK_FN = None                      # lazily-jitted on-device block pad


def _pad_block(buf, pad: int):
    """Widen a resident (1, C, Rp) block to Rp+pad on its own device by
    appending zero columns (pad rows read invalid, like fresh staging).
    Donated, with the pad width static — one executable per (old, new)
    capacity pair, and no host round-trip: this is what lets one grown
    shard group re-pad WITHOUT re-uploading every other group."""
    global _PAD_BLOCK_FN
    if _PAD_BLOCK_FN is None:
        import jax
        import jax.numpy as jnp

        def fn(buf, *, pad):
            return jnp.pad(buf, ((0, 0), (0, 0), (0, pad)))

        _PAD_BLOCK_FN = jax.jit(fn, static_argnames=("pad",),
                                donate_argnums=(0,))
    return _PAD_BLOCK_FN(buf, pad=pad)


_SCATTER_ROW_FN = None                    # lazily-jitted single-row scatter


def _scatter_row(buf, row: int, rows: np.ndarray, vals: np.ndarray):
    """Scatter values into ONE block row (age-bucket rollovers touch only
    the ``_AB_COL`` row). Donated + bucket-padded like :func:`_scatter_rows`;
    the row index is static (one executable per analytics row)."""
    global _SCATTER_ROW_FN
    if _SCATTER_ROW_FN is None:
        import jax

        def fn(buf, rows, vals, *, row):
            return buf.at[0, row, rows].set(vals)

        _SCATTER_ROW_FN = jax.jit(fn, static_argnames=("row",),
                                  donate_argnums=(0,))
    return _SCATTER_ROW_FN(buf, rows, vals, row=row)


_CUBE_SCATTER_FN = None                   # lazily-jitted cube scatter-add


def _cube_scatter(buf, flat: np.ndarray, vals: np.ndarray):
    """Signed scatter-add of (3, k) measure deltas into a resident
    (1, 3, M) flat partial cube at flat cell indices ``flat``. Donated
    (in-place on the partial's own device); callers pad with
    :func:`_pad_zero` so duplicate pad cells add nothing."""
    global _CUBE_SCATTER_FN
    if _CUBE_SCATTER_FN is None:
        import jax

        def fn(buf, flat, vals):
            return buf[0].at[:, flat].add(vals)[None]

        _CUBE_SCATTER_FN = jax.jit(fn, donate_argnums=(0,))
    return _CUBE_SCATTER_FN(buf, flat, vals)


class MeshMatch:
    """Result of one mesh-parallel program-batch evaluation.

    Holds the per-group matched local row indices (already nonzero'd on the
    host from the program-0 mask) plus the store's host mirrors; ``plan``
    gathers the planner arrays without touching the catalog. A delta
    refresh mutates the mirrors in place, so ``plan`` takes the store lock
    and raises :class:`PolicyError` when the store refreshed since this
    match (a stale plan would mix pre-churn masks with post-churn values)
    — call it before the next refresh, as the engine does.
    """

    def __init__(self, store: "DeviceColumnStore", epoch: int,
                 mirrors: List[Tuple[np.ndarray, Dict[str, np.ndarray]]],
                 group_idx: List[np.ndarray], group_rule: List[np.ndarray],
                 agg: dict, reval: int) -> None:
        self._store = store
        self._epoch = epoch                # store mutation tick at match
        self._mirrors = mirrors            # per group: (fids, cols) refs
        self._group_idx = group_idx        # per group: matched local rows
        self._group_rule = group_rule      # per group: rule idx at those rows
        self.agg = agg
        self.reval = reval                 # valid rows evaluated on-device

    @property
    def matched(self) -> int:
        return int(sum(ix.size for ix in self._group_idx))

    def plan(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """(fids, sizes, sort_keys, rule_idx) of matched rows, native
        dtypes from the host mirror (exact budgets/ordering)."""
        if sort_by not in PLAN_COLUMNS:
            raise PolicyError(
                f"sort_by {sort_by!r} is not in the device-store host "
                f"mirror (available: fid + kernel columns)")
        with self._store._lock:
            if self._store._epoch != self._epoch:
                raise PolicyError(
                    "stale MeshMatch: the device store refreshed since "
                    "this match — re-match before planning")
            return self._plan_locked(sort_by)

    def _plan_locked(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray]:
        fids, sizes, keys, rules = [], [], [], []
        for (gfids, gcols), idx, rl in zip(self._mirrors, self._group_idx,
                                           self._group_rule):
            fids.append(gfids[idx])
            sizes.append(gcols["size"][idx])
            keys.append(np.asarray(gcols[sort_by][idx], dtype=np.float64))
            rules.append(rl)
        return (np.concatenate(fids) if fids else np.zeros(0, np.int64),
                np.concatenate(sizes) if sizes else np.zeros(0, np.int64),
                np.concatenate(keys) if keys else np.zeros(0),
                np.concatenate(rules) if rules else np.zeros(0, np.int32))

    def cache_arrays(self, sort_by: str, age_preds, now: float
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray, np.ndarray]:
        """Plan arrays + the age-flip schedule that primes the engine's
        incremental match cache from this mesh full scan.

        Returns ``(fids, sizes, sort_keys, rule_idx, flip_fids, flips)``:
        the first four are :meth:`plan`'s exact output; the last two cover
        **every** mirrored row whose age predicates flip at a finite
        future instant (``time_col + threshold``, boundary kept — the
        same semantics as ``policy_engine._next_flips`` over a host
        snapshot), so a currently-unmatched row that ages into scope is
        still re-evaluated on time. Everything is served from the host
        mirrors — the catalog columns are never touched.
        """
        if sort_by not in PLAN_COLUMNS:
            raise PolicyError(
                f"sort_by {sort_by!r} is not in the device-store host "
                f"mirror (available: fid + kernel columns)")
        with self._store._lock:
            if self._store._epoch != self._epoch:
                raise PolicyError(
                    "stale MeshMatch: the device store refreshed since "
                    "this match — re-match before planning")
            fids, sizes, keys, rules = self._plan_locked(sort_by)
            ffids, flips = [], []
            for gfids, gcols in self._mirrors:
                if not gfids.size or not age_preds:
                    continue
                nxt = np.full(gfids.size, np.inf)
                for time_col, thr in age_preds:
                    cand = np.asarray(gcols[time_col],
                                      dtype=np.float64) + thr
                    np.minimum(nxt, np.where(cand >= now, cand, np.inf),
                               out=nxt)
                keep = np.isfinite(nxt)
                ffids.append(gfids[keep])
                flips.append(nxt[keep])
            return (fids, sizes, keys, rules,
                    np.concatenate(ffids) if ffids
                    else np.zeros(0, np.int64),
                    np.concatenate(flips) if flips else np.zeros(0))


class _ShardGroup:
    """One device's slice of the catalog: host mirror + freshness state.

    Beside the kernel-column mirror, a group carries the analytics-plane
    mirrors: ``offsets`` (member-shard row starts — find/top-N results
    re-emit in catalog ``arrays()`` order through them), the reports
    plane's row-aligned ``paths`` / sorted ``spaths`` / rank ``ord``, and
    the cube plane's per-row group id / size bucket / age bucket / next
    flip instant (``cgid``/``csb``/``cab``/``cflip``, ``cmin_flip`` the
    cheap due-rollover bound).
    """

    __slots__ = ("gid", "shard_ids", "fids", "cols", "rows", "versions",
                 "dirty", "structural", "uploaded", "_order",
                 "offsets", "paths", "spaths", "ord",
                 "cgid", "csb", "cab", "cflip", "cmin_flip", "vis",
                 "resident", "segment", "churn", "heat", "pending_demote",
                 "frozen_cube", "frozen_min_flip", "frozen_ref",
                 "sstack", "sstack_ref", "svis", "svis_ver", "sspaths")

    def __init__(self, gid: int, shard_ids: List[int]) -> None:
        self.gid = gid
        self.shard_ids = shard_ids
        self.fids = np.zeros(0, np.int64)
        self.cols: Dict[str, np.ndarray] = {}
        self.rows = 0                      # valid rows (<= Rp)
        self.versions: Dict[int, int] = {}  # shard id -> tick at last upload
        self.dirty: set = set()
        self.structural = False
        self.uploaded = False
        self._order: Optional[np.ndarray] = None   # argsort(fids), lazy
        self.offsets = np.zeros(1, np.int64)       # member-shard row starts
        self.paths: Optional[list] = None          # row-aligned (reports)
        self.spaths: Optional[np.ndarray] = None   # sorted paths (reports)
        self.ord: Optional[np.ndarray] = None      # row -> sorted-path rank
        self.cgid: Optional[np.ndarray] = None     # cube: dense group id
        self.csb: Optional[np.ndarray] = None      # cube: size bucket
        self.cab: Optional[np.ndarray] = None      # cube: age bucket @ ref
        self.cflip: Optional[np.ndarray] = None    # cube: next flip instant
        self.cmin_flip = np.inf
        self.vis: Optional[np.ndarray] = None      # perms: (Sp, rows) bool
        # tiered residency (see "Tiered residency" in the module doc)
        self.resident = True               # device-resident vs warm segment
        self.segment: Optional[PackedSegment] = None
        self.churn = 0                     # deltas since last placement pass
        self.heat = 0.0                    # decayed churn score (placement)
        self.pending_demote = False        # async pack in flight
        self.frozen_cube: Optional[np.ndarray] = None  # (3,b,S,A) i64 @ ref
        self.frozen_min_flip = np.inf      # first age flip that stales it
        self.frozen_ref = 0.0              # age reference it was built at
        # transient streaming caches (dropped on repack / promote)
        self.sstack: Optional[np.ndarray] = None   # decoded f32 row stack
        self.sstack_ref = np.nan                   # _cube_ref of sstack AB
        self.svis: Optional[np.ndarray] = None     # (Sp, rows) bool
        self.svis_ver = -1                         # grants version of svis
        self.sspaths: Optional[np.ndarray] = None  # sorted decoded paths

    def locate(self, fids: np.ndarray) -> Optional[np.ndarray]:
        """Local row index per fid; None when any fid is not in the mirror
        (caller falls back to a full re-upload)."""
        if not self.rows:
            return None
        if self._order is None:
            self._order = np.argsort(self.fids, kind="stable")
        sorted_fids = self.fids[self._order]
        pos = np.searchsorted(sorted_fids, fids)
        pos = np.clip(pos, 0, sorted_fids.size - 1)
        rows = self._order[pos]
        if not (self.fids[rows] == fids).all():
            return None
        return rows


class DeviceColumnStore:
    """Per-shard-group kernel column stacks held resident on a jax mesh.

    See the module docstring for the residency / refresh / envelope
    contracts. Construction registers a delta hook on the catalog and
    uploads lazily: the first :meth:`refresh` (or :meth:`match`) pays the
    cold full upload, warm calls scatter only churned rows.
    """

    # refresh-mode counters (benchmarks / tests assert the mode taken) —
    # registry-backed, read/written through the old int attribute API
    full_uploads = counter_attr(
        "store_full_uploads", "cold whole-block uploads")
    delta_refreshes = counter_attr(
        "store_delta_refreshes", "warm dirty-row scatter refreshes")
    rows_scattered = counter_attr(
        "store_rows_scattered", "rows moved by dirty scatters")
    cube_rebuilds = counter_attr(
        "store_cube_rebuilds", "full partial-cube rebuilds")
    rollovers = counter_attr(
        "store_rollovers", "age-bucket moves served on-device")
    store_queries = counter_attr(
        "store_queries", "report queries served resident")
    perm_materializations = counter_attr(
        "store_perm_materializations", "per-group perm bitset (re)builds")
    perm_word_scatters = counter_attr(
        "store_perm_word_scatters", "warm packed perm-word scatters")
    # tiering counters (RunReport / bench_tiering assert these so a
    # silently-resident "streaming" run fails loudly)
    demotions = counter_attr(
        "store_demotions", "groups packed to warm segments")
    promotions = counter_attr(
        "store_promotions", "groups re-uploaded from segments")
    segments_streamed = counter_attr(
        "store_segments_streamed", "warm-segment sweeps executed")
    windows_streamed = counter_attr(
        "store_windows_streamed", "device-window batches uploaded")
    window_stalls = counter_attr(
        "store_window_stalls", "window consume blocked on compute")
    segment_repacks = counter_attr(
        "store_segment_repacks", "stale segments re-encoded")
    demote_races = counter_attr(
        "store_demote_races", "async packs discarded (raced)")
    device_pads = counter_attr(
        "store_device_pads", "on-device re-pads (no re-upload)")

    def __init__(self, catalog: Catalog, mesh=None,
                 refresh_frac: float = 0.25, tile: int = 0,
                 headroom: float = 1.25,
                 hbm_budget_rows: Optional[int] = None,
                 window_rows: int = 0,
                 demote_async: bool = False) -> None:
        import jax
        from ..kernels.policy_scan.kernel import LANE
        if mesh is None:
            from ..launch.mesh import make_shards_mesh
            mesh = make_shards_mesh()
        if "shards" not in mesh.axis_names:
            raise PolicyError('device store needs a mesh with a "shards" '
                              f"axis, got {mesh.axis_names}")
        self.catalog = catalog
        self.mesh = mesh
        self.devices = list(np.asarray(mesh.devices).reshape(-1))
        self.n_devices = len(self.devices)
        self.refresh_frac = refresh_frac
        self.tile = tile or 8 * LANE
        self.headroom = headroom
        # tiered residency: total padded resident rows the mesh may hold
        # (None = unlimited, everything stays resident — the pre-tiering
        # behavior); when any group is demoted, 2*D*window_rows of the
        # budget are reserved for the double-buffered streaming window
        self.hbm_budget_rows = hbm_budget_rows
        # streaming window rows per device: 0 -> sized lazily from the
        # budget; explicit values round up to a tile multiple (the perm
        # window packing also needs a multiple of 32, which tile is)
        self._rw = (-(-window_rows // self.tile) * self.tile
                    if window_rows else 0)
        self.demote_async = demote_async
        self._demote_workers: List[threading.Thread] = []
        self._submeshes: Dict[tuple, object] = {}   # resident-set sub-meshes
        self._lock = threading.RLock()
        self._groups = [
            _ShardGroup(g, [s for s in range(catalog.n_shards)
                            if s % self.n_devices == g])
            for g in range(self.n_devices)]
        self._rp = 0                        # padded rows per device block
        self._bufs: List[Optional["jax.Array"]] = [None] * self.n_devices
        self._global = None                 # assembled (D, C+1, Rp) array
        self._epoch = 0                     # bumped by every mirror mutation
        # analytics planes (see module docstring): off until enabled
        self._plane_reports = False
        self._plane_cube = False
        self._cube_groups = None            # shared core.profiles.GroupIndex
        self._cube_clock = None
        self._cube_ref = 0.0                # age reference of resident cab
        self._cube_bp = 0                   # padded group capacity on device
        self._cube_bufs = None              # per-device (1, 3, bp*S*A) f32
        self._cube_partials = None          # assembled (D, 3, bp*S*A) array
        self._cube_cache = None             # host int64 (3, bp, S, A) cache
        self._cube_stale = True             # partials need a full rebuild
        self._plane_perm = False
        self._grants = None                 # shared core.grants.GrantTable
        self._grants_version = -1           # table version at materialization
        self._perm_sp = 0                   # padded subject capacity
        self._perm_bufs = None              # per-device (1, Sp, Rp/32) u32
        self._perm_global = None            # assembled (D, Sp, Rp/32) array
        # perf/tiering counters: registry-backed series on the catalog's
        # telemetry plane (instance label keeps several stores sharing one
        # catalog distinct); the zeroing writes below create the series so
        # they export as 0 before first use
        self.telemetry = catalog.telemetry
        self._tlabels = {"store": catalog.telemetry.instance("store")}
        self.full_uploads = 0
        self.delta_refreshes = 0
        self.rows_scattered = 0
        self.cube_rebuilds = 0
        self.rollovers = 0                  # age-bucket moves served on-device
        self.store_queries = 0              # report queries served resident
        self.perm_materializations = 0      # per-group bitset (re)builds
        self.perm_word_scatters = 0         # warm packed-word scatters
        self.demotions = 0                  # groups packed to warm segments
        self.promotions = 0                 # groups re-uploaded from segments
        self.segments_streamed = 0          # warm-segment sweeps executed
        self.windows_streamed = 0           # device-window batches uploaded
        self.window_stalls = 0              # consume blocked on compute
        self.segment_repacks = 0            # stale segments re-encoded
        self.demote_races = 0               # async packs discarded (raced)
        self.device_pads = 0                # on-device re-pads (no re-upload)
        catalog.add_delta_hook(self._on_delta, batch=self._on_delta_batch)

    # -- analytics planes ------------------------------------------------------
    def _block_rows(self) -> int:
        """Device-block row count: kernel columns + validity, plus the
        analytics rows once any plane is enabled."""
        extra = _N_ANALYTICS if (self._plane_reports or self._plane_cube) \
            else 0
        return len(KERNEL_COLUMNS) + 1 + extra

    def _drop_device_state(self) -> None:
        """Invalidate every resident block (block layout changed): the
        next refresh re-uploads at the new row count. Lock held."""
        self._bufs = [None] * self.n_devices
        self._global = None
        self._cube_bufs = None
        self._cube_partials = None
        self._cube_cache = None
        self._cube_stale = True
        self._perm_bufs = None
        self._perm_global = None
        self._epoch += 1
        for group in self._groups:
            group.uploaded = False
            group.vis = None

    def enable_reports_plane(self) -> None:
        """Add the sorted-path-rank row + path mirrors to every block so
        ``find``/``top_files``/``du`` serve from the resident mesh.
        Idempotent; the next refresh pays one full re-upload."""
        with self._lock:
            if self._plane_reports:
                return
            self._plane_reports = True
            self._drop_device_state()

    def enable_cube_plane(self, groups, clock) -> None:
        """Add the gid/size-bucket/age-bucket rows plus the per-device
        partial profile cubes. ``groups`` is the shared
        :class:`~repro.core.profiles.GroupIndex` (report masks read its
        key columns) and ``clock`` supplies the age reference. Idempotent
        for the same index; a different index raises."""
        with self._lock:
            if self._plane_cube:
                if groups is not self._cube_groups:
                    raise PolicyError(
                        "cube plane already enabled with a different "
                        "GroupIndex")
                return
            self._plane_cube = True
            self._cube_groups = groups
            self._cube_clock = clock
            self._cube_ref = float(clock())
            self._drop_device_state()

    def enable_permissions_plane(self, grants) -> None:
        """Add the per-subject packed visibility bitsets (multi-tenant
        ``subject=`` scoping). ``grants`` is the shared
        :class:`~repro.core.grants.GrantTable`; subtree grants resolve
        through the sorted-path mirrors, so this forces the reports plane
        on. Idempotent for the same table; a different table raises."""
        with self._lock:
            if self._plane_perm:
                if grants is not self._grants:
                    raise PolicyError(
                        "permissions plane already enabled with a "
                        "different GrantTable")
                return
            if self.tile % 32:
                raise PolicyError(
                    "permissions plane packs rows into uint32 words; the "
                    f"block tile must be a multiple of 32, got {self.tile}")
            self._plane_perm = True
            self._grants = grants
            self._grants_version = -1
            self._plane_reports = True
            self._drop_device_state()

    def detach(self) -> None:
        """Unregister from the catalog's delta hooks and drop the device
        blocks. A store that is replaced (mesh resize, re-attach) must be
        detached, or the long-lived catalog keeps feeding its dirty sets
        forever. A detached store can still match, but without delta
        intake every refresh is a cold full upload (the hook-less
        version-drift fallback) — detach is for decommissioning."""
        self.catalog.remove_delta_hook(self._on_delta)
        with self._lock:
            self._drop_device_state()
            for group in self._groups:
                group.dirty = set()
                group.structural = False
                group.fids = np.zeros(0, np.int64)
                group.cols = {}
                group.rows = 0
                group.offsets = np.zeros(1, np.int64)
                group.paths = group.spaths = group.ord = None
                group.cgid = group.csb = group.cab = group.cflip = None
                group.cmin_flip = np.inf
                group.vis = None
                group.resident = True
                group.segment = None
                group.pending_demote = False
                group.churn = 0
                group.heat = 0.0
                group.frozen_cube = None
                group.frozen_min_flip = np.inf
                group.sstack = group.svis = group.sspaths = None
                group.sstack_ref = np.nan
                group.svis_ver = -1
            self._rp = 0

    # -- delta intake (catalog mutation hooks) --------------------------------
    def _on_delta(self, old: Optional[Delta], new: Optional[Delta]) -> None:
        ref = new if new is not None else old
        if ref is None:
            return
        fid = int(ref[0])
        group = self._groups[self.catalog._shard_id(fid) % self.n_devices]
        group.churn += 1                    # placement heat (resident or not)
        if old is None or new is None:      # insert / remove: rows shift
            group.structural = True
        else:
            group.dirty.add(fid)

    def _on_delta_batch(self, pairs) -> None:
        """Single fan-out arm: classify one committed delta batch in one
        call — same per-pair semantics as :meth:`_on_delta`, with the
        group/shard routing hoisted out of the loop."""
        groups = self._groups
        shard_id = self.catalog._shard_id
        n_dev = self.n_devices
        for old, new in pairs:
            ref = new if new is not None else old
            if ref is None:
                continue
            group = groups[shard_id(int(ref[0])) % n_dev]
            group.churn += 1
            if old is None or new is None:
                group.structural = True
            else:
                group.dirty.add(int(ref[0]))

    # -- freshness ------------------------------------------------------------
    def _shard_versions(self, group: _ShardGroup) -> Dict[int, int]:
        return {s: self.catalog.shards[s].version for s in group.shard_ids}

    def _stale(self, group: _ShardGroup) -> bool:
        if not group.uploaded or group.structural or group.dirty:
            return True
        return self._shard_versions(group) != group.versions

    # -- upload paths ----------------------------------------------------------
    def _snapshot_group(self, group: _ShardGroup
                        ) -> Tuple[Dict[str, int], np.ndarray,
                                   Dict[str, np.ndarray], list, np.ndarray]:
        """(versions-before, fids, native column dict, paths, offsets)
        for a full upload. Paths are gathered only when the reports plane
        is on; ``offsets`` records each member shard's row start (the
        group's row order is the concat of member-shard snapshots, so
        results re-emit in catalog ``arrays()`` order through it)."""
        versions = self._shard_versions(group)   # BEFORE the snapshot reads
        names = ("fid",) + KERNEL_COLUMNS
        with_paths = self._plane_reports
        parts, paths, counts = [], [], []
        for s in group.shard_ids:
            cols_s, snap = self.catalog.shards[s].snapshot(
                names=names, with_strings=with_paths)
            parts.append(cols_s)
            counts.append(cols_s["fid"].size)
            if with_paths:
                paths.extend(snap.gather("_paths"))
        if parts:
            cols = {n: np.concatenate([p[n] for p in parts]) for n in names}
        else:
            cols = {n: np.zeros(0, dtype=np.int64) for n in names}
        # fid stays IN the mirror dict (it is a valid plan sort key)
        cols["fid"] = fids = cols["fid"].astype(np.int64, copy=False)
        offsets = np.concatenate([[0], np.cumsum(np.asarray(counts,
                                                            np.int64))])
        return versions, fids, cols, paths, offsets

    def _refresh_plane_mirrors(self, group: _ShardGroup,
                               paths: list) -> None:
        """Recompute a group's analytics mirrors after a full snapshot."""
        n = group.rows
        if self._plane_reports:
            group.paths = paths
            parr = np.asarray(paths) if paths else np.zeros(0, dtype="<U1")
            order = np.argsort(parr, kind="stable")
            group.spaths = parr[order]
            rank = np.empty(n, np.int64)
            rank[order] = np.arange(n)
            group.ord = rank
        if self._plane_cube:
            from .profiles import (_FLIP_EDGES, age_buckets_np,
                                   size_buckets_np)
            cols = group.cols
            group.cgid = self._cube_groups.get_or_add_many(
                cols["owner"], cols["group"], cols["type"],
                cols["hsm_state"])
            group.csb = size_buckets_np(np.asarray(cols["size"], np.int64))
            stamps = np.asarray(cols["atime"], np.float64)
            group.cab = age_buckets_np(self._cube_ref - stamps)
            group.cflip = stamps + _FLIP_EDGES[group.cab]
            finite = np.isfinite(group.cflip)
            group.cmin_flip = float(group.cflip[finite].min()) \
                if finite.any() else np.inf

    def _stack_f32(self, group: _ShardGroup, rp: int) -> np.ndarray:
        """(n_rows, rp) f32 device-block staging from the host mirror."""
        out = np.zeros((self._block_rows(), rp), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            out[i, : group.rows] = group.cols[name]
        out[_VALID_COL, : group.rows] = 1.0
        if self._plane_reports and group.ord is not None:
            out[_ORD_COL, : group.rows] = group.ord
        if self._plane_cube and group.cgid is not None:
            out[_GID_COL, : group.rows] = group.cgid
            out[_SB_COL, : group.rows] = group.csb
            out[_AB_COL, : group.rows] = group.cab
        return out

    def _host_refresh(self, group: _ShardGroup) -> None:
        """Bring a group's host mirrors (columns + plane mirrors) to the
        catalog's current state — the snapshot half of a full upload,
        shared with segment packing. Lock held."""
        versions, fids, cols, paths, offsets = self._snapshot_group(group)
        group.fids, group.cols, group.rows = fids, cols, fids.size
        group._order = None
        group.offsets = offsets
        self._refresh_plane_mirrors(group, paths)
        group.versions = versions
        group.dirty = set()
        group.structural = False

    def _mirror_fresh(self, group: _ShardGroup) -> bool:
        """True when the host mirrors already match the catalog (and hold
        every enabled plane's arrays), so a device upload can stage
        straight from them without re-snapshotting. Lock held."""
        if group.dirty or group.structural or not group.cols:
            return False
        if self._plane_reports and group.ord is None:
            return False
        if self._plane_cube and group.cgid is None:
            return False
        return self._shard_versions(group) == group.versions

    def _stage_upload(self, group: _ShardGroup, rp: int) -> None:
        """Stack the (fresh) host mirrors and ship the block to the
        group's device. Row positions are whatever the mirrors hold, so
        callers that changed them must invalidate vis/cube themselves."""
        import jax
        if group.rows > rp:
            # a concurrent insert grew the group past the capacity check
            # at the top of refresh(): re-pad and retry instead of serving
            # a truncated block (or crashing the stack staging)
            raise _RepadNeeded(group.rows)
        stack = self._stack_f32(group, rp)
        self._bufs[group.gid] = jax.device_put(
            stack[None], self.devices[group.gid])
        group.uploaded = True
        self._global = None
        self._epoch += 1
        self.full_uploads += 1
        self._bytes_moved("full", stack.nbytes)
        if self._plane_perm:
            # block capacity may differ from the old packed words: drop
            # the packed buffer (repacked from the kept vis mirror)
            if self._perm_bufs is not None:
                self._perm_bufs[group.gid] = None
            self._perm_global = None

    def _full_upload(self, group: _ShardGroup, rp: int) -> None:
        self._host_refresh(group)
        self._stage_upload(group, rp)
        if self._plane_perm:
            # row positions changed: the group's resident bitset indexes
            # stale local rows — re-materialize on the next scoped query
            group.vis = None
        if self._plane_cube:
            # row positions changed: this group's resident partial cube
            # no longer matches the block — rebuild on next cube query
            self._cube_stale = True
            self._cube_cache = None

    def _delta_refresh(self, group: _ShardGroup) -> bool:
        """Scatter just the dirty rows into the resident block; returns
        False when the group needs the full-upload fallback instead."""
        # swap the dirty set out BEFORE reading versions: a hook landing
        # after the swap goes to the fresh set and keeps the group stale
        # (re-scattered next refresh), so a concurrent mutation can delay
        # a row's upload by one refresh but never lose it — and the
        # fromiter below never races a growing set
        dirty_set, group.dirty = group.dirty, set()
        versions = self._shard_versions(group)   # BEFORE the row gather
        dirty = np.fromiter(dirty_set, dtype=np.int64, count=len(dirty_set))
        rows = group.locate(dirty)
        if rows is None:
            group.dirty |= dirty_set
            return False                    # unseen fid: rows shifted
        cols, present = self.catalog.gather_rows(
            dirty.tolist(), with_strings=self._plane_reports)
        if not bool(present.all()):
            group.dirty |= dirty_set
            return False                    # raced a remove: restack
        if self._plane_reports:
            # a rename shifts the group's sorted-path order (every rank
            # after the move changes): degrade to a full re-upload, the
            # same fallback as a structural change
            if any(group.paths[r] != p
                   for r, p in zip(rows.tolist(), cols["_paths"])):
                group.dirty |= dirty_set
                group.structural = True
                return False
        cube_live = (self._plane_cube and self._cube_bufs is not None
                     and not self._cube_stale)
        if cube_live:
            # capture the OLD cube cells before the mirror updates — the
            # signed move subtracts exactly what the resident cube holds
            old_cells = (group.cgid[rows].copy(), group.csb[rows].copy(),
                         group.cab[rows].copy(),
                         np.asarray(group.cols["size"][rows], np.float32),
                         np.asarray(group.cols["blocks"][rows], np.float32))
        vals = np.zeros((self._block_rows(), dirty.size), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            group.cols[name][rows] = cols[name]      # host mirror first
            vals[i] = cols[name]
        vals[_VALID_COL] = 1.0               # pure updates: rows stay valid
        if self._plane_reports:
            vals[_ORD_COL] = group.ord[rows]  # paths unchanged: ranks stay
        if self._plane_cube:
            from .profiles import (_FLIP_EDGES, age_buckets_np,
                                   size_buckets_np)
            ngid = self._cube_groups.get_or_add_many(
                cols["owner"], cols["group"], cols["type"],
                cols["hsm_state"])
            nsb = size_buckets_np(np.asarray(cols["size"], np.int64))
            stamps = np.asarray(cols["atime"], np.float64)
            nab = age_buckets_np(self._cube_ref - stamps)
            nflip = stamps + _FLIP_EDGES[nab]
            group.cgid[rows] = ngid
            group.csb[rows] = nsb
            group.cab[rows] = nab
            group.cflip[rows] = nflip
            finite = np.isfinite(nflip)
            if finite.any():
                group.cmin_flip = min(group.cmin_flip,
                                      float(nflip[finite].min()))
            vals[_GID_COL] = ngid
            vals[_SB_COL] = nsb
            vals[_AB_COL] = nab
        # release the assembled global BEFORE the scatter: it holds the
        # only other reference to the block, which must drop for the
        # donated in-place update to actually donate
        self._global = None
        # the scatter runs on the block's own device (donated buffer); the
        # validity row is re-asserted to 1 (pure updates never change
        # which rows exist) and the op is bucket-padded for executable
        # reuse
        prows, pvals = _pad_bucket(rows.astype(np.int32), vals)
        self._bufs[group.gid] = _scatter_rows(self._bufs[group.gid],
                                              prows, pvals)
        if self._plane_cube and cube_live:
            if len(self._cube_groups) > self._cube_bp:
                # a delta minted more groups than the partials can hold:
                # full cube rebuild on the next query
                self._cube_stale = True
                self._cube_cache = None
            else:
                ogid, osb, oab, osize, oblocks = old_cells
                from .profiles import A as _A, S as _S
                flat = np.concatenate([
                    (ogid * _S + osb) * _A + oab,
                    (ngid * _S + nsb) * _A + nab]).astype(np.int32)
                ones = np.ones(dirty.size, np.float32)
                cvals = np.stack([
                    np.concatenate([-ones, ones]),
                    np.concatenate([-osize,
                                    np.asarray(cols["size"], np.float32)]),
                    np.concatenate([-oblocks,
                                    np.asarray(cols["blocks"],
                                               np.float32)])])
                # drop the assembled partials (same donation discipline
                # as the column global above)
                self._cube_partials = None
                self._cube_cache = None
                pflat, pcvals = _pad_zero(flat, cvals)
                self._cube_bufs[group.gid] = _cube_scatter(
                    self._cube_bufs[group.gid], pflat, pcvals)
        if self._plane_perm:
            perm_live = (group.vis is not None
                         and self._perm_bufs is not None
                         and self._perm_bufs[group.gid] is not None
                         and self._grants.version == self._grants_version)
            if perm_live:
                # pure updates keep row positions and paths, so only the
                # ownership grants of the dirty rows can flip: re-derive
                # just those rows' visibility and scatter the changed
                # packed words (scatter-SET, idempotent under dup pad)
                nvis = self._vis_rows(
                    group.spaths, np.asarray(cols["owner"], np.int64),
                    np.asarray(cols["group"], np.int64), group.ord[rows])
                if not np.array_equal(nvis, group.vis[:, rows]):
                    group.vis[:, rows] = nvis
                    words = np.unique(rows // 32)
                    wvals = self._pack_words(group, words)
                    self._perm_global = None
                    pw, pv = _pad_bucket(words.astype(np.int32), wvals)
                    self._perm_bufs[group.gid] = _scatter_rows(
                        self._perm_bufs[group.gid], pw, pv)
                    self.perm_word_scatters += 1
            else:
                # grants ticked (or the bitset never materialized): a
                # row-granular patch could miss a new subject's row —
                # drop the group's bitset, rebuilt on the next scoped
                # query by _ensure_perms
                group.vis = None
        group.versions = versions
        self._epoch += 1
        self.delta_refreshes += 1
        self.rows_scattered += int(dirty.size)
        self._bytes_moved("scatter", vals.nbytes)
        return True

    def _bytes_moved(self, mode: str, nbytes: int) -> None:
        self.telemetry.counter(
            "store_bytes_moved", help="host->device bytes shipped",
            mode=mode, **self._tlabels).inc(int(nbytes))

    def _round_up(self, n: int) -> int:
        return -(-max(n, 1) // self.tile) * self.tile

    def _group_count(self, group: _ShardGroup) -> int:
        return sum(self.catalog.shards[s].count() for s in group.shard_ids)

    def _pad_resident(self) -> int:
        """Widen every clean resident block to the current ``self._rp``
        on-device (zero pad columns, donated) instead of re-uploading it
        — only the grown group pays a full upload. Groups already headed
        for a full upload (structural / never uploaded) skip the pad.
        Returns the number of blocks padded. Lock held."""
        padded = 0
        for group in self._groups:
            buf = self._bufs[group.gid]
            if (not group.resident or buf is None or not group.uploaded
                    or group.structural):
                continue
            cur = int(buf.shape[2])
            if cur >= self._rp:
                continue
            # drop the assembled global first: it holds the only other
            # reference to the block, which must go for donation
            self._global = None
            self._bufs[group.gid] = _pad_block(buf, self._rp - cur)
            if self._plane_perm:
                # word capacity changed: repack from the kept vis mirror
                if self._perm_bufs is not None:
                    self._perm_bufs[group.gid] = None
                self._perm_global = None
            padded += 1
            self.device_pads += 1
        if padded:
            self._epoch += 1
        return padded

    def refresh(self) -> Dict[str, int]:
        """Bring every stale shard group up to date; returns counters of
        the refresh modes taken: ``full``/``delta``/``fresh`` resident
        groups, plus ``padded`` blocks widened on-device by a grown
        sibling. Placement (demote/promote under ``hbm_budget_rows``) and
        warm-segment freshness run first, so after a refresh both the
        resident blocks and the warm segments reflect the catalog."""
        with self.telemetry.trace("store.refresh", **self._tlabels) as _sp:
            stats = self._refresh_locked()
            _sp.annotate(**stats)
            return stats

    def _refresh_locked(self) -> Dict[str, int]:
        with self._lock:
            self._reap_demote_workers()
            self._placement_pass()
            self._ensure_segments()
            stats = {"full": 0, "delta": 0, "fresh": 0, "padded": 0}
            resident = [g for g in self._groups if g.resident]
            stale = [g for g in resident if self._stale(g)]
            stats["fresh"] = len(resident) - len(stale)
            if not stale:
                return stats
            # a grown group re-pads the mesh capacity, but siblings keep
            # their blocks: clean groups widen on-device (_pad_resident),
            # only the grown group re-uploads
            need = max((self._group_count(g) for g in resident), default=1)
            if need > self._rp or self._rp == 0:
                self._rp = self._round_up(int(need * self.headroom))
            stats["padded"] += self._pad_resident()
            # bounded retry: a concurrent insert can outgrow the capacity
            # check above (_stage_upload raises _RepadNeeded) — re-pad and
            # retry the still-stale groups, never serve a truncated block
            for _attempt in range(8):
                try:
                    for group in stale:
                        if not self._stale(group):
                            continue        # settled on a prior attempt
                        churn_ok = (group.uploaded and not group.structural
                                    and group.dirty
                                    and len(group.dirty)
                                    <= self.refresh_frac
                                    * max(1, group.rows))
                        if churn_ok and self._delta_refresh(group):
                            stats["delta"] += 1
                        elif self._mirror_fresh(group):
                            # fresh mirrors, no block (promotion from a
                            # warm segment): stage without re-snapshotting
                            self._stage_upload(group, self._rp)
                            stats["full"] += 1
                        else:
                            self._full_upload(group, self._rp)
                            stats["full"] += 1
                    return stats
                except _RepadNeeded as grown:
                    self._rp = self._round_up(
                        int(grown.rows * self.headroom))
                    stats["padded"] += self._pad_resident()
            raise PolicyError(
                "device store could not settle a refresh: the catalog "
                "grew on every re-pad attempt")

    # -- tiered residency: placement, packing, promotion -----------------------
    def _window_rows(self) -> int:
        """Per-device rows of the streaming window (tile multiple). Under
        a budget the double-buffered window (2 host staging + the live
        device batch) must fit the reserve, so the default 32-tile window
        shrinks to budget/(2*D) when the budget is tighter."""
        if not self._rw:
            rw = 32 * self.tile
            if self.hbm_budget_rows:
                cap = max(self.hbm_budget_rows // (2 * self.n_devices), 1)
                rw = min(rw, cap)
            self._rw = max((rw // self.tile) * self.tile, self.tile)
        return self._rw

    def _hot_fraction(self, group: _ShardGroup) -> float:
        """Volume fraction of the group's young age buckets — the
        ProfileCube side of the placement signal (recently-accessed data
        predicts upcoming policy work). Served from the resident cube
        mirrors or the demoted group's frozen partial; 0 when the cube
        plane is off."""
        if not self._plane_cube:
            return 0.0
        from .profiles import HOT_AGE_BUCKETS, hot_volume_fraction
        if group.resident and group.cab is not None and group.rows:
            return hot_volume_fraction(
                group.cab, np.asarray(group.cols["size"], np.float64))
        if group.frozen_cube is not None:
            vol_ab = group.frozen_cube[1].sum(axis=(0, 1)).astype(np.float64)
            total = float(vol_ab.sum())
            if total <= 0.0:
                return 0.0
            return float(vol_ab[:HOT_AGE_BUCKETS].sum()) / total
        return 0.0

    def _placement_pass(self) -> None:
        """Decide the resident set under ``hbm_budget_rows``: groups rank
        by decayed churn heat, then cube hot-volume fraction (residents
        win exact ties — hysteresis), and the largest prefix whose padded
        blocks + window reserve fit the budget stays resident. Quiet
        groups demote to packed segments; hot-again groups promote.
        Lock held (start of refresh)."""
        budget = self.hbm_budget_rows
        if budget is None:
            for group in self._groups:
                if not group.resident:
                    self._promote(group)
            return
        for group in self._groups:
            group.heat = 0.5 * group.heat + group.churn
            group.churn = 0
        order = sorted(self._groups,
                       key=lambda g: (-g.heat, -self._hot_fraction(g),
                                      0 if g.resident else 1, g.gid))
        rw = self._window_rows()
        m = len(order)
        while m > 0:
            need = max((self._group_count(g) for g in order[:m]),
                       default=1)
            rp = self._round_up(int(need * self.headroom))
            reserve = 0 if m == len(order) else 2 * self.n_devices * rw
            if m * rp + reserve <= budget:
                break
            m -= 1
        desired = {g.gid for g in order[:m]}
        for group in self._groups:
            if group.resident and group.gid not in desired \
                    and not group.pending_demote:
                self._demote(group)
        for group in self._groups:
            if not group.resident and group.gid in desired:
                self._promote(group)
            elif group.resident and group.gid in desired:
                group.pending_demote = False   # placement changed its mind

    def _seg_fresh(self, group: _ShardGroup) -> bool:
        """True when the group's packed segment still matches the catalog
        and carries every enabled plane's columns. Lock held."""
        seg = group.segment
        if seg is None or group.dirty or group.structural:
            return False
        if self._plane_reports and "ord" not in seg.names:
            return False
        if self._plane_cube and "cgid" not in seg.names:
            return False
        return self._shard_versions(group) == group.versions

    def _ensure_segments(self) -> None:
        """Re-encode any demoted group whose segment went stale (churn on
        warm data): snapshot, repack, refreeze its cube partial. The
        churn counters feeding :meth:`_placement_pass` promote a group
        that keeps doing this. Lock held."""
        for group in self._groups:
            if group.resident or self._seg_fresh(group):
                continue
            self._commit_demote(group, self._pack_segment(group),
                                repack=True)

    def _pack_segment(self, group: _ShardGroup) -> PackedSegment:
        """Encode the group's column stack into a PackedSegment (host
        mirrors refreshed first if stale), persisted as an mmap-able
        ``.npz`` beside the sqlite mirror when the catalog has one.
        Lock held."""
        if not self._mirror_fresh(group):
            self._host_refresh(group)
        cols: Dict[str, np.ndarray] = {
            n: np.asarray(group.cols[n]) for n in PLAN_COLUMNS}
        if self._plane_reports:
            cols["path"] = np.asarray(group.paths if group.paths is not None
                                      else [], dtype="<U1" if not group.rows
                                      else None)
            cols["ord"] = group.ord
        if self._plane_cube:
            cols["cgid"] = group.cgid
            cols["csb"] = group.csb
        seg = PackedSegment.pack(
            cols, meta={"gid": group.gid, "rows": group.rows,
                        "versions": {str(k): int(v)
                                     for k, v in group.versions.items()}})
        path = self.catalog.sidecar_path(f"seg{group.gid}.npz")
        if path:
            seg.save(path)
            seg = PackedSegment.load(path, mmap=True)
        return seg

    def _freeze_cube(self, group: _ShardGroup) -> None:
        """Capture the demoted group's exact int64 partial cube at the
        current ``_cube_ref`` (host bincount over the cube mirrors) so
        unscoped profile queries never stream: merged cube = resident
        psum + frozen partials. Stale once an age flip passes
        ``frozen_min_flip`` (then :meth:`_refreeze` recomputes from the
        segment). Lock held, mirrors fresh."""
        from .profiles import A as _A, S as _S, _bincount_i64
        b = max(len(self._cube_groups), 1)
        k = b * _S * _A
        flat = ((group.cgid * _S + group.csb) * _A
                + group.cab).astype(np.int64)
        counts = np.bincount(flat, minlength=k)
        sizes = np.asarray(group.cols["size"], np.int64)
        blocks = np.asarray(group.cols["blocks"], np.int64)
        group.frozen_cube = np.stack([
            counts.astype(np.int64),
            _bincount_i64(flat, sizes, k, counts),
            _bincount_i64(flat, blocks, k, counts)]).reshape(3, b, _S, _A)
        group.frozen_min_flip = group.cmin_flip
        group.frozen_ref = self._cube_ref

    def _refreeze(self, group: _ShardGroup, now: float) -> int:
        """Recompute a demoted group's frozen partial cube at ``now``
        (decoding the segment) after an age-bucket flip passed. Returns
        the number of rows that moved buckets. Lock held."""
        from .profiles import (_FLIP_EDGES, A as _A, S as _S,
                               _bincount_i64, age_buckets_np)
        dec = group.segment.columns()
        stamps = np.asarray(dec["atime"], np.float64)
        old_ab = age_buckets_np(group.frozen_ref - stamps)
        new_ab = age_buckets_np(now - stamps)
        cgid = np.asarray(dec["cgid"], np.int64)
        csb = np.asarray(dec["csb"], np.int64)
        b = max(len(self._cube_groups), 1)
        k = b * _S * _A
        flat = ((cgid * _S + csb) * _A + new_ab).astype(np.int64)
        counts = np.bincount(flat, minlength=k)
        group.frozen_cube = np.stack([
            counts.astype(np.int64),
            _bincount_i64(flat, np.asarray(dec["size"], np.int64), k,
                          counts),
            _bincount_i64(flat, np.asarray(dec["blocks"], np.int64), k,
                          counts)]).reshape(3, b, _S, _A)
        flips = stamps + _FLIP_EDGES[new_ab]
        finite = np.isfinite(flips)
        group.frozen_min_flip = float(flips[finite].min()) \
            if finite.any() else np.inf
        group.frozen_ref = now
        group.sstack_ref = np.nan           # AB row of the stack is stale
        return int((new_ab != old_ab).sum())

    def _frozen_total(self) -> np.ndarray:
        """Sum of every demoted group's frozen partial, padded to the
        current ``_cube_bp`` group capacity. Lock held."""
        from .profiles import A as _A, S as _S
        out = np.zeros((3, self._cube_bp, _S, _A), np.int64)
        for group in self._groups:
            fz = group.frozen_cube
            if group.resident or fz is None:
                continue
            out[:, : fz.shape[1]] += fz
        return out

    def _commit_demote(self, group: _ShardGroup, seg: PackedSegment,
                       repack: bool = False) -> None:
        """Install a packed segment and free the group's device buffers
        and host mirrors. Lock held."""
        group.segment = seg
        group.sstack = group.svis = group.sspaths = None
        group.sstack_ref = np.nan
        group.svis_ver = -1
        if self._plane_cube:
            self._freeze_cube(group)
        group.resident = False
        group.uploaded = False
        group.pending_demote = False
        self._bufs[group.gid] = None        # device buffers freed (donated
        self._global = None                 # assemblies dropped with them)
        if self._perm_bufs is not None:
            self._perm_bufs[group.gid] = None
        self._perm_global = None
        if self._cube_bufs is not None:
            self._cube_bufs[group.gid] = None
        self._cube_partials = None
        self._cube_cache = None
        # host mirrors dropped: the packed segment IS the warm copy
        group.fids = np.zeros(0, np.int64)
        group.cols = {}
        group._order = None
        group.paths = group.spaths = group.ord = None
        group.cgid = group.csb = group.cab = group.cflip = None
        group.cmin_flip = np.inf
        group.vis = None
        # deliberately NOT an epoch bump: the commit is content-preserving
        # (version-revalidated against the catalog), and in-flight
        # MeshMatch handles hold their own mirror-array references — an
        # async commit landing between match() and plan() must not stale
        # them
        if repack:
            self.segment_repacks += 1
        else:
            self.demotions += 1

    def _demote(self, group: _ShardGroup) -> None:
        """Demote a resident group to a packed warm segment. With
        ``demote_async`` the encode runs on a worker thread against its
        own catalog snapshot (the group keeps serving resident); the
        commit re-validates versions under the lock and discards the pack
        if the group churned meanwhile. Lock held."""
        if not self.demote_async:
            self._commit_demote(group, self._pack_segment(group))
            return
        group.pending_demote = True
        versions = self._shard_versions(group)

        def worker() -> None:
            shadow = _ShardGroup(group.gid, group.shard_ids)
            with self._lock:
                if not (group.pending_demote and group.resident):
                    return
            seg_versions = self._shard_versions(group)
            shadow.versions = seg_versions
            # snapshot + encode WITHOUT the store lock (queries keep
            # serving the still-resident blocks meanwhile)
            self._host_refresh(shadow)
            shadow.resident = group.resident
            seg = self._pack_segment_from(shadow)
            with self._lock:
                if (group.pending_demote and group.resident
                        and not group.dirty and not group.structural
                        and self._shard_versions(group) == shadow.versions):
                    # adopt the shadow's fresh mirrors so _freeze_cube
                    # inside the commit reads consistent state
                    for slot in ("fids", "cols", "rows", "versions",
                                 "offsets", "paths", "spaths", "ord",
                                 "cgid", "csb", "cab", "cflip",
                                 "cmin_flip"):
                        setattr(group, slot, getattr(shadow, slot))
                    self._commit_demote(group, seg)
                else:
                    group.pending_demote = False
                    self.demote_races += 1

        t = threading.Thread(target=worker, daemon=True)
        self._demote_workers.append(t)
        t.start()

    def _pack_segment_from(self, shadow: _ShardGroup) -> PackedSegment:
        """Encode from an already-fresh shadow mirror (async demote path:
        no store lock needed — the shadow is thread-private)."""
        cols: Dict[str, np.ndarray] = {
            n: np.asarray(shadow.cols[n]) for n in PLAN_COLUMNS}
        if self._plane_reports:
            cols["path"] = np.asarray(
                shadow.paths if shadow.paths is not None else [],
                dtype="<U1" if not shadow.rows else None)
            cols["ord"] = shadow.ord
        if self._plane_cube:
            cols["cgid"] = shadow.cgid
            cols["csb"] = shadow.csb
        seg = PackedSegment.pack(
            cols, meta={"gid": shadow.gid, "rows": shadow.rows,
                        "versions": {str(k): int(v)
                                     for k, v in shadow.versions.items()}})
        path = self.catalog.sidecar_path(f"seg{shadow.gid}.npz")
        if path:
            seg.save(path)
            seg = PackedSegment.load(path, mmap=True)
        return seg

    def _reap_demote_workers(self) -> None:
        self._demote_workers = [t for t in self._demote_workers
                                if t.is_alive()]

    def drain_demotions(self, timeout: Optional[float] = None) -> None:
        """Join any in-flight async demotions (tests / shutdown). Must be
        called WITHOUT the store lock held."""
        for t in list(self._demote_workers):
            t.join(timeout)
        with self._lock:
            self._reap_demote_workers()

    def _promote(self, group: _ShardGroup) -> None:
        """Bring a demoted group back resident: decode the segment into
        host mirrors (exact round-trip — no catalog re-read when the
        segment is fresh) and let the refresh loop stage the block.
        Lock held."""
        seg = group.segment
        if seg is not None and self._seg_fresh(group):
            dec = seg.columns()
            group.fids = np.asarray(dec["fid"], np.int64)
            # mirrors must be writable (delta refresh patches in place);
            # decoded arrays may be read-only mmap views, so copy
            group.cols = {n: np.array(dec[n]) for n in PLAN_COLUMNS}
            group.rows = int(group.fids.size)
            group._order = None
            if self._plane_reports:
                parr = np.asarray(dec["path"])
                group.paths = parr.tolist()
                group.ord = np.asarray(dec["ord"], np.int64)
                sp = np.empty_like(parr)
                sp[group.ord] = parr
                group.spaths = sp
            if self._plane_cube:
                from .profiles import _FLIP_EDGES, age_buckets_np
                group.cgid = np.asarray(dec["cgid"], np.int64)
                group.csb = np.asarray(dec["csb"], np.int64)
                stamps = np.asarray(dec["atime"], np.float64)
                group.cab = age_buckets_np(self._cube_ref - stamps)
                group.cflip = stamps + _FLIP_EDGES[group.cab]
                finite = np.isfinite(group.cflip)
                group.cmin_flip = float(group.cflip[finite].min()) \
                    if finite.any() else np.inf
        # else: stale/absent segment — mirrors stay empty and the refresh
        # loop takes the full snapshot+upload path
        group.segment = None
        group.sstack = group.svis = group.sspaths = None
        group.frozen_cube = None
        group.frozen_min_flip = np.inf
        group.resident = True
        group.uploaded = False
        group.pending_demote = False
        if self._plane_cube:
            self._cube_stale = True         # its partial must rebuild
            self._cube_cache = None
        self._epoch += 1
        self.promotions += 1

    def tiering_counters(self) -> Dict[str, int]:
        """Snapshot of the tiering observability counters (surfaced per
        run in :attr:`RunReport.tiering`, asserted by ``bench_tiering``)."""
        with self._lock:
            return {
                "demotions": self.demotions,
                "promotions": self.promotions,
                "segments_streamed": self.segments_streamed,
                "windows_streamed": self.windows_streamed,
                "window_stalls": self.window_stalls,
                "segment_repacks": self.segment_repacks,
                "demote_races": self.demote_races,
                "device_pads": self.device_pads,
                "resident_groups": sum(g.resident for g in self._groups),
                "demoted_groups": sum(not g.resident
                                      for g in self._groups),
            }

    # -- warm-segment streaming ------------------------------------------------
    def _segment_stack(self, group: _ShardGroup) -> np.ndarray:
        """(block_rows, n) f32 staging stack decoded from the group's
        warm segment — the streaming analogue of :meth:`_stack_f32`,
        cached on the group until the segment repacks. The age-bucket row
        re-derives (from the exact float64 stamps) whenever the cube
        reference moved, so streamed windows carry the same AB codes the
        resident blocks do. Lock held."""
        dec = group.segment.columns()
        if group.sstack is None:
            n = int(group.segment.n_rows)
            out = np.zeros((self._block_rows(), n), np.float32)
            for i, name in enumerate(KERNEL_COLUMNS):
                out[i] = dec[name]
            out[_VALID_COL] = 1.0
            if self._plane_reports:
                out[_ORD_COL] = dec["ord"]
            if self._plane_cube:
                out[_GID_COL] = dec["cgid"]
                out[_SB_COL] = dec["csb"]
            group.sstack = out
            group.sstack_ref = np.nan       # AB row filled below
        if self._plane_cube and group.sstack_ref != self._cube_ref:
            from .profiles import age_buckets_np
            stamps = np.asarray(dec["atime"], np.float64)
            group.sstack[_AB_COL] = age_buckets_np(self._cube_ref - stamps)
            group.sstack_ref = self._cube_ref
        return group.sstack

    def _segment_spaths(self, group: _ShardGroup) -> np.ndarray:
        """Sorted path mirror of a demoted group (du rank bounds, subtree
        grants) — decoded once per segment."""
        if group.sspaths is None:
            group.sspaths = np.sort(
                np.asarray(group.segment.decode("path")), kind="stable")
        return group.sspaths

    def _segment_vis(self, group: _ShardGroup) -> np.ndarray:
        """(Sp, n) bool subject visibility over a demoted group's rows,
        cached per grants version — the host source the streamed
        permission windows pack from. Lock held, after
        :meth:`_ensure_perms` (sizes ``_perm_sp``)."""
        if (group.svis is not None
                and group.svis_ver == self._grants.version
                and group.svis.shape[0] == self._perm_sp):
            return group.svis
        dec = group.segment.columns()
        group.svis = self._vis_rows(
            self._segment_spaths(group),
            np.asarray(dec["owner"], np.int64),
            np.asarray(dec["group"], np.int64),
            np.asarray(dec["ord"], np.int64))
        group.svis_ver = self._grants.version
        return group.svis

    def _perm_window(self, vis: np.ndarray, base: int,
                     nrows: int, rw: int):
        """Pack one chunk of a demoted group's visibility into the
        (D, Sp, Rw/32) uint32 window layout (rows past ``nrows`` pack to
        0 — invisible, like the validity row)."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        D = self.n_devices
        sub = np.zeros((self._perm_sp, D * rw), dtype=bool)
        sub[:, :nrows] = vis[:, base:base + nrows]
        words = np.packbits(
            sub.reshape(self._perm_sp, D, rw).transpose(1, 0, 2),
            axis=2, bitorder="little").view(np.uint32)
        return jax.make_array_from_single_device_arrays(
            (D, self._perm_sp, rw // 32),
            NamedSharding(self.mesh, P("shards")),
            [jax.device_put(words[d:d + 1], dev)
             for d, dev in enumerate(self.devices)])

    def _stream_windows(self, group: _ShardGroup, launch, want_perm: bool):
        """Drive one demoted group's packed segment through the
        double-buffered streaming window.

        The segment decodes into the cached f32 row stack, which walks
        the FULL mesh in (D·Rw)-row chunks — device ``d`` of the chunk at
        ``base`` holds group-local rows ``[base+d·Rw, base+(d+1)·Rw)``.
        Chunk k+1 stages into the alternate host buffer and dispatches
        while chunk k's launch is still computing (async dispatch
        overlaps the host→device copy with the compute); results are
        consumed one batch behind, so a staging buffer is never rewritten
        before its transfer completed. ``launch(window, perm_window)``
        returns jax array(s); yields ``(base, nrows, result)`` in row
        order. Lock held for the whole sweep (same discipline as match).
        """
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        D = self.n_devices
        rw = self._window_rows()
        chunk = D * rw
        stack = self._segment_stack(group)
        n = stack.shape[1]
        if not n:
            return
        br = self._block_rows()
        vis = self._segment_vis(group) if want_perm else None
        sharding = NamedSharding(self.mesh, P("shards"))
        staging = (np.zeros((D, br, rw), np.float32),
                   np.zeros((D, br, rw), np.float32))
        pending = None
        self.segments_streamed += 1
        for k, base in enumerate(range(0, n, chunk)):
            nrows = min(chunk, n - base)
            buf = staging[k % 2]
            if nrows == chunk:
                buf[:] = stack[:, base:base + chunk].reshape(
                    br, D, rw).transpose(1, 0, 2)
            else:                           # final partial chunk
                buf.fill(0.0)               # pad rows read valid=0
                for d in range(D):
                    lo = base + d * rw
                    cnt = min(max(n - lo, 0), rw)
                    if cnt:
                        buf[d, :, :cnt] = stack[:, lo:lo + cnt]
            win = jax.make_array_from_single_device_arrays(
                (D, br, rw), sharding,
                [jax.device_put(buf[d:d + 1], dev)
                 for d, dev in enumerate(self.devices)])
            pwin = self._perm_window(vis, base, nrows, rw) \
                if want_perm else None
            res = launch(win, pwin)
            self.windows_streamed += 1
            self._bytes_moved("window", buf.nbytes)
            if pending is not None:
                yield self._consume_window(pending)
            pending = (base, nrows, res)
        if pending is not None:
            yield self._consume_window(pending)

    def _consume_window(self, pending):
        import time as _time
        base, nrows, res = pending
        first = res[0] if isinstance(res, tuple) else res
        ready = getattr(first, "is_ready", None)
        if ready is not None and not ready():
            # the overlapped copy did not hide this batch's compute: the
            # consumer blocks on device_get (bench watches this counter);
            # the wait is timed explicitly so the stall shows up in the
            # telemetry export, not just as a count
            self.window_stalls += 1
            import jax
            t0 = _time.perf_counter()
            jax.block_until_ready(first)
            self.telemetry.histogram(
                "store_window_stall_seconds",
                help="streaming-window consume blocked on compute",
                **self._tlabels).observe(_time.perf_counter() - t0)
        return base, nrows, res

    def _group_paths(self, group: _ShardGroup):
        """Row-aligned paths: the host mirror list for a resident group,
        the cached segment decode for a demoted one."""
        if group.resident:
            return group.paths
        return group.segment.decode("path")

    def _group_arrays(self, group: _ShardGroup):
        """(fids, columns, row-aligned paths) for result gathering —
        host mirrors resident, cached segment decode demoted."""
        if group.resident:
            return group.fids, group.cols, group.paths
        dec = group.segment.columns()
        return np.asarray(dec["fid"], np.int64), dec, dec.get("path")

    # -- permissions plane (per-subject packed visibility bitsets) -------------
    def _require_permissions_plane(self) -> None:
        if not self._plane_perm:
            raise PolicyError(
                "permissions plane not enabled "
                "(DeviceColumnStore.enable_permissions_plane)")

    def _subject_id(self, subject: str) -> int:
        # unknown subjects raise KeyError, NOT PolicyError: a host
        # fallback would fail identically, so degrading serves nothing
        return int(self._grants.subject_id(subject))

    def _vis_rows(self, spaths: Optional[np.ndarray], owner: np.ndarray,
                  grp: np.ndarray, rank: np.ndarray) -> np.ndarray:
        """(Sp, k) bool visibility of k group rows (given the group's
        sorted path mirror, the rows' interned owner/group codes and
        sorted-path ranks) for every registered subject — rows past the
        registry stay all-False pad. Mirrors
        :meth:`GrantTable.visible_mask` exactly: ownership via code
        membership, subtrees via the same rank-range searches ``du``
        uses on the sorted-path mirror. Lock held."""
        strings = self.catalog.strings
        subjects = self._grants.subjects()
        out = np.zeros((self._perm_sp, owner.size), dtype=bool)
        sp = spaths if spaths is not None else np.zeros(0, dtype="<U1")
        for sid, s in enumerate(subjects):
            v = out[sid]
            ocodes = [c for c in (strings.code_of(u) for u in s.owners)
                      if c is not None]
            if ocodes:
                v |= np.isin(owner, ocodes)
            gcodes = [c for c in (strings.code_of(g) for g in s.groups)
                      if c is not None]
            if gcodes:
                v |= np.isin(grp, gcodes)
            for pref in s.subtrees:
                lo = np.searchsorted(sp, pref + "/", side="left")
                hi = np.searchsorted(sp, pref + "0", side="left")
                lo2 = np.searchsorted(sp, pref, side="left")
                hi2 = np.searchsorted(sp, pref, side="right")
                v |= ((rank >= lo) & (rank < hi)) \
                    | ((rank >= lo2) & (rank < hi2))
        return out

    def _pack_group(self, group: _ShardGroup) -> np.ndarray:
        """Pack a group's full (Sp, rows) visibility into the (Sp, Rp/32)
        uint32 bit layout: bit b of word w (LSB first) = local row
        w*32+b; pad rows read 0 (invisible, like the validity row)."""
        full = np.zeros((self._perm_sp, self._rp), dtype=bool)
        if group.rows:
            full[:, : group.rows] = group.vis
        return np.packbits(full, axis=1,
                           bitorder="little").view(np.uint32)

    def _pack_words(self, group: _ShardGroup,
                    words: np.ndarray) -> np.ndarray:
        """(Sp, k) packed uint32 values of k whole words re-read from the
        group's visibility mirror (rows past ``group.rows`` pack to 0) —
        the warm-scatter payload after a dirty-row visibility change."""
        rows = (words[:, None] * 32 + np.arange(32)).reshape(-1)
        sub = np.zeros((self._perm_sp, rows.size), dtype=bool)
        inside = rows < group.rows
        sub[:, inside] = group.vis[:, rows[inside]]
        return np.packbits(sub, axis=1, bitorder="little").view(np.uint32)

    def _ensure_perms(self) -> None:
        """Materialize / refresh the resident bitsets. Lock held; call
        AFTER :meth:`refresh` (full uploads invalidate group bitsets).
        Any :attr:`GrantTable.version` tick or subject-capacity overflow
        re-materializes every group; otherwise only groups whose bitset
        was invalidated (structural churn, re-pad) rebuild."""
        import jax
        g = self._grants
        if (g.version != self._grants_version or self._perm_bufs is None
                or len(g) > self._perm_sp):
            # subject axis padded like the group axis of the cube plane:
            # headroom + sublane multiple, so new subjects keep landing
            # without an immediate re-materialization
            self._perm_sp = max(
                -(-int(max(len(g), 1) * self.headroom) // 8) * 8, 8)
            self._grants_version = g.version
            self._perm_bufs = [None] * self.n_devices
            self._perm_global = None
            for group in self._groups:
                group.vis = None
                group.svis = None          # streaming bitsets stale too
                group.svis_ver = -1
        changed = False
        for group in self._groups:
            if not group.resident:         # demoted: _segment_vis on demand
                continue
            if group.vis is not None \
                    and self._perm_bufs[group.gid] is not None:
                continue
            if group.rows:
                owner = np.asarray(group.cols["owner"], np.int64)
                grp = np.asarray(group.cols["group"], np.int64)
                rank = group.ord
            else:
                owner = grp = np.zeros(0, np.int64)
                rank = np.zeros(0, np.int64)
            group.vis = self._vis_rows(group.spaths, owner, grp, rank)
            self._perm_bufs[group.gid] = jax.device_put(
                self._pack_group(group)[None], self.devices[group.gid])
            self.perm_materializations += 1
            changed = True
        if changed:
            self._perm_global = None
            self._epoch += 1

    def _assemble_perm(self, res: List[_ShardGroup], mesh):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self._perm_global is None:
            shape = (len(res), self._perm_sp, self._rp // 32)
            self._perm_global = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(mesh, P("shards")),
                [self._perm_bufs[g.gid] for g in res])
        return self._perm_global

    def _resolve_subject(self, subject: Optional[str]):
        """Traced subject id for a scoped query (None unscoped),
        materializing the resident bitsets. Lock held, AFTER refresh()."""
        if subject is None:
            return None
        self._require_permissions_plane()
        self._ensure_perms()
        return np.int32(self._subject_id(subject))

    # -- resident sub-mesh assembly --------------------------------------------
    def _resident(self) -> List[_ShardGroup]:
        """Resident groups in gid order — the device order of every
        assembled global array (and of its result shards)."""
        return [g for g in self._groups if g.resident]

    def _demoted(self) -> List[_ShardGroup]:
        return [g for g in self._groups if not g.resident]

    def _resident_mesh(self, res: List[_ShardGroup]):
        """1-D ``("shards",)`` mesh over the resident groups' devices.
        The full store mesh when everything is resident (compile caches
        and pre-tiering behavior stay byte-identical); otherwise a cached
        sub-mesh — mesh identity is a static jit arg, so each resident
        set compiles its collectives once."""
        if len(res) == self.n_devices:
            return self.mesh
        from jax.sharding import Mesh
        gids = tuple(g.gid for g in res)
        mesh = self._submeshes.get(gids)
        if mesh is None:
            mesh = Mesh(np.asarray([self.devices[g] for g in gids]),
                        ("shards",))
            self._submeshes[gids] = mesh
        return mesh

    def _assemble(self, res: List[_ShardGroup], mesh):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self._global is None:
            shape = (len(res), self._block_rows(), self._rp)
            self._global = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(mesh, P("shards")),
                [self._bufs[g.gid] for g in res])
        return self._global

    def match(self, exprs: Sequence, now: float,
              use_kernel: Optional[bool] = None,
              with_agg: bool = True,
              subject: Optional[str] = None) -> MeshMatch:
        """Evaluate ``[combined criteria] + per-rule conditions`` over the
        resident mesh; see :class:`MeshMatch`. Raises PolicyError on glob
        (host-only) predicates — callers fall back to the numpy path.
        ``with_agg=False`` skips the fused size-profile aggregation (the
        engine's match path needs only mask + attribution; ``.agg`` then
        reads all-zero). ``subject=`` ANDs that subject's permission
        bitset into the match (permissions plane required)."""
        # the lock is held for the WHOLE match (launch included): a
        # concurrent refresh would donate the resident blocks out from
        # under the in-flight launch and mutate the host mirrors this
        # match translates through — concurrent matches serialize instead
        with self._lock, \
                self.telemetry.trace("store.match", **self._tlabels) as _sp:
            m = self._match_locked(exprs, now, use_kernel, with_agg,
                                   subject)
            _sp.annotate(rows_revaluated=m.reval,
                         scoped=subject is not None)
            return m

    def _match_locked(self, exprs: Sequence, now: float,
                      use_kernel: Optional[bool] = None,
                      with_agg: bool = True,
                      subject: Optional[str] = None) -> MeshMatch:
        import jax
        from ..kernels.policy_scan.ops import (_agg_dict,
                                               merge_agg_partials, _on_tpu,
                                               _program_tuples,
                                               mesh_policy_scan_batch)
        ops, colidx, operands = compile_programs(exprs, self.catalog.strings,
                                                 now)
        ops_t, colidx_t = _program_tuples(ops, colidx)
        if use_kernel is None:
            use_kernel = _on_tpu()
        self.refresh()
        sid = self._resolve_subject(subject)
        kw = dict(ops_t=ops_t, colidx_t=colidx_t,
                  size_col=KERNEL_COLUMNS.index("size"),
                  blocks_col=KERNEL_COLUMNS.index("blocks"),
                  valid_col=_VALID_COL, use_kernel=bool(use_kernel),
                  tile=self.tile, with_agg=with_agg)
        res = self._resident()
        mirrors: List[Tuple[np.ndarray, Dict[str, np.ndarray]]] = \
            [(np.zeros(0, np.int64), {})] * self.n_devices
        group_idx = [np.zeros(0, np.int64)] * self.n_devices
        group_rule = [np.zeros(0, np.int32)] * self.n_devices
        agg_parts = []
        reval = 0
        if res:
            mesh = self._resident_mesh(res)
            perm = self._assemble_perm(res, mesh) if sid is not None \
                else None
            with self.telemetry.trace("store.match.launch",
                                      groups=len(res), **self._tlabels):
                mask, rule, agg = mesh_policy_scan_batch(
                    self._assemble(res, mesh), operands, mesh=mesh,
                    perm=perm, subject=sid, **kw)
            # only mask + attribution cross device→host, never the columns
            with self.telemetry.trace("store.match.combine",
                                      **self._tlabels):
                mask_np = np.asarray(jax.device_get(mask))
                rule_np = np.asarray(jax.device_get(rule))
                agg_parts.append(np.asarray(jax.device_get(agg)))
            for i, g in enumerate(res):
                idx = np.nonzero(mask_np[i, : g.rows] > 0.5)[0]
                mirrors[g.gid] = (g.fids, g.cols)
                group_idx[g.gid] = idx
                group_rule[g.gid] = rule_np[i, idx].astype(np.int32)
                reval += g.rows
        for g in self._demoted():
            def launch(win, pwin):
                return mesh_policy_scan_batch(
                    win, operands, mesh=self.mesh, perm=pwin,
                    subject=sid if pwin is not None else None, **kw)
            idx_parts, rule_parts = [], []
            for base, nrows, (mask, rule, agg) in self._stream_windows(
                    g, launch, want_perm=sid is not None):
                m = np.asarray(jax.device_get(mask)).reshape(-1)[:nrows]
                r = np.asarray(jax.device_get(rule)).reshape(-1)[:nrows]
                hit = np.nonzero(m > 0.5)[0]
                idx_parts.append(base + hit)
                rule_parts.append(r[hit].astype(np.int32))
                if with_agg:
                    agg_parts.append(np.asarray(jax.device_get(agg)))
            dec = g.segment.columns()
            mirrors[g.gid] = (np.asarray(dec["fid"], np.int64),
                              {n: dec[n] for n in PLAN_COLUMNS})
            group_idx[g.gid] = (np.concatenate(idx_parts) if idx_parts
                                else np.zeros(0, np.int64))
            group_rule[g.gid] = (np.concatenate(rule_parts) if rule_parts
                                 else np.zeros(0, np.int32))
            reval += int(g.segment.n_rows)
        per_rule = merge_agg_partials(agg_parts, len(ops_t))
        return MeshMatch(self, self._epoch, mirrors, group_idx,
                         group_rule, _agg_dict(per_rule[0], per_rule),
                         reval)

    def scan(self, expr, now: float,
             use_kernel: Optional[bool] = None) -> Tuple[np.ndarray, dict]:
        """Single-expression mesh scan: (matching fids, aggregate dict) —
        the device-resident analogue of ``ops.scan_catalog``."""
        match = self.match([expr], now, use_kernel=use_kernel)
        fids, _sizes, _sort, _ridx = match.plan("size")
        return fids, match.agg

    # -- resident profile cube -------------------------------------------------
    def _assemble_cube(self, res: List[_ShardGroup], mesh):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..kernels.profile_cube.ref import (A_BUCKETS, N_MEASURES,
                                                S_BUCKETS)
        if self._cube_partials is None:
            shape = (len(res), N_MEASURES,
                     self._cube_bp * S_BUCKETS * A_BUCKETS)
            self._cube_partials = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(mesh, P("shards")),
                [self._cube_bufs[g.gid] for g in res])
        return self._cube_partials

    def _advance_cube_ref(self, now: float,
                          update_partials: bool = True) -> int:
        """Advance the age reference: re-bucket only the rows whose next
        flip instant passed (block ``_AB_COL`` scatter + mirror update;
        when the partials are live, a signed cube move too). Mirrors
        ``core.profiles._ShardCube.sweep``. Lock held."""
        if now <= self._cube_ref:
            return 0
        from .profiles import _FLIP_EDGES, age_buckets_np, A as _A, S as _S
        moved = 0
        for group in self._groups:
            if not group.rows or group.cflip is None \
                    or group.cmin_flip > now:
                continue
            due = np.nonzero(group.cflip <= now)[0]
            if due.size:
                stamps = np.asarray(group.cols["atime"][due], np.float64)
                new_ab = age_buckets_np(now - stamps)
                if update_partials and self._cube_bufs is not None \
                        and not self._cube_stale:
                    gid, sb = group.cgid[due], group.csb[due]
                    flat = np.concatenate([
                        (gid * _S + sb) * _A + group.cab[due],
                        (gid * _S + sb) * _A + new_ab]).astype(np.int32)
                    ones = np.ones(due.size, np.float32)
                    size = np.asarray(group.cols["size"][due], np.float32)
                    blocks = np.asarray(group.cols["blocks"][due],
                                        np.float32)
                    cvals = np.stack([
                        np.concatenate([-ones, ones]),
                        np.concatenate([-size, size]),
                        np.concatenate([-blocks, blocks])])
                    self._cube_partials = None
                    self._cube_cache = None
                    pflat, pcvals = _pad_zero(flat, cvals)
                    self._cube_bufs[group.gid] = _cube_scatter(
                        self._cube_bufs[group.gid], pflat, pcvals)
                group.cab[due] = new_ab
                group.cflip[due] = stamps + _FLIP_EDGES[new_ab]
                # scatter the new age buckets into the resident block so a
                # later full cube rebuild reads current codes
                self._global = None
                prows, pvals = _pad_bucket(
                    due.astype(np.int32),
                    new_ab[None].astype(np.float32))
                self._bufs[group.gid] = _scatter_row(
                    self._bufs[group.gid], _AB_COL, prows, pvals[0])
                moved += int(due.size)
            finite = np.isfinite(group.cflip)
            group.cmin_flip = float(group.cflip[finite].min()) \
                if finite.any() else np.inf
        self._cube_ref = now
        self.rollovers += moved
        return moved

    def _cube_capacity(self) -> int:
        # group-axis capacity: headroom + f32 sublane multiple, so newly
        # minted groups keep scatter-adding without an immediate rebuild
        b = max(len(self._cube_groups), 1)
        return max(-(-int(b * self.headroom) // 8) * 8, 8)

    def _rebuild_cube(self, now: float) -> None:
        """Cold/fallback path: one ``mesh_profile_cube`` launch rebuilds
        every resident device's partial from its block. Lock held; blocks
        must be fresh (call after :meth:`refresh`) and at least one group
        resident."""
        import jax
        from ..kernels.profile_cube.ops import mesh_profile_cube
        self._advance_cube_ref(now, update_partials=False)
        self._cube_bp = self._cube_capacity()
        res = self._resident()
        mesh = self._resident_mesh(res)
        partials, combined = mesh_profile_cube(
            self._assemble(res, mesh), mesh=mesh, n_groups=self._cube_bp,
            gid_col=_GID_COL, size_col=KERNEL_COLUMNS.index("size"),
            blocks_col=KERNEL_COLUMNS.index("blocks"), sb_col=_SB_COL,
            ab_col=_AB_COL, valid_col=_VALID_COL, use_kernel=False,
            tile=self.tile)
        by_dev = {s.device: s.data for s in partials.addressable_shards}
        self._cube_bufs = [by_dev.get(d) for d in self.devices]
        self._cube_partials = partials
        self._cube_cache = np.rint(
            np.asarray(jax.device_get(combined))).astype(np.int64)
        self._cube_stale = False
        self.cube_rebuilds += 1

    def _ensure_cube(self, now: float) -> None:
        if not self._plane_cube:
            raise PolicyError("cube plane not enabled "
                              "(DeviceColumnStore.enable_cube_plane)")
        res = self._resident()
        if res:
            if (self._cube_bufs is None or self._cube_stale
                    or len(self._cube_groups) > self._cube_bp
                    or any(self._cube_bufs[g.gid] is None for g in res)):
                self._rebuild_cube(now)
            else:
                self._advance_cube_ref(now, update_partials=True)
        else:
            # nothing resident: only the frozen partials + streamed
            # windows serve, but the reference still advances so their
            # age buckets stay exact as of ``now``
            if self._cube_bp < len(self._cube_groups) \
                    or self._cube_bp == 0:
                self._cube_bp = self._cube_capacity()
            self._advance_cube_ref(now, update_partials=False)
        # demoted partials whose first scheduled age flip passed refreeze
        # from their segments at the advanced reference
        for g in self._demoted():
            if g.frozen_cube is not None \
                    and g.frozen_min_flip <= self._cube_ref:
                self.rollovers += self._refreeze(g, self._cube_ref)

    def invalidate_cube(self) -> None:
        """Force a full on-device cube rebuild on the next query (the
        store-backed analogue of ``ProfileCube.rebuild``)."""
        with self._lock:
            self._cube_stale = True
            self._cube_cache = None

    def analytics_cube(self, now: Optional[float] = None,
                       subject: Optional[str] = None) -> np.ndarray:
        """Merged (N_MEASURES, B, S, A) int64 cube as of ``now``, served
        from the resident partials: refresh scatters churned rows, due
        age rollovers move on-device, and the only cross-device traffic
        is the psum of the partial cubes. ``subject=`` bins only rows
        that subject may see — one fused :func:`mesh_scoped_cube` launch
        over the resident block + bitsets (no resident scoped partials;
        the rollover advance above keeps the block's age codes exact as
        of ``now``, so the scoped cube matches the host oracle).

        Under tiering, demoted groups contribute without re-residency:
        the unscoped cube adds their exact int64 frozen partials
        (refrozen from the segment when an age flip passed); a scoped
        cube streams their windows through :func:`mesh_scoped_cube` and
        sums the per-window cubes with the resident launch."""
        import jax
        from ..kernels.profile_cube.ops import mesh_cube_combine
        from ..kernels.profile_cube.ref import (A_BUCKETS, N_MEASURES,
                                                S_BUCKETS)
        with self._lock:
            if not self._plane_cube:
                raise PolicyError("cube plane not enabled "
                                  "(DeviceColumnStore.enable_cube_plane)")
            now = float(self._cube_clock()) if now is None else float(now)
            self.refresh()
            self._ensure_cube(now)
            self.store_queries += 1
            res = self._resident()
            demoted = self._demoted()
            b = min(len(self._cube_groups), self._cube_bp)
            if subject is not None:
                from ..kernels.profile_cube.ops import mesh_scoped_cube
                self._require_permissions_plane()
                self._ensure_perms()
                sid = np.int32(self._subject_id(subject))
                kw = dict(n_groups=self._cube_bp, gid_col=_GID_COL,
                          size_col=KERNEL_COLUMNS.index("size"),
                          blocks_col=KERNEL_COLUMNS.index("blocks"),
                          sb_col=_SB_COL, ab_col=_AB_COL,
                          valid_col=_VALID_COL)
                total = np.zeros((N_MEASURES, self._cube_bp, S_BUCKETS,
                                  A_BUCKETS), np.float64)
                if res:
                    mesh = self._resident_mesh(res)
                    cube = mesh_scoped_cube(
                        self._assemble(res, mesh),
                        self._assemble_perm(res, mesh), sid,
                        mesh=mesh, **kw)
                    total += np.asarray(jax.device_get(cube), np.float64)
                for g in demoted:
                    def launch(win, pwin):
                        return mesh_scoped_cube(win, pwin, sid,
                                                mesh=self.mesh, **kw)
                    for _b, _n, cube in self._stream_windows(
                            g, launch, want_perm=True):
                        total += np.asarray(jax.device_get(cube),
                                            np.float64)
                return np.rint(total).astype(np.int64)[:, :b]
            if res and self._cube_cache is None:
                mesh = self._resident_mesh(res)
                combined = mesh_cube_combine(
                    self._assemble_cube(res, mesh), mesh=mesh)
                self._cube_cache = np.rint(
                    np.asarray(jax.device_get(combined))).astype(
                        np.int64).reshape(N_MEASURES, self._cube_bp,
                                          S_BUCKETS, A_BUCKETS)
            frozen = [g for g in demoted if g.frozen_cube is not None]
            if not frozen:
                return (self._cube_cache[:, :b] if res
                        else np.zeros((N_MEASURES, b, S_BUCKETS,
                                       A_BUCKETS), np.int64))
            cube = (self._cube_cache.copy() if res
                    else np.zeros((N_MEASURES, self._cube_bp, S_BUCKETS,
                                   A_BUCKETS), np.int64))
            cube += self._frozen_total()
            return cube[:, :b]

    # -- resident report queries (rbh-find / top-N / rbh-du) -------------------
    def _require_reports_plane(self) -> None:
        if not self._plane_reports:
            raise PolicyError("reports plane not enabled "
                              "(DeviceColumnStore.enable_reports_plane)")

    def _arrays_positions(self, group: _ShardGroup,
                          idx: np.ndarray) -> np.ndarray:
        """Map group-local row indices to catalog ``arrays()`` positions
        (the host oracle's row order) for tie-exact result ordering."""
        counts = {}
        for g in self._groups:
            for p, sid in enumerate(g.shard_ids):
                counts[sid] = int(g.offsets[p + 1] - g.offsets[p])
        base = np.concatenate(
            [[0], np.cumsum([counts.get(s, 0)
                             for s in range(self.catalog.n_shards)])])
        seg = np.searchsorted(group.offsets, idx, side="right") - 1
        sids = np.asarray(group.shard_ids, np.int64)[seg]
        return base[sids] + (idx - group.offsets[seg])

    def find_paths(self, expr, now: float, limit: int = 0,
                   subject: Optional[str] = None) -> List[str]:
        """``rbh-find`` from the resident mesh: one program match, then
        winning rows translate to paths through the host path mirrors —
        emitted in catalog ``arrays()`` order (byte-identical to the host
        fold). Raises PolicyError on glob predicates (host fallback).
        ``subject=`` lists only rows that subject may see."""
        with self._lock:
            self._require_reports_plane()
            match = self._match_locked([expr], now, with_agg=False,
                                       subject=subject)
            self.store_queries += 1
            out: List[str] = []
            for sid in range(self.catalog.n_shards):
                group = self._groups[sid % self.n_devices]
                p = sid // self.n_devices
                lo = int(group.offsets[p])
                hi = int(group.offsets[p + 1])
                idx = match._group_idx[group.gid]
                seg = idx[(idx >= lo) & (idx < hi)]
                paths = self._group_paths(group)
                out.extend(str(paths[i]) for i in seg.tolist())
                if limit and len(out) >= limit:
                    return out[:limit]
            return out

    def top_files(self, by: str = "size", k: int = 10, desc: bool = True,
                  now: float = 0.0,
                  subject: Optional[str] = None) -> List[dict]:
        """Top-N listing from the resident mesh, two passes: per-device
        top-k finds the exact global k-th-best value (the union of
        per-device top-k's contains the global top-k), then a threshold
        mask recovers every candidate incl. cross-device ties; the final
        order sorts candidates by native mirror values with the host
        oracle's exact tie semantics (stable argsort + reversal)."""
        import jax
        from .types import FsType
        from ..kernels.policy_scan.ops import (mesh_column_topk,
                                               mesh_threshold_rows)
        if by not in KERNEL_COLUMNS:
            raise PolicyError(f"top_files by {by!r} is not a kernel column")
        with self._lock:
            self._require_reports_plane()
            self.refresh()
            self.store_queries += 1
            res = self._resident()
            demoted = self._demoted()
            if k <= 0 or not (any(g.rows for g in res)
                              or any(g.segment.n_rows for g in demoted)):
                return []
            sid = self._resolve_subject(subject)
            col = KERNEL_COLUMNS.index(by)
            type_col = KERNEL_COLUMNS.index("type")
            file_code = float(int(FsType.FILE))
            want_perm = sid is not None
            # pass 1: per-device / per-window top-k candidates — the
            # global top-k is a subset of their union, so the merged
            # k-th best is an exact selection threshold for pass 2
            cand_thr = []
            mesh = global_cols = perm = None
            if res:
                mesh = self._resident_mesh(res)
                global_cols = self._assemble(res, mesh)
                perm = self._assemble_perm(res, mesh) if want_perm \
                    else None
                vals, _idx = mesh_column_topk(
                    global_cols, mesh=mesh, col=col,
                    k=min(k, self._rp), desc=desc, valid_col=_VALID_COL,
                    type_col=type_col, file_code=file_code, perm=perm,
                    subject=sid)
                cand_thr.append(np.asarray(jax.device_get(vals)).ravel())
            kw = min(k, self._window_rows())
            for g in demoted:
                def launch_topk(win, pwin):
                    return mesh_column_topk(
                        win, mesh=self.mesh, col=col, k=kw, desc=desc,
                        valid_col=_VALID_COL, type_col=type_col,
                        file_code=file_code, perm=pwin,
                        subject=sid if pwin is not None else None)
                for _b, _n, (vals, _i) in self._stream_windows(
                        g, launch_topk, want_perm):
                    cand_thr.append(
                        np.asarray(jax.device_get(vals)).ravel())
            merged = np.concatenate(cand_thr)
            merged = merged[np.isfinite(merged)]
            if merged.size == 0:
                return []
            merged.sort()                     # ascending
            kk = min(k, merged.size)
            thr = float(merged[-kk] if desc else merged[kk - 1])
            # pass 2: threshold mask recovers every candidate, including
            # cross-device / cross-window boundary ties
            cand_vals, cand_pos, cand_paths, cand_fids = [], [], [], []

            def collect(group, rows):
                fids, gcols, paths = self._group_arrays(group)
                cand_vals.append(np.asarray(gcols[by])[rows])
                cand_pos.append(self._arrays_positions(group, rows))
                cand_fids.append(np.asarray(fids)[rows])
                cand_paths.extend(str(paths[i]) for i in rows.tolist())

            if res:
                mask = mesh_threshold_rows(
                    global_cols, thr, mesh=mesh, col=col, ge=desc,
                    valid_col=_VALID_COL, type_col=type_col,
                    file_code=file_code, perm=perm, subject=sid)
                mask_np = np.asarray(jax.device_get(mask))
                for i, group in enumerate(res):
                    rows = np.nonzero(mask_np[i, : group.rows] > 0.5)[0]
                    if rows.size:
                        collect(group, rows)
            for g in demoted:
                def launch_thr(win, pwin):
                    return mesh_threshold_rows(
                        win, thr, mesh=self.mesh, col=col, ge=desc,
                        valid_col=_VALID_COL, type_col=type_col,
                        file_code=file_code, perm=pwin,
                        subject=sid if pwin is not None else None)
                parts = []
                for base, nrows, mask in self._stream_windows(
                        g, launch_thr, want_perm):
                    m = np.asarray(jax.device_get(mask)) \
                        .reshape(-1)[:nrows]
                    hit = np.nonzero(m > 0.5)[0]
                    if hit.size:
                        parts.append(base + hit)
                if parts:
                    collect(g, np.concatenate(parts))
            if not cand_vals:
                return []
            values = np.concatenate(cand_vals)
            pos = np.concatenate(cand_pos)
            fids = np.concatenate(cand_fids)
            # host tie semantics: stable ascending argsort (ties by
            # arrays position), reversed wholesale for descending
            order = np.lexsort((pos, values))
            order = order[::-1][:kk] if desc else order[:kk]
            return [{"path": cand_paths[o], by: float(values[o]),
                     "fid": int(fids[o])} for o in order.tolist()]

    def du(self, path_prefix: str, subject: Optional[str] = None) -> dict:
        """``rbh-du -s`` from the resident mesh: two host binary searches
        per group into the sorted path mirror produce rank bounds; one
        fused on-device range aggregate psum-combines
        [count, files, volume, spc_used] — no row leaves a device.
        ``subject=`` counts only rows that subject may see."""
        import jax
        from .types import FsType
        from ..kernels.policy_scan.ops import mesh_range_aggregate
        with self._lock:
            self._require_reports_plane()
            self.refresh()
            self.store_queries += 1
            sid = self._resolve_subject(subject)
            want_perm = sid is not None
            prefix = path_prefix.rstrip("/")

            def rank_bounds(sp):
                return (np.searchsorted(sp, prefix + "/", side="left"),
                        np.searchsorted(sp, prefix + "0", side="left"),
                        np.searchsorted(sp, prefix, side="left"),
                        np.searchsorted(sp, prefix, side="right"))

            kw = dict(ord_col=_ORD_COL,
                      type_col=KERNEL_COLUMNS.index("type"),
                      size_col=KERNEL_COLUMNS.index("size"),
                      blocks_col=KERNEL_COLUMNS.index("blocks"),
                      valid_col=_VALID_COL,
                      file_code=float(int(FsType.FILE)))
            res = self._resident()
            total = np.zeros(4, np.float64)
            if res:
                mesh = self._resident_mesh(res)
                perm = self._assemble_perm(res, mesh) if want_perm \
                    else None
                bounds = np.zeros((len(res), 4), np.float32)
                for i, group in enumerate(res):
                    sp = group.spaths if group.spaths is not None \
                        else np.zeros(0, dtype="<U1")
                    bounds[i] = rank_bounds(sp)
                agg = mesh_range_aggregate(
                    self._assemble(res, mesh), bounds, mesh=mesh,
                    perm=perm, subject=sid, **kw)
                total += np.asarray(jax.device_get(agg), np.float64)
            for g in self._demoted():
                # the window rows carry each row's rank in the GROUP's
                # sorted-path order, so one bounds row serves every
                # device of every window of this group
                gb = np.tile(np.asarray(
                    rank_bounds(self._segment_spaths(g)), np.float32),
                    (self.n_devices, 1))

                def launch(win, pwin):
                    return mesh_range_aggregate(
                        win, gb, mesh=self.mesh, perm=pwin,
                        subject=sid if pwin is not None else None, **kw)
                for _b, _n, agg in self._stream_windows(g, launch,
                                                        want_perm):
                    total += np.asarray(jax.device_get(agg), np.float64)
            return {"count": int(round(float(total[0]))),
                    "files": int(round(float(total[1]))),
                    "volume": int(round(float(total[2]))),
                    "spc_used": int(round(float(total[3])))}
