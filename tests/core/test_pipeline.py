"""Changelog-consumption pipeline: sync + async dirty-tag modes (C4/C11)."""
import time

from repro.core import (Catalog, ChangelogCounters, ChangelogStream,
                        EventPipeline, PipelineConfig, Scanner)
from repro.fs import LustreSim


def _fs_with_files(n=30):
    fs = LustreSim(n_mdts=1)
    d = fs.mkdir(fs.root_fid(), "dir")
    fids = []
    for i in range(n):
        f = fs.create(d, f"f{i}", owner="u", uid="u")
        fs.write(f, 100 * (i + 1))
        fids.append(f)
    return fs, d, fids


def test_sync_pipeline_mirrors_fs():
    fs, d, fids = _fs_with_files()
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    n = pipe.process_once(100000)
    assert n > 0
    assert len(cat) == fs.count() - 1      # root not in changelog
    assert cat.get(fids[3]).size == 400
    # acks happened: nothing pending
    assert fs.changelog.stream(0).pending() == 0


def test_incremental_updates_no_rescan():
    fs, d, fids = _fs_with_files(10)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    pipe.process_once(100000)
    fs.write(fids[0], 5000, uid="u")
    fs.unlink(fids[1])
    new = fs.create(d, "fresh", owner="u")
    fs.write(new, 7)
    pipe.process_once()
    assert cat.get(fids[0]).size == 100 + 5000
    assert cat.get(fids[1]) is None
    assert cat.get(new).size == 7


def test_async_dirty_tag_dedups():
    """Paper SIII-A2 future work: repeated changes fold into one refresh."""
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    cfg = PipelineConfig(async_updates=True)
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), cfg)
    pipe.process_once(100000)
    for _ in range(20):                    # 20 writes to the same file
        fs.write(fids[2], 10, uid="u")
    n = pipe.process_once()
    assert n == 20
    assert pipe.dedup_hits >= 18           # tagged once, folded repeatedly
    assert cat.get(fids[2]).size == 300 + 200


def test_threaded_pipeline_drains():
    fs, d, fids = _fs_with_files(40)
    cat = Catalog()
    counters = ChangelogCounters()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(n_workers=3), counters)
    pipe.start()
    try:
        assert pipe.drain(timeout=20)
        for i in range(10):
            fs.write(fids[i], 1, uid="live")
        assert pipe.drain(timeout=20)
    finally:
        pipe.stop()
    assert cat.get(fids[0]).size == 101
    assert counters.snapshot()["per_user"]["live"]


def test_same_batch_create_unlink_never_materializes():
    """An UNLNK after a CREAT of the same fid in one batch folds to nothing:
    no error, no catalog entry, no dirty tag (sync and async modes)."""
    for async_updates in (False, True):
        fs = LustreSim(n_mdts=1)
        d = fs.mkdir(fs.root_fid(), "dir")
        keep = fs.create(d, "keep", owner="u")
        fs.write(keep, 50)
        ephemeral = fs.create(d, "tmp", owner="u")
        fs.write(ephemeral, 999)
        fs.unlink(ephemeral)               # same pending batch as its CREAT
        cat = Catalog()
        pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                             PipelineConfig(async_updates=async_updates,
                                            batch_size=1024))
        pipe.process_once(100000)
        assert cat.get(ephemeral) is None
        assert ephemeral not in pipe._dirty
        assert cat.get(keep).size == 50
        assert fs.changelog.stream(0).pending() == 0   # all acked cleanly


def test_delta_fanout_notifies_after_commit():
    fs, d, fids = _fs_with_files(8)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    events = []
    pipe.add_delta_listener(
        lambda changed, removed: events.append((sorted(changed),
                                                sorted(removed))))
    pipe.process_once(100000)
    changed = sorted(f for ch, _ in events for f in ch)
    assert changed == sorted([d] + fids)
    events.clear()

    fs.write(fids[0], 7, uid="u")
    fs.write(fids[0], 7, uid="u")          # folded: one refresh per batch
    fs.unlink(fids[1])
    pipe.process_once(100000)
    changed = [f for ch, _ in events for f in ch]
    removed = [f for _, rm in events for f in rm]
    assert changed == [fids[0]] and removed == [fids[1]]


def test_delta_fanout_async_mode_notifies_refresh():
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(async_updates=True))
    pipe.process_once(100000)
    events = []
    pipe.add_delta_listener(
        lambda changed, removed: events.append((list(changed),
                                                list(removed))))
    for _ in range(10):
        fs.write(fids[2], 10, uid="u")
    fs.unlink(fids[3])
    pipe.process_once(100000)
    changed = [f for ch, _ in events for f in ch]
    removed = [f for _, rm in events for f in rm]
    assert removed == [fids[3]]
    assert changed == [fids[2]]            # deduped to one refresh
    assert cat.get(fids[2]).size == 300 + 100


def test_scan_and_changelog_agree():
    """DB built by scan == DB built by changelog replay."""
    fs, d, fids = _fs_with_files(25)
    by_scan = Catalog()
    Scanner(fs, by_scan).scan()
    by_log = Catalog()
    EventPipeline(fs, by_log, fs.changelog.stream(0),
                  PipelineConfig()).process_once(100000)
    for fid in fids:
        a, b = by_scan.get(fid), by_log.get(fid)
        assert a.size == b.size and a.owner == b.owner and a.path == b.path


# -- columnar ingest plane ----------------------------------------------------

class _SlowStat:
    """fs proxy whose (batched) stat takes a while — long enough that a
    drain() racing an in-flight refresh would observe stale state."""

    def __init__(self, inner, delay):
        self._inner = inner
        self._delay = delay

    def stat_batch(self, fids):
        time.sleep(self._delay)
        return self._inner.stat_batch(fids)

    def stat(self, fid):
        time.sleep(self._delay)
        return self._inner.stat(fid)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_drain_waits_for_inflight_updater_refresh():
    """Regression: drain() returned True while an async updater held fids
    it had already popped from ``_dirty`` with the refresh still in
    flight — pending()==0 and an empty dirty set are not 'drained'."""
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    pipe = EventPipeline(_SlowStat(fs, 0.25), cat, fs.changelog.stream(0),
                         PipelineConfig(async_updates=True, n_updaters=1))
    pipe.start()
    try:
        assert pipe.drain(timeout=30)
        size0 = cat.get(fids[0]).size
        fs.write(fids[0], 77, uid="u")
        # wait for the tag to be consumed AND popped by the updater: the
        # only remaining signal of unfinished work is the refresh itself
        deadline = time.time() + 10
        while (fs.changelog.stream(0).pending() or pipe._dirty) \
                and time.time() < deadline:
            time.sleep(0.005)
        assert pipe.drain(timeout=30)
        assert cat.get(fids[0]).size == size0 + 77, \
            "drain() returned before the in-flight refresh committed"
    finally:
        pipe.stop()


def test_drain_counts_inflight_worker_batches():
    """Same race on the oracle worker pool: a popped-but-uncommitted
    batch must keep drain() blocked (the batch queue is already empty)."""
    fs, d, fids = _fs_with_files(6)
    cat = Catalog()
    pipe = EventPipeline(_SlowStat(fs, 0.2), cat, fs.changelog.stream(0),
                         PipelineConfig(columnar=False, n_workers=2))
    pipe.start()
    try:
        assert pipe.drain(timeout=30)
        assert len(cat) == fs.count() - 1
    finally:
        pipe.stop()


def test_idle_pipeline_does_not_busy_wait():
    """Readers and updaters block on Conditions: an idle second must add
    zero wakeups and zero pipeline.apply spans to the histograms."""
    fs, d, fids = _fs_with_files(10)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(async_updates=True))
    pipe.start()
    try:
        assert pipe.drain(timeout=30)
        time.sleep(0.2)                      # settle any tail wakeup

        def snap():
            wake = sum(v for k, v in
                       cat.telemetry.counter_values().items()
                       if k.startswith("pipeline_wakeups"))
            spans = cat.telemetry.histogram(
                "span_seconds", span="pipeline.apply").count
            return wake, spans

        before = snap()
        time.sleep(0.6)
        assert snap() == before, \
            "idle pipeline threads iterated without work (busy-wait)"
        fs.write(fids[0], 9, uid="u")        # ...but wakeups still work
        assert pipe.drain(timeout=30)
        assert snap() > before
    finally:
        pipe.stop()
    assert cat.get(fids[0]).size == 109


def test_hub_sharded_readers_mirror_all_mdts():
    """One pipeline over a whole hub: per-MDT readers with independent
    acks, one shared catalog, all MDT streams drained."""
    fs = LustreSim(n_mdts=4)
    dirs = [fs.mkdir(fs.root_fid(), f"d{i}") for i in range(8)]
    fids = [fs.create(dirs[i % 8], f"f{i}", owner="u", uid="u")
            for i in range(60)]
    for f in fids:
        fs.write(f, 10, uid="u")
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog, PipelineConfig())
    pipe.start()
    try:
        assert pipe.drain(timeout=30)
        assert len(cat) == fs.count() - 1
        for mdt in range(4):
            assert fs.changelog.stream(mdt).pending() == 0
        fs.unlink(fids[0])
        fs.write(fids[1], 90, uid="u")
        assert pipe.drain(timeout=30)
        assert cat.get(fids[0]) is None
        assert cat.get(fids[1]).size == 100
    finally:
        pipe.stop()


def test_adaptive_quantum_grows_and_is_visible():
    """A pre-emitted burst on one MDT grows the reader's quantum toward
    max_batch; transitions land in the adaptation counters."""
    fs, d, fids = _fs_with_files(10)
    for _ in range(40):
        for f in fids:
            fs.write(f, 1, uid="u")
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(batch_size=16, min_batch=16,
                                        max_batch=1024, lag_target=60.0))
    pipe.start()
    try:
        assert pipe.drain(timeout=30)
    finally:
        pipe.stop()
    vals = cat.telemetry.counter_values()
    grown = sum(v for k, v in vals.items()
                if k.startswith("pipeline_batch_adaptations")
                and 'direction="grow"' in k)
    assert grown >= 1
    assert pipe._quantum[0] > 16


def test_crash_resume_mid_columnar_batch(tmp_path):
    """Crash after commit but before ack: the restarted stream re-delivers
    the committed batch; replaying it lands on identical catalog state."""
    d = str(tmp_path)
    fs = LustreSim(n_mdts=1, changelog_dir=d)
    root_d = fs.mkdir(fs.root_fid(), "dir")
    fids = [fs.create(root_d, f"f{i}", owner="u", uid="u")
            for i in range(12)]
    for f in fids:
        fs.write(f, 100, uid="u")
    fs.unlink(fids[3])

    cat = Catalog()
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig(batch_size=9))
    pipe._acks[0].complete_range = lambda lo, hi: None   # die before ack
    pipe.process_once(10 ** 6)
    n_committed = len(cat)
    assert n_committed > 0 and stream.pending() > 0      # mid-batch crash

    # restart: fresh stream over the same persist dir re-delivers all
    # unacked records; the same catalog replays them idempotently
    stream.close()
    s2 = ChangelogStream(mdt=0, persist_dir=d)
    pipe2 = EventPipeline(fs, cat, s2, PipelineConfig(batch_size=9))
    pipe2.process_once(10 ** 6)
    assert s2.pending() == 0

    # byte-identical to a ground-truth mirror of the fs
    oracle = Catalog()
    Scanner(fs, oracle).scan()
    for f in [root_d] + fids:
        a, b = cat.get(f), oracle.get(f)
        if b is None:
            assert a is None
        else:
            assert (a.size, a.owner, a.path, int(a.type)) == \
                (b.size, b.owner, b.path, int(b.type))
