"""Multi-stage record-processing pipeline (C4) + async dirty-tag mode (C11).

Paper SIII-A2: record processing is split into steps, one per resource kind
(filesystem lookups vs database commits), serviced by a worker-thread pool;
per-resource concurrency is capped so neither the MDS nor the DB is
overloaded. We reproduce that, plus the paper's *proposed* asynchronous
improvement: changelog processing merely **tags** entries dirty (cheap, acks
fast), and a background pool of *updaters* refreshes tagged entries, folding
repeated changes to one refresh (dedup).

Stages (synchronous mode):
  changelog record -> [GET_INFO: fs.stat, bounded by fs_concurrency]
                   -> [DB_APPLY: catalog batch upsert, bounded by db_concurrency]
                   -> ack(seq)

Acks are only issued once every record up to ``seq`` is committed (the
catalog's sqlite commit happens inside ``upsert_batch``), preserving the
transactional contract end-to-end.

**Delta fan-out**: downstream consumers (the policy engine's incremental
match state, cache invalidators, ...) can register a listener via
:meth:`EventPipeline.add_delta_listener`; after each batch is committed to
the catalog the listener receives ``(changed_fids, removed_fids)``.
Listeners are notified *after* the catalog mutation, so re-reading the
catalog for a notified fid always observes at least that change. Within one
batch, records are folded per fid in record order (one refresh per fid; an
``UNLNK`` arriving after a ``CREAT`` of the same fid in the same batch wins
— the entry is removed, never materialized, and never reported dirty).

The same committed mutations also reach every ``Catalog.add_delta_hook``
consumer (each claiming exactly one feed — see the shared fan-out
contract in ``core.device_store`` / ``ProfileCube.claim_delta_feed``):
the :class:`~repro.core.device_store.DeviceColumnStore` drains one dirty
batch into the resident column block, the cube partials, the plane
mirrors **and the permissions-plane bitsets** in a single scatter pass,
so changelog ingestion keeps multi-tenant ``subject=`` serving fresh
without any consumer rescanning the catalog.
"""
from __future__ import annotations

import dataclasses
import heapq
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from .catalog import Catalog
from .changelog import ChangelogStream
from .stats import ChangelogCounters
from .telemetry import counter_attr
from .types import ChangelogRecord, ChangelogType, Entry


@dataclasses.dataclass
class PipelineConfig:
    fs_concurrency: int = 4       # max simultaneous filesystem operations
    db_concurrency: int = 2       # max simultaneous catalog commit batches
    batch_size: int = 256         # records per DB commit batch
    n_workers: int = 4
    async_updates: bool = False   # dirty-tag + background updaters
    n_updaters: int = 2
    updater_interval: float = 0.002


class _AckTracker:
    """Tracks per-stream contiguous completion so acks stay in order."""

    def __init__(self, stream: ChangelogStream) -> None:
        self.stream = stream
        self._lock = threading.Lock()
        self._done: List[int] = []     # min-heap of completed seqs
        self._acked = stream.acked

    def complete(self, seqs: List[int]) -> None:
        with self._lock:
            for s in seqs:
                heapq.heappush(self._done, s)
            new_ack = self._acked
            while self._done and self._done[0] == new_ack + 1:
                new_ack = heapq.heappop(self._done)
            if new_ack != self._acked:
                self._acked = new_ack
                self.stream.ack(new_ack)


class EventPipeline:
    """Consumes one changelog stream into the catalog."""

    # ingest counters, registry-backed (tests read them as plain ints)
    processed = counter_attr(
        "pipeline_records_processed", "changelog records folded into the "
        "catalog")
    dedup_hits = counter_attr(
        "pipeline_dedup_hits", "records folded into an already-pending "
        "dirty tag (async mode)")

    def __init__(self, fs, catalog: Catalog, stream: ChangelogStream,
                 config: Optional[PipelineConfig] = None,
                 counters: Optional[ChangelogCounters] = None) -> None:
        self.fs = fs
        self.catalog = catalog
        self.stream = stream
        self.cfg = config or PipelineConfig()
        self.counters = counters
        self.telemetry = catalog.telemetry
        self._tlabels = {"pipeline": catalog.telemetry.instance("pipeline")}
        # the stream's backlog/lag gauges + events counter land in the
        # same registry (first binder wins; a stream shared by several
        # catalogs keeps its first registry)
        if stream.telemetry is None:
            stream.bind_telemetry(catalog.telemetry)
        self._fs_sem = threading.Semaphore(self.cfg.fs_concurrency)
        self._db_sem = threading.Semaphore(self.cfg.db_concurrency)
        self._ack = _AckTracker(stream)
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._batches: "queue.Queue[List[ChangelogRecord]]" = queue.Queue(maxsize=64)
        self.processed = 0
        self._processed_lock = threading.Lock()
        # async dirty-tag state
        self._dirty: Set[int] = set()
        self._dirty_lock = threading.Lock()
        self.dedup_hits = 0
        # delta fan-out (policy engine incremental match state, caches, ...)
        self._delta_listeners: List[Callable[[List[int], List[int]], None]] = []

    # -- delta fan-out ------------------------------------------------------------
    def add_delta_listener(self, fn: Callable[[List[int], List[int]], None]
                           ) -> None:
        """Register ``fn(changed_fids, removed_fids)``, called after each
        batch of records has been committed to the catalog."""
        self._delta_listeners.append(fn)

    def _notify(self, changed: List[int], removed: List[int]) -> None:
        if changed or removed:
            self.telemetry.counter(
                "pipeline_deltas_fanned_out", help="fids propagated to "
                "delta listeners after a catalog commit",
                **self._tlabels).inc(len(changed) + len(removed))
            with self.telemetry.trace("pipeline.fanout",
                                      changed=len(changed),
                                      removed=len(removed),
                                      **self._tlabels):
                for fn in self._delta_listeners:
                    fn(changed, removed)

    # -- record -> catalog application -------------------------------------------
    def _apply_records(self, recs: List[ChangelogRecord]) -> None:
        """GET_INFO + DB_APPLY for one batch, then mark complete for ack.

        Records are folded per fid, last-in-record-order wins: repeated
        updates of one entry cost a single ``fs.stat``, and an ``UNLNK``
        following a ``CREAT`` of the same fid inside the batch results in a
        removal only (the short-lived entry is never materialized).
        """
        with self.telemetry.trace("pipeline.apply", records=len(recs),
                                  **self._tlabels):
            is_removal: Dict[int, bool] = {}  # fid -> last op kind, batch order
            for rec in recs:
                if self.counters is not None:
                    self.counters.on_record(rec)
                is_removal[rec.fid] = rec.type in (ChangelogType.UNLNK,
                                                   ChangelogType.RMDIR)
            entries: List[Entry] = []
            removals: List[int] = []
            for fid, rm in is_removal.items():
                if rm:
                    removals.append(fid)
                    continue
                with self._fs_sem:                   # bounded FS concurrency
                    e = self.fs.stat(fid)
                if e is not None:
                    entries.append(e)
            with self._db_sem:                        # bounded DB concurrency
                if entries:
                    self.catalog.upsert_batch(entries)  # durable before ack
                for fid in removals:
                    self.catalog.remove(fid)
            with self._processed_lock:
                self.processed += len(recs)
            self.telemetry.counter(
                "pipeline_events_folded", help="per-fid folds committed "
                "(records deduped per batch)", **self._tlabels
            ).inc(len(is_removal))
            self._notify([e.fid for e in entries], removals)
            self._ack.complete([r.seq for r in recs])

    def _tag_records(self, recs: List[ChangelogRecord]) -> None:
        """Async mode stage 1: tag dirty + ack immediately after durable tag.

        Removals still apply synchronously (they can't be 'refreshed' later).
        """
        removals = []
        folds = 0                 # committed work: new tags + removals
        with self._dirty_lock:
            for rec in recs:
                if self.counters is not None:
                    self.counters.on_record(rec)
                if rec.type in (ChangelogType.UNLNK, ChangelogType.RMDIR):
                    removals.append(rec.fid)
                    self._dirty.discard(rec.fid)      # never refreshed post-rm
                    folds += 1
                elif rec.fid in self._dirty:
                    self.dedup_hits += 1              # folded into pending tag
                else:
                    self._dirty.add(rec.fid)
                    self.catalog.update_fields(rec.fid, dirty=1)
                    folds += 1
        with self._db_sem:
            for fid in removals:
                self.catalog.remove(fid)
        with self._processed_lock:
            self.processed += len(recs)
        self.telemetry.counter(
            "pipeline_events_folded", help="per-fid folds committed "
            "(records deduped per batch)", **self._tlabels).inc(folds)
        # changed fids are notified by the updater after the actual refresh
        self._notify([], removals)
        self._ack.complete([r.seq for r in recs])

    def _updater(self) -> None:
        """Background refresh of dirty-tagged entries (paper's 'updaters')."""
        while not self._stop.is_set() or self._dirty:
            with self._dirty_lock:
                take = list(self._dirty)[: self.cfg.batch_size]
                for fid in take:
                    self._dirty.discard(fid)
            if not take:
                time.sleep(self.cfg.updater_interval)
                continue
            entries = []
            for fid in take:
                with self._fs_sem:
                    e = self.fs.stat(fid)
                if e is not None:
                    e.dirty = False
                    entries.append(e)
            with self._db_sem:
                if entries:
                    self.catalog.upsert_batch(entries)
            self._notify([e.fid for e in entries], [])

    # -- driver ------------------------------------------------------------------
    def _reader(self) -> None:
        while not self._stop.is_set():
            recs = self.stream.read(max_records=self.cfg.batch_size,
                                    timeout=0.05)
            if recs:
                self._batches.put(recs)

    def _worker(self) -> None:
        handler = self._tag_records if self.cfg.async_updates \
            else self._apply_records
        while not self._stop.is_set() or not self._batches.empty():
            try:
                recs = self._batches.get(timeout=0.05)
            except queue.Empty:
                continue
            handler(recs)
            self._batches.task_done()

    def start(self) -> None:
        self._threads = [threading.Thread(target=self._reader, daemon=True)]
        self._threads += [threading.Thread(target=self._worker, daemon=True)
                          for _ in range(self.cfg.n_workers)]
        if self.cfg.async_updates:
            self._threads += [threading.Thread(target=self._updater,
                                               daemon=True)
                              for _ in range(self.cfg.n_updaters)]
        for t in self._threads:
            t.start()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every emitted record has been processed and acked."""
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self.stream.pending() == 0 and self._batches.empty() \
                    and not self._dirty:
                return True
            time.sleep(0.01)
        return False

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)

    def process_once(self, max_records: int = 4096) -> int:
        """Synchronous single-shot processing (no threads) — for tests."""
        handler = self._tag_records if self.cfg.async_updates \
            else self._apply_records
        total = 0
        while True:
            recs = self.stream.read(max_records=min(max_records - total,
                                                    self.cfg.batch_size))
            if not recs:
                break
            handler(recs)
            total += len(recs)
            if total >= max_records:
                break
        if self.cfg.async_updates:
            # run one updater sweep inline
            while self._dirty:
                with self._dirty_lock:
                    take = list(self._dirty)[: self.cfg.batch_size]
                    for fid in take:
                        self._dirty.discard(fid)
                entries = []
                for fid in take:
                    e = self.fs.stat(fid)
                    if e is not None:
                        e.dirty = False
                        entries.append(e)
                if entries:
                    self.catalog.upsert_batch(entries)
                self._notify([e.fid for e in entries], [])
        return total
