import threading

from repro.core import ChangelogStream, ChangelogType


def test_ack_purges_and_pending():
    s = ChangelogStream()
    for fid in range(1, 6):
        s.emit(ChangelogType.CREAT, fid)
    recs = s.read(max_records=3)
    assert [r.seq for r in recs] == [1, 2, 3]
    assert s.pending() == 5         # nothing acked yet
    s.ack(3)
    assert s.pending() == 2
    recs = s.read()
    assert [r.seq for r in recs] == [4, 5]


def test_crash_redelivery_no_loss(tmp_path):
    """Paper SII-C2: unacked records survive a consumer crash."""
    d = str(tmp_path)
    s = ChangelogStream(mdt=0, persist_dir=d)
    for fid in range(1, 11):
        s.emit(ChangelogType.CREAT, fid)
    s.read(max_records=7)
    s.ack(4)                        # only 4 committed before the "crash"
    s.close()
    # restart: a fresh stream on the same dir re-delivers 5..10
    s2 = ChangelogStream(mdt=0, persist_dir=d)
    recs = s2.read(max_records=100)
    assert [r.seq for r in recs] == list(range(5, 11))
    # and new records continue the sequence
    r = s2.emit(ChangelogType.UNLNK, 99)
    assert r.seq == 11


def test_reset_cursor_redelivers():
    s = ChangelogStream()
    for fid in range(3):
        s.emit(ChangelogType.MKDIR, fid)
    s.read()
    s.ack(1)
    s.reset_cursor()
    assert [r.seq for r in s.read()] == [2, 3]


def test_concurrent_producers_unique_seqs():
    s = ChangelogStream()

    def produce():
        for i in range(100):
            s.emit(ChangelogType.CREAT, i)

    threads = [threading.Thread(target=produce) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = s.read(max_records=1000)
    seqs = [r.seq for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == 400
