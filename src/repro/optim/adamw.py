"""AdamW with configurable moment dtype (bf16 moments halve optimizer HBM
for the 400B llama4 single-pod fit — see EXPERIMENTS.md SDry-run)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
OptState = Dict[str, PyTree]


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Union[float, Callable[[jax.Array], jax.Array]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    grad_clip: float = 1.0

    def init(self, params: PyTree) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def _lr(self, count: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(count)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: PyTree, state: OptState, params: PyTree
               ) -> Tuple[PyTree, OptState]:
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        lr = self._lr(count)
        # global-norm clip (f32 accumulation)
        gsq = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                         grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12)) \
            if self.grad_clip else 1.0

        bc1 = 1.0 - self.b1 ** cf
        bc2 = 1.0 - self.b2 ** cf

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            step = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                step = step + lr * self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - step).astype(p.dtype)
            return p_new, m_new.astype(self.moment_dtype), \
                v_new.astype(self.moment_dtype)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {"m": treedef.unflatten([o[1] for o in out]),
                     "v": treedef.unflatten([o[2] for o in out]),
                     "count": count}
        return new_params, new_state
