"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds, from the PER-DEVICE
partitioned module (XLA cost_analysis on an SPMD module reports per-device
numbers — calibrated in EXPERIMENTS.md SDry-run):

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_wire_bytes_per_device / ICI_bw

collective bytes are parsed from the optimized HLO: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
tensor size and apply the standard ring-cost factor over the parsed replica
group size k:

    all-reduce: 2 * (k-1)/k * bytes     all-gather: (k-1)/k * out_bytes
    reduce-scatter: (k-1)/k * in_bytes  all-to-all: (k-1)/k * bytes
    collective-permute: bytes

(Per the assignment we also report the raw operand-size sum.)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE[shape]{layout} op-name(...)` — possibly tuple-typed `(a, b)`
_INSTR_RE = re.compile(
    r"=\s*(?P<otype>\(?[a-z0-9\[\],{}:#\s()]+?\)?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+[0-9]*)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(?P<g>\d+),(?P<k>\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{(?P<first>[0-9,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group("k")))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group("first").split(",")))
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-type totals: count, tensor bytes, estimated wire bytes."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        # avoid double counting async -start/-done pairs: skip -done
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done\(", line):
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("otype"))
        k = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * (k - 1) / k * nbytes
        elif op == "collective-permute":
            wire = float(nbytes)
        else:
            wire = (k - 1) / k * nbytes
        d = out[op]
        d["count"] += 1
        d["bytes"] += nbytes
        d["wire_bytes"] += wire
    return dict(out)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_wire_bytes: float) -> Dict[str, float]:
    """Per-device three-term roofline, in seconds."""
    compute = flops / PEAK_FLOPS_BF16
    memory = bytes_accessed / HBM_BW
    collective = collective_wire_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    terms["bottleneck"] = dom.replace("_s", "")
    terms["roofline_fraction_compute"] = compute / total
    return terms


def analyze(compiled, lowered=None) -> Dict[str, object]:
    """Full analysis dict for one compiled cell."""
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    tensor_bytes = sum(d["bytes"] for d in colls.values())
    wire_bytes = sum(d["wire_bytes"] for d in colls.values())
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    out = {
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_accessed,
        "collective_tensor_bytes": tensor_bytes,
        "collective_wire_bytes": wire_bytes,
        "collectives": colls,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
    }
    out.update(roofline_terms(flops, bytes_accessed, wire_bytes))
    return out


def model_flops(cfg, shape, mesh_devices: int) -> Dict[str, float]:
    """Analytic MODEL_FLOPS per device: 6*N_active*tokens (train),
    2*N_active*tokens (prefill/decode forward)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n_active * tokens
    return {"model_flops_total": total,
            "model_flops_per_device": total / mesh_devices}
