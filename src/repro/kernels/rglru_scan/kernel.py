"""Pallas TPU kernel: RG-LRU diagonal linear recurrence (recurrentgemma).

The pure-JAX path uses ``jax.lax.associative_scan`` (log-depth, but
materializes O(log S) intermediate (B,S,R) tensors in HBM). On TPU the
recurrence is better served by a sequential in-VMEM loop: each grid step
owns a (block_s, r_tile) tile of the sequence, the carry h lives in a VMEM
scratch accumulator, and HBM traffic is exactly one read of (log_a, b) and
one write of h — the memory-roofline optimum.

Grid: (B, R // r_tile, S // block_s); the time loop runs inside the kernel
over ``block_s`` steps (sublane-dim), with the lane dim carrying r_tile
channels (128-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(h0_ref, la_ref, b_ref, h_ref, carry_ref, *, block_s: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    h = carry_ref[0]                                   # (r_tile,)
    la = la_ref[0]                                     # (block_s, r_tile)
    bb = b_ref[0]

    def step(t, h):
        h_new = jnp.exp(la[t]) * h + bb[t]
        h_ref[0, t, :] = h_new
        return h_new

    h = jax.lax.fori_loop(0, block_s, step, h)
    carry_ref[0] = h


def rglru_pallas(log_a: jax.Array, b: jax.Array, h0: jax.Array, *,
                 r_tile: int = 128, block_s: int = 64,
                 interpret: bool = True) -> jax.Array:
    """log_a, b: (B, S, R) f32; h0: (B, R) f32 -> h: (B, S, R)."""
    from jax.experimental.pallas import tpu as pltpu

    B, S, R = log_a.shape
    r_tile = min(r_tile, R)
    block_s = min(block_s, S)
    assert R % r_tile == 0 and S % block_s == 0

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    grid = (B, R // r_tile, S // block_s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r_tile), lambda b_, r, s: (b_, r)),
            pl.BlockSpec((1, block_s, r_tile), lambda b_, r, s: (b_, s, r)),
            pl.BlockSpec((1, block_s, r_tile), lambda b_, r, s: (b_, s, r)),
        ],
        out_specs=pl.BlockSpec((1, block_s, r_tile),
                               lambda b_, r, s: (b_, s, r)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, r_tile), jnp.float32)],
        interpret=interpret,
    )(h0, log_a, b)
