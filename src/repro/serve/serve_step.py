"""Serving steps: prefill (prompt -> cache) and decode (one token/step)."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def make_serve_step(model):
    """serve_step(params, cache, tokens (B,1), pos) -> (next (B,1), cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_cache

    return serve_step


def make_prefill(model, cache_len: int):
    """prefill(params, tokens, extras) -> (last-token logits, cache)."""

    def prefill(params, tokens, extras=None):
        logits, cache = model.prefill(params, tokens, cache_len, extras)
        return logits[:, -1, :], cache

    return prefill
