"""Pallas TPU kernel: fused columnar predicate scan + aggregation.

The TPU-native analogue of Robinhood's MySQL table scan (paper C1) fused
with its on-the-fly aggregation (C6): one pass through the entry table
evaluates a postfix predicate program and accumulates count / volume /
spc_used / size-profile histogram — without materializing intermediate
masks in HBM.

Tiling: the entry table is columnar f32[n_cols, N]; the grid walks row
tiles of ``tile`` entries (lane-dim aligned to 128). Each grid step holds a
(n_cols, tile) block in VMEM, evaluates the program on the tile with a
small in-register stack, emits the tile's match mask, and accumulates the
aggregate vector into a (1, N_AGG) accumulator block (revisited by every
grid step — standard Pallas reduction pattern).

The program (ops/colidx/operands) rides in SMEM-like small blocks; P is
static (padded with NOPs), so the instruction loop fully unrolls into
vector selects — no scalar branching on TPU.

Two launch shapes share the evaluation loop:

* :func:`policy_scan_pallas` — one program, (N,) mask + fused aggregates;
* :func:`policy_scan_batch_pallas` — the full (R, P) program batch of a
  policy (combined criteria + per-rule conditions) in a SINGLE launch,
  writing the (R, N) mask tile, the fused first-match-wins rule
  attribution, and per-program size/blocks reductions. One grid walk over
  the entry table replaces R launches plus two host-side passes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_AGG

LANE = 128
# static python floats (array constants cannot be captured by a kernel)
_EDGE_VALS = (0.0, 1.0, 32.0, float(1 << 10), float(32 << 10),
              float(1 << 20), float(32 << 20), float(1 << 30),
              float(32 << 30), float(1 << 40))


def _eval_program_tile(cols, read_instr, n_instr: int, max_stack: int):
    """Unrolled postfix-program evaluation on a (n_cols, tile) block.

    ``read_instr(i)`` returns the (op, col, val) scalars of instruction i —
    indirection so the single- and batch-program kernels share the loop.
    """
    tile = cols.shape[1]
    stack = jnp.zeros((max_stack, tile), jnp.float32)
    sp = jnp.zeros((), jnp.int32)
    for i in range(n_instr):                   # static unroll
        op, col, val = read_instr(i)
        vec = jax.lax.dynamic_index_in_dim(cols, col, axis=0,
                                           keepdims=False)
        cmps = jnp.stack([
            (vec == val), (vec != val), (vec > val), (vec >= val),
            (vec < val), (vec <= val)], axis=0).astype(jnp.float32)
        cmp = jax.lax.dynamic_index_in_dim(cmps, jnp.clip(op, 0, 5), axis=0,
                                           keepdims=False)
        a = jax.lax.dynamic_index_in_dim(stack, jnp.maximum(sp - 1, 0),
                                         axis=0, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(stack, jnp.maximum(sp - 2, 0),
                                         axis=0, keepdims=False)
        is_cmp = op < 6
        is_and = op == 6
        is_or = op == 7
        is_not = op == 8
        is_nop = op < 0
        new_val = jnp.where(is_cmp, cmp,
                            jnp.where(is_and, a * b,
                                      jnp.where(is_or, jnp.clip(a + b, 0, 1),
                                                1.0 - a)))
        write_pos = jnp.where(is_cmp, sp, jnp.where(is_not, sp - 1, sp - 2))
        write_pos = jnp.clip(write_pos, 0, max_stack - 1)
        written = jax.lax.dynamic_update_index_in_dim(
            stack, new_val, write_pos, axis=0)
        stack = jnp.where(is_nop, stack, written)
        sp = jnp.where(is_nop, sp,
                       jnp.where(is_cmp, sp + 1,
                                 jnp.where(is_not, sp, sp - 1)))
    return jax.lax.dynamic_index_in_dim(stack, jnp.maximum(sp - 1, 0),
                                        axis=0, keepdims=False)


def _policy_scan_kernel(ops_ref, colidx_ref, operands_ref, cols_ref,
                        mask_ref, agg_ref, *, n_instr: int, max_stack: int,
                        size_col: int, blocks_col: int, valid_col: int):
    step = pl.program_id(0)

    cols = cols_ref[...]                       # (n_cols, tile) f32 in VMEM
    tile = cols.shape[1]

    mask = _eval_program_tile(
        cols, lambda i: (ops_ref[i], colidx_ref[i], operands_ref[i]),
        n_instr, max_stack)
    if valid_col >= 0:
        mask = mask * cols[valid_col]
    mask_ref[...] = mask[None, :]

    # --- fused aggregation -------------------------------------------------
    size = cols[size_col]
    spc = cols[blocks_col]
    count = jnp.sum(mask)
    volume = jnp.sum(mask * size)
    spc_used = jnp.sum(mask * spc)
    bucket = sum((size >= e).astype(jnp.int32) for e in _EDGE_VALS) - 1
    bucket = jnp.clip(bucket, 0, 9)
    iota10 = jax.lax.broadcasted_iota(jnp.int32, (10, tile), 0)
    onehot = (bucket[None, :] == iota10).astype(jnp.float32)
    hist = onehot @ mask                       # (10,)
    any_match = jnp.max(mask)
    agg = jnp.concatenate([jnp.stack([count, volume, spc_used]), hist,
                           any_match[None]])            # (N_AGG,)

    @pl.when(step == 0)
    def _init():
        agg_ref[...] = jnp.zeros_like(agg_ref)

    prev = agg_ref[0, :]
    acc = prev + agg
    # any_match is a max-, not sum-, accumulator
    agg_ref[0, :] = acc.at[N_AGG - 1].set(jnp.maximum(prev[N_AGG - 1],
                                                      any_match))


def policy_scan_pallas(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                       operands: jax.Array, *, size_col: int = 0,
                       blocks_col: int = 1, valid_col: int = -1,
                       tile: int = 8 * LANE, max_stack: int = 8,
                       interpret: bool = True
                       ) -> Tuple[jax.Array, jax.Array]:
    """cols: (n_cols, N) f32, N % tile == 0. Returns (mask (N,), agg)."""
    n_cols, n = cols.shape
    assert n % tile == 0, f"N={n} must be padded to tile={tile}"
    grid = (n // tile,)
    n_instr = int(ops.shape[0])

    kernel = functools.partial(
        _policy_scan_kernel, n_instr=n_instr, max_stack=max_stack,
        size_col=size_col, blocks_col=blocks_col, valid_col=valid_col)

    mask, agg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_instr,), lambda i: (0,)),       # ops
            pl.BlockSpec((n_instr,), lambda i: (0,)),       # colidx
            pl.BlockSpec((n_instr,), lambda i: (0,)),       # operands
            pl.BlockSpec((n_cols, tile), lambda i: (0, i)),  # column tile
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),       # mask
            pl.BlockSpec((1, N_AGG), lambda i: (0, 0)),      # aggregates
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, N_AGG), jnp.float32),
        ],
        interpret=interpret,
    )(ops, colidx, operands, cols)
    return mask[0], agg[0]


def _policy_scan_batch_kernel(ops_ref, colidx_ref, operands_ref, cols_ref,
                              masks_ref, rule_ref, agg_ref, *, n_progs: int,
                              n_instr: int, max_stack: int, size_col: int,
                              blocks_col: int, valid_col: int):
    """Single-launch multi-program scan: the whole (R, P) program batch over
    one column tile, writing an (R, tile) mask block, the fused
    first-match-wins rule attribution, and per-program aggregates.

    Program 0 is the policy's combined criteria; programs 1..R-1 are the
    per-rule conditions in priority order. Both loops (programs × unrolled
    instructions) are static, so the whole matcher lowers to straight-line
    vector selects — one grid walk over the entry table replaces R kernel
    launches and the host-side attribution pass.
    """
    step = pl.program_id(0)
    cols = cols_ref[...]                       # (n_cols, tile) f32 in VMEM
    tile = cols.shape[1]

    rows = []
    for r in range(n_progs):                   # static unroll over programs
        mask = _eval_program_tile(
            cols, lambda i, r=r: (ops_ref[r, i], colidx_ref[r, i],
                                  operands_ref[r, i]),
            n_instr, max_stack)
        if valid_col >= 0:
            mask = mask * cols[valid_col]
        rows.append(mask)
    masks = jnp.stack(rows)                    # (R, tile)
    masks_ref[...] = masks

    # --- fused first-match-wins attribution (programs 1..R-1) -------------
    if n_progs > 1:
        rules = masks[1:] > 0.5                # (R-1, tile)
        first = jnp.argmax(rules, axis=0).astype(jnp.int32)
        att = jnp.where(jnp.any(rules, axis=0), first, -1)
    else:
        att = jnp.full((tile,), -1, jnp.int32)
    rule_ref[...] = att[None, :]

    # --- fused per-program aggregation ------------------------------------
    size = cols[size_col]
    spc = cols[blocks_col]
    count = jnp.sum(masks, axis=1)                         # (R,)
    volume = jnp.sum(masks * size[None, :], axis=1)        # (R,)
    spc_used = jnp.sum(masks * spc[None, :], axis=1)       # (R,)
    bucket = sum((size >= e).astype(jnp.int32) for e in _EDGE_VALS) - 1
    bucket = jnp.clip(bucket, 0, 9)
    iota10 = jax.lax.broadcasted_iota(jnp.int32, (10, tile), 0)
    onehot = (bucket[None, :] == iota10).astype(jnp.float32)   # (10, tile)
    hist = jax.lax.dot_general(masks, onehot,
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (R, 10)
    any_match = jnp.max(masks, axis=1)                     # (R,)
    agg = jnp.concatenate([count[:, None], volume[:, None],
                           spc_used[:, None], hist, any_match[:, None]],
                          axis=1)                          # (R, N_AGG)

    @pl.when(step == 0)
    def _init():
        agg_ref[...] = jnp.zeros_like(agg_ref)

    prev = agg_ref[...]
    acc = prev + agg
    # any_match is a max-, not sum-, accumulator
    agg_ref[...] = acc.at[:, N_AGG - 1].set(
        jnp.maximum(prev[:, N_AGG - 1], any_match))


def policy_scan_batch_pallas(cols: jax.Array, ops: jax.Array,
                             colidx: jax.Array, operands: jax.Array, *,
                             size_col: int = 0, blocks_col: int = 1,
                             valid_col: int = -1, tile: int = 8 * LANE,
                             max_stack: int = 8, interpret: bool = True
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """cols: (n_cols, N) f32, N % tile == 0; ops/colidx/operands: (R, P).

    Returns (masks (R, N) f32, rule_idx (N,) i32, agg (R, N_AGG) f32) from a
    single kernel launch.
    """
    n_cols, n = cols.shape
    assert n % tile == 0, f"N={n} must be padded to tile={tile}"
    n_progs, n_instr = int(ops.shape[0]), int(ops.shape[1])
    grid = (n // tile,)

    kernel = functools.partial(
        _policy_scan_batch_kernel, n_progs=n_progs, n_instr=n_instr,
        max_stack=max_stack, size_col=size_col, blocks_col=blocks_col,
        valid_col=valid_col)

    masks, rule, agg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_progs, n_instr), lambda i: (0, 0)),   # ops
            pl.BlockSpec((n_progs, n_instr), lambda i: (0, 0)),   # colidx
            pl.BlockSpec((n_progs, n_instr), lambda i: (0, 0)),   # operands
            pl.BlockSpec((n_cols, tile), lambda i: (0, i)),       # columns
        ],
        out_specs=[
            pl.BlockSpec((n_progs, tile), lambda i: (0, i)),      # masks
            pl.BlockSpec((1, tile), lambda i: (0, i)),            # rule idx
            pl.BlockSpec((n_progs, N_AGG), lambda i: (0, 0)),     # aggregates
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_progs, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((n_progs, N_AGG), jnp.float32),
        ],
        interpret=interpret,
    )(ops, colidx, operands, cols)
    return masks, rule[0], agg
