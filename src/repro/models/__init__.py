"""Model zoo: pattern-scanned backbone covering all assigned architectures."""
from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                     TRAIN_4K, LayerSpec, ModelConfig, MoeSpec, ShapeSpec,
                     is_subquadratic, shapes_for)
from .transformer import Model

__all__ = ["Model", "ModelConfig", "LayerSpec", "MoeSpec", "ShapeSpec",
           "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K", "is_subquadratic", "shapes_for"]
