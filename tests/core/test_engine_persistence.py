"""Incremental state persistence: an engine restart resumes incrementally
(match table + age-flip schedule serialized beside the sqlite mirror)
instead of paying a cold full scan."""
import os

import numpy as np
import pytest

from repro.core import (Catalog, Entry, FsType, PolicyDefinition,
                        PolicyEngine)
from repro.core.policy import PolicyError

NOW = 1_000_000.0


def _catalog(n=400, db_path=None):
    cat = Catalog(n_shards=2, db_path=db_path)
    cat.upsert_batch([
        Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}", type=FsType.FILE,
              size=(i % 40 + 1) * 1000, blocks=i % 40 + 1,
              owner=f"user{i % 3}", atime=NOW - float(i + 1))
        for i in range(n)])
    return cat


def _engine(cat, clock, rules=None, name="p"):
    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name=name, action=lambda e, p: True, scope="type == file",
        rules=rules or [("old", "last_access > 100s", {})],
        sort_by="atime", mutates=False))
    return eng


class _Clock:
    def __init__(self, t=NOW):
        self.t = t

    def __call__(self):
        return self.t


def test_save_load_roundtrip_resumes_incrementally(tmp_path):
    path = str(tmp_path / "state.npz")
    cat = _catalog()
    clock = _Clock()
    eng = _engine(cat, clock)
    eng.enable_incremental()
    assert eng.run("p").mode == "full"          # prime the cache
    assert eng.save_incremental(path) == path

    # churn while the engine is "down"
    cat.update_fields(3, atime=NOW)             # young again -> unmatches
    cat.remove(7)
    cat.upsert(Entry(fid=9000, name="n", path="/p/n", type=FsType.FILE,
                     size=5000, atime=NOW - 900))

    eng2 = _engine(cat, clock)
    assert eng2.load_incremental(path) == ["p"]
    eng2.mark_dirty([3, 7, 9000])               # re-delivered deltas
    clock.t = NOW + 50
    r = eng2.run("p", matching="incremental")   # NO cold full scan
    assert r.mode == "incremental"
    assert r.reval < len(cat)

    r_full = _engine(cat, _Clock(NOW + 50)).run("p")
    assert r_full.mode == "full"
    assert (r.matched, r.succeeded, r.volume) == \
        (r_full.matched, r_full.succeeded, r_full.volume)


def test_flip_schedule_survives_restart(tmp_path):
    """Age flips due after the restart still fire without any delta."""
    path = str(tmp_path / "state.npz")
    cat = _catalog(50)
    clock = _Clock()
    eng = _engine(cat, clock)
    eng.enable_incremental()
    r0 = eng.run("p")
    eng.save_incremental(path)

    eng2 = _engine(cat, clock)
    assert eng2.load_incremental(path) == ["p"]
    clock.t = NOW + 80                    # ages 21..50 cross the 100s line
    r = eng2.run("p", matching="incremental")
    assert r.mode == "incremental"
    r_full = _engine(cat, clock).run("p")
    assert r.matched == r_full.matched > r0.matched


def test_changed_definition_is_not_resumed(tmp_path):
    path = str(tmp_path / "state.npz")
    cat = _catalog()
    eng = _engine(cat, _Clock())
    eng.enable_incremental()
    eng.run("p")
    eng.save_incremental(path)

    changed = _engine(cat, _Clock(),
                      rules=[("old", "last_access > 999s", {})])
    assert changed.load_incremental(path) == []     # signature mismatch
    assert changed.run("p").mode == "full"          # safe cold start


def test_unregistered_policy_and_missing_file(tmp_path):
    path = str(tmp_path / "state.npz")
    cat = _catalog(50)
    eng = _engine(cat, _Clock())
    assert eng.load_incremental(path) == []         # missing file: no-op
    eng.enable_incremental()
    eng.run("p")
    eng.save_incremental(path)
    other = PolicyEngine(cat, clock=_Clock())
    other.register(PolicyDefinition.from_config(
        name="q", action=lambda e, p: True, scope="true", mutates=False))
    assert other.load_incremental(path) == []       # "p" not registered


def test_undrained_dirty_fids_survive(tmp_path):
    path = str(tmp_path / "state.npz")
    cat = _catalog(60)
    clock = _Clock()
    eng = _engine(cat, clock)
    eng.enable_incremental()
    eng.run("p")
    cat.update_fields(5, atime=NOW)
    eng.mark_dirty([5])                   # noted but never drained by a run
    eng.save_incremental(path)

    eng2 = _engine(cat, clock)
    eng2.load_incremental(path)
    r = eng2.run("p", matching="incremental")
    assert r.reval >= 1                   # fid 5 was re-evaluated
    r_full = _engine(cat, clock).run("p")
    assert r.matched == r_full.matched


def test_default_path_requires_db_or_explicit(tmp_path):
    cat = _catalog(10)
    eng = _engine(cat, _Clock())
    eng.enable_incremental()
    eng.run("p")
    with pytest.raises(PolicyError):
        eng.save_incremental()            # no sqlite mirror, no path
    db = str(tmp_path / "cat.sqlite")
    cat2 = _catalog(10, db_path=db)
    eng2 = _engine(cat2, _Clock())
    eng2.enable_incremental()
    eng2.run("p")
    out = eng2.save_incremental()
    assert out == db + ".incstate.npz" and os.path.exists(out)
