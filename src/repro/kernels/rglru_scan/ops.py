"""Public RG-LRU scan op."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rglru_pallas
from .ref import rglru_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def rglru_scan(log_a, b, h0=None, use_kernel: bool = True):
    if h0 is None:
        h0 = jnp.zeros((log_a.shape[0], log_a.shape[2]), jnp.float32)
    if not use_kernel:
        return rglru_ref(log_a, b, h0)
    return rglru_pallas(log_a, b, h0, interpret=not _on_tpu())
