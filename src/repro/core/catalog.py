"""Columnar, sharded metadata catalog — the paper's "database" (C1).

The paper stores the filesystem-metadata mirror in MySQL and observes
(SIII-B) that a single DB host becomes the bottleneck once DNE spreads the
namespace over several MDSes; it names catalog *sharding* as the way out.
This implementation builds that future directly:

* entries live in N independent **shards** (hash of fid), each with its own
  lock, so concurrent changelog streams (one per MDT) never contend;
* each shard is **columnar** (struct-of-arrays, numpy): policy predicates and
  report aggregations run as vectorized column masks — the in-process
  analogue of a DB table scan, and the exact memory layout consumed by the
  ``policy_scan`` Pallas kernel on TPU;
* durability is sqlite WAL (optional): a batch of updates is committed to
  sqlite *before* the changelog reader acks, preserving the paper's
  transactional contract (SII-C2).

Strings (owner, group, pool, status) are interned to int32 codes in a shared
:class:`StringTable`, which is what makes vectorized/accelerator predicate
evaluation possible.
"""
from __future__ import annotations

import os
import sqlite3
import threading
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .types import Entry, FsType, HsmState

# Stats/alert hooks receive these light tuples instead of full Entries.
# (owner_code, group_code, type, size, blocks, hsm_state)
Delta = Tuple[int, int, int, int, int, int]

_NUMERIC_COLUMNS: Tuple[Tuple[str, np.dtype], ...] = (
    ("fid", np.int64),
    ("parent_fid", np.int64),
    ("type", np.int8),
    ("size", np.int64),
    ("blocks", np.int64),
    ("mode", np.int32),
    ("nlink", np.int32),
    ("atime", np.float64),
    ("mtime", np.float64),
    ("ctime", np.float64),
    ("ost_idx", np.int16),
    ("hsm_state", np.int8),
    ("archive_id", np.int32),
    ("owner", np.int32),     # interned code
    ("group", np.int32),     # interned code
    ("pool", np.int32),      # interned code
    ("status", np.int32),    # interned code (v3 generic-policy status)
    ("dirty", np.int8),
)
_STRING_FIELDS = ("owner", "group", "pool", "status")


class StringTable:
    """Bidirectional string<->int32 interning table (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._to_code: Dict[str, int] = {}
        self._to_str: List[str] = []
        self.intern("")  # code 0 is always the empty string

    def intern(self, s: str) -> int:
        with self._lock:
            code = self._to_code.get(s)
            if code is None:
                code = len(self._to_str)
                self._to_code[s] = code
                self._to_str.append(s)
            return code

    def lookup(self, code: int) -> str:
        return self._to_str[code]

    def code_of(self, s: str) -> Optional[int]:
        return self._to_code.get(s)

    def __len__(self) -> int:
        return len(self._to_str)


class CatalogShard:
    """One catalog shard: columnar entry store with amortized growth."""

    _INITIAL = 1024

    def __init__(self, shard_id: int, strings: StringTable) -> None:
        self.shard_id = shard_id
        self.strings = strings
        self.lock = threading.RLock()
        self._rows: Dict[int, int] = {}          # fid -> row index
        self._free: List[int] = []
        self._n = 0                               # high-water row count
        self._cols: Dict[str, np.ndarray] = {
            name: np.zeros(self._INITIAL, dtype=dt) for name, dt in _NUMERIC_COLUMNS
        }
        self._valid = np.zeros(self._INITIAL, dtype=bool)
        self._names: List[str] = [""] * self._INITIAL
        self._paths: List[str] = [""] * self._INITIAL
        self._xattrs: List[Optional[dict]] = [None] * self._INITIAL
        self._stripes: List[tuple] = [()] * self._INITIAL

    # -- storage management -------------------------------------------------
    def _grow(self) -> None:
        cap = len(self._valid)
        new_cap = cap * 2
        for name in self._cols:
            col = np.zeros(new_cap, dtype=self._cols[name].dtype)
            col[:cap] = self._cols[name]
            self._cols[name] = col
        valid = np.zeros(new_cap, dtype=bool)
        valid[:cap] = self._valid
        self._valid = valid
        self._names.extend([""] * cap)
        self._paths.extend([""] * cap)
        self._xattrs.extend([None] * cap)
        self._stripes.extend([()] * cap)

    def _alloc_row(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n >= len(self._valid):
            self._grow()
        row = self._n
        self._n += 1
        return row

    # -- entry operations ---------------------------------------------------
    def _row_delta(self, row: int) -> Delta:
        c = self._cols
        return (int(c["owner"][row]), int(c["group"][row]), int(c["type"][row]),
                int(c["size"][row]), int(c["blocks"][row]),
                int(c["hsm_state"][row]))

    def upsert(self, e: Entry) -> Tuple[Optional[Delta], Delta]:
        """Insert or update an entry; returns (old_delta|None, new_delta)."""
        with self.lock:
            row = self._rows.get(e.fid)
            old: Optional[Delta] = None
            if row is None:
                row = self._alloc_row()
                self._rows[e.fid] = row
                self._valid[row] = True
            else:
                old = self._row_delta(row)
            c = self._cols
            c["fid"][row] = e.fid
            c["parent_fid"][row] = e.parent_fid
            c["type"][row] = int(e.type)
            c["size"][row] = e.size
            c["blocks"][row] = e.blocks
            c["mode"][row] = e.mode
            c["nlink"][row] = e.nlink
            c["atime"][row] = e.atime
            c["mtime"][row] = e.mtime
            c["ctime"][row] = e.ctime
            c["ost_idx"][row] = e.ost_idx
            c["hsm_state"][row] = int(e.hsm_state)
            c["archive_id"][row] = e.archive_id
            c["owner"][row] = self.strings.intern(e.owner)
            c["group"][row] = self.strings.intern(e.group)
            c["pool"][row] = self.strings.intern(e.pool)
            c["status"][row] = self.strings.intern(e.status)
            c["dirty"][row] = 1 if e.dirty else 0
            self._names[row] = e.name
            self._paths[row] = e.path
            self._xattrs[row] = dict(e.xattrs) if e.xattrs else None
            self._stripes[row] = tuple(e.stripe_osts)
            return old, self._row_delta(row)

    def update_fields(self, fid: int, **fields) -> Optional[Tuple[Delta, Delta]]:
        """Patch a subset of attributes; returns (old, new) deltas or None."""
        with self.lock:
            row = self._rows.get(fid)
            if row is None:
                return None
            old = self._row_delta(row)
            c = self._cols
            for k, v in fields.items():
                if k in ("name",):
                    self._names[row] = v
                elif k in ("path",):
                    self._paths[row] = v
                elif k == "xattrs":
                    self._xattrs[row] = dict(v) if v else None
                elif k == "stripe_osts":
                    self._stripes[row] = tuple(v)
                elif k in _STRING_FIELDS:
                    c[k][row] = self.strings.intern(v)
                elif k == "hsm_state":
                    c[k][row] = int(v)
                elif k == "type":
                    c[k][row] = int(v)
                elif k == "dirty":
                    c[k][row] = 1 if v else 0
                else:
                    c[k][row] = v
            return old, self._row_delta(row)

    def remove(self, fid: int) -> Optional[Delta]:
        with self.lock:
            row = self._rows.pop(fid, None)
            if row is None:
                return None
            old = self._row_delta(row)
            self._valid[row] = False
            self._names[row] = self._paths[row] = ""
            self._xattrs[row] = None
            self._stripes[row] = ()
            self._free.append(row)
            return old

    def get(self, fid: int) -> Optional[Entry]:
        with self.lock:
            row = self._rows.get(fid)
            if row is None:
                return None
            return self._entry_at(row)

    def _entry_at(self, row: int) -> Entry:
        c = self._cols
        return Entry(
            fid=int(c["fid"][row]), parent_fid=int(c["parent_fid"][row]),
            name=self._names[row], path=self._paths[row],
            type=FsType(int(c["type"][row])), size=int(c["size"][row]),
            blocks=int(c["blocks"][row]), mode=int(c["mode"][row]),
            nlink=int(c["nlink"][row]), atime=float(c["atime"][row]),
            mtime=float(c["mtime"][row]), ctime=float(c["ctime"][row]),
            ost_idx=int(c["ost_idx"][row]),
            stripe_osts=self._stripes[row],
            pool=self.strings.lookup(int(c["pool"][row])),
            hsm_state=HsmState(int(c["hsm_state"][row])),
            archive_id=int(c["archive_id"][row]),
            owner=self.strings.lookup(int(c["owner"][row])),
            group=self.strings.lookup(int(c["group"][row])),
            status=self.strings.lookup(int(c["status"][row])),
            xattrs=self._xattrs[row] or {},
            dirty=bool(c["dirty"][row]),
        )

    # -- vectorized access ----------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        """Columnar views (copies) limited to valid rows, for vector queries."""
        with self.lock:
            valid = self._valid[: self._n]
            out = {name: self._cols[name][: self._n][valid].copy()
                   for name in self._cols}
            idx = np.nonzero(valid)[0]
            out["_paths"] = [self._paths[i] for i in idx]   # type: ignore
            out["_names"] = [self._names[i] for i in idx]   # type: ignore
            return out

    def count(self) -> int:
        with self.lock:
            return len(self._rows)

    def fids(self) -> List[int]:
        with self.lock:
            return list(self._rows.keys())


class Catalog:
    """Sharded catalog facade: routing, hooks, persistence, vector queries."""

    def __init__(self, n_shards: int = 4, db_path: Optional[str] = None) -> None:
        self.strings = StringTable()
        self.shards = [CatalogShard(i, self.strings) for i in range(n_shards)]
        self.n_shards = n_shards
        self._hooks: List[Callable[[Optional[Delta], Optional[Delta]], None]] = []
        self._entry_hooks: List[Callable[[Entry], None]] = []
        self.db_path = db_path
        self._db: Optional[sqlite3.Connection] = None
        self._db_lock = threading.Lock()
        if db_path:
            self._open_db(db_path)

    # -- persistence ----------------------------------------------------------
    _SCHEMA = (
        "CREATE TABLE IF NOT EXISTS entries ("
        "fid INTEGER PRIMARY KEY, parent_fid INTEGER, name TEXT, path TEXT,"
        "type INTEGER, size INTEGER, blocks INTEGER, owner TEXT, grp TEXT,"
        "mode INTEGER, nlink INTEGER, atime REAL, mtime REAL, ctime REAL,"
        "ost_idx INTEGER, pool TEXT, hsm_state INTEGER, archive_id INTEGER,"
        "status TEXT, dirty INTEGER)"
    )

    def _open_db(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._db = sqlite3.connect(path, check_same_thread=False)
        self._db.execute("PRAGMA journal_mode=WAL")
        self._db.execute("PRAGMA synchronous=NORMAL")
        self._db.execute(self._SCHEMA)
        self._db.commit()

    def _persist(self, entries: Sequence[Entry], removed: Sequence[int]) -> None:
        if self._db is None:
            return
        with self._db_lock:
            if entries:
                self._db.executemany(
                    "INSERT OR REPLACE INTO entries VALUES "
                    "(?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?)",
                    [(e.fid, e.parent_fid, e.name, e.path, int(e.type), e.size,
                      e.blocks, e.owner, e.group, e.mode, e.nlink, e.atime,
                      e.mtime, e.ctime, e.ost_idx, e.pool, int(e.hsm_state),
                      e.archive_id, e.status, int(e.dirty)) for e in entries],
                )
            if removed:
                self._db.executemany("DELETE FROM entries WHERE fid=?",
                                     [(f,) for f in removed])
            self._db.commit()   # durable before changelog ack

    def load_from_db(self) -> int:
        """Crash recovery: repopulate shards from sqlite. Returns #entries."""
        if self._db is None:
            return 0
        n = 0
        with self._db_lock:
            cur = self._db.execute("SELECT * FROM entries")
            rows = cur.fetchall()
        for r in rows:
            e = Entry(fid=r[0], parent_fid=r[1], name=r[2], path=r[3],
                      type=FsType(r[4]), size=r[5], blocks=r[6], owner=r[7],
                      group=r[8], mode=r[9], nlink=r[10], atime=r[11],
                      mtime=r[12], ctime=r[13], ost_idx=r[14], pool=r[15],
                      hsm_state=HsmState(r[16]), archive_id=r[17],
                      status=r[18], dirty=bool(r[19]))
            self.upsert(e, persist=False)
            n += 1
        return n

    # -- hooks (stats aggregators, alerts) -------------------------------------
    def add_delta_hook(self, fn: Callable[[Optional[Delta], Optional[Delta]], None]) -> None:
        self._hooks.append(fn)

    def add_entry_hook(self, fn: Callable[[Entry], None]) -> None:
        """Entry-level hook (alerts need names/paths, not just deltas)."""
        self._entry_hooks.append(fn)

    def _fire(self, old: Optional[Delta], new: Optional[Delta]) -> None:
        for fn in self._hooks:
            fn(old, new)

    # -- routing ----------------------------------------------------------------
    def shard_of(self, fid: int) -> CatalogShard:
        return self.shards[fid % self.n_shards]

    # -- operations ---------------------------------------------------------------
    def upsert(self, e: Entry, persist: bool = True) -> None:
        old, new = self.shard_of(e.fid).upsert(e)
        self._fire(old, new)
        for fn in self._entry_hooks:
            fn(e)
        if persist:
            self._persist([e], [])

    def upsert_batch(self, entries: Sequence[Entry]) -> None:
        """Apply a batch then durably commit — callers ack changelog after."""
        for e in entries:
            old, new = self.shard_of(e.fid).upsert(e)
            self._fire(old, new)
            for fn in self._entry_hooks:
                fn(e)
        self._persist(entries, [])

    def update_fields(self, fid: int, **fields) -> bool:
        res = self.shard_of(fid).update_fields(fid, **fields)
        if res is None:
            return False
        self._fire(res[0], res[1])
        if self._db is not None:
            e = self.get(fid)
            if e is not None:
                self._persist([e], [])
        return True

    def remove(self, fid: int, persist: bool = True) -> bool:
        old = self.shard_of(fid).remove(fid)
        if old is None:
            return False
        self._fire(old, None)
        if persist:
            self._persist([], [fid])
        return True

    def get(self, fid: int) -> Optional[Entry]:
        return self.shard_of(fid).get(fid)

    def __len__(self) -> int:
        return sum(s.count() for s in self.shards)

    def entries(self) -> Iterator[Entry]:
        for s in self.shards:
            for fid in s.fids():
                e = s.get(fid)
                if e is not None:
                    yield e

    # -- vectorized queries ----------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        """Concatenate all shards' columns (the full 'table')."""
        per_shard = [s.arrays() for s in self.shards]
        out: Dict[str, np.ndarray] = {}
        for name, _ in _NUMERIC_COLUMNS:
            out[name] = np.concatenate([p[name] for p in per_shard]) \
                if per_shard else np.zeros(0)
        out["_paths"] = sum((p["_paths"] for p in per_shard), [])  # type: ignore
        out["_names"] = sum((p["_names"] for p in per_shard), [])  # type: ignore
        return out

    def query_fids(self, mask_fn: Callable[[Dict[str, np.ndarray]], np.ndarray]) -> np.ndarray:
        """Vectorized query: mask_fn(columns)->bool mask; returns matching fids."""
        cols = self.arrays()
        mask = mask_fn(cols)
        return cols["fid"][mask]
