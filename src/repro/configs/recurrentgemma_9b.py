"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, local) 1:2.

38 = 12 complete (rec, rec, attn) superblocks + 2 tail recurrent blocks.
[arXiv:2402.19427]
"""
from repro.models.config import (ATTN_LOCAL, MIX_RGLRU, LayerSpec,
                                 ModelConfig)

_PATTERN = (LayerSpec(mix=MIX_RGLRU), LayerSpec(mix=MIX_RGLRU),
            LayerSpec(mix=ATTN_LOCAL))

CONFIG = ModelConfig(
    name="recurrentgemma_9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, head_dim=256,
    d_ff=12288, vocab=256000,
    pattern=_PATTERN, window=2048,
    embed_scale=True, tie_embeddings=True, d_rnn=4096, conv_width=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma_9b_smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv=1, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN, window=16,
    embed_scale=True, tie_embeddings=True, d_rnn=64, conv_width=4,
)
