"""`rbh-report` / `rbh-find` / `rbh-du` clones (C6, C9) — answer from the DB.

All queries here run against the catalog (vectorized column masks), the
pre-aggregated stats, or the on-device profile cube — never against the
filesystem, which is the paper's point: *"all these metadata queries do not
generate extra load on the filesystem"*.

With :meth:`Reports.attach_device_store`, ``find``/``top_files``/``du``
additionally go **mesh-resident**: predicates evaluate and top-k/range
aggregates reduce over the device store's sharded column blocks under
``shard_map``, and only the winning rows' paths come back through the
store's host mirrors — a warm query never calls ``Catalog.arrays()``.
Queries the resident plane cannot serve (glob predicates, non-kernel
columns) raise :class:`~repro.core.policy.PolicyError` inside the store
and fall back to the host folds below, which also stay on as the
byte-identical differential oracle (``tests/core/test_mesh_reports.py``).
The fallback is recorded in :attr:`Reports.last_fallback_reason`.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from .catalog import Catalog
from .policy import Expr, KERNEL_COLUMNS, PolicyError, parse_expr
from .profiles import ProfileCube
from .stats import DirUsage, StatsAggregator
from .types import FsType, format_size


class _PathIndex:
    """Sorted path column + subtree prefix sums for O(log n) ``du``.

    Built once per **shard** version: every path under ``prefix/`` is
    contiguous in the sorted order — bounded below by ``prefix + "/"`` and
    above by ``prefix + "0"`` ('0' is the successor of '/') — so a subtree
    aggregate is two binary searches into precomputed prefix sums instead
    of a per-path scan.
    """

    def __init__(self, cols) -> None:
        paths = np.asarray(cols["_paths"])
        order = np.argsort(paths, kind="stable")
        self.spaths = paths[order]
        is_file = (cols["type"][order] == int(FsType.FILE))
        fsize = np.where(is_file, cols["size"][order], 0)
        fblocks = np.where(is_file, cols["blocks"][order], 0)
        # leading 0 so any [lo, hi) range sum is csum[hi] - csum[lo]
        self.csize = np.concatenate([[0], np.cumsum(fsize)])
        self.cblocks = np.concatenate([[0], np.cumsum(fblocks)])
        self.cfiles = np.concatenate([[0], np.cumsum(is_file.astype(np.int64))])

    def _range(self, lo_key: str, hi_key: str, side_hi: str = "left") -> dict:
        lo = int(np.searchsorted(self.spaths, lo_key, side="left"))
        hi = int(np.searchsorted(self.spaths, hi_key, side=side_hi))
        return {
            "count": hi - lo,
            "files": int(self.cfiles[hi] - self.cfiles[lo]),
            "volume": int(self.csize[hi] - self.csize[lo]),
            "spc_used": int(self.cblocks[hi] - self.cblocks[lo]),
        }

    def du(self, path_prefix: str) -> dict:
        prefix = path_prefix.rstrip("/")
        sub = self._range(prefix + "/", prefix + "0")
        root = self._range(prefix, prefix, side_hi="right")
        return {k: sub[k] + root[k] for k in sub}


class Reports:
    def __init__(self, catalog: Catalog, stats: Optional[StatsAggregator] = None,
                 clock=time.time, profiles: Optional[ProfileCube] = None
                 ) -> None:
        self.catalog = catalog
        self.stats = stats
        self.profiles = profiles
        self.clock = clock
        # one path index per shard, rebuilt only when THAT shard's version
        # ticked — churn in one shard leaves the other indexes warm
        self._pindexes: Dict[int, _PathIndex] = {}
        self._pversions: Dict[int, int] = {}
        self.index_rebuilds = 0
        # mesh-resident serving (attach_device_store): counters mirror the
        # engine's RunReport telemetry — store_served / host_served tally
        # where each query answered, last_fallback_reason says why the
        # most recent query fell back to the host fold (None = none did)
        self.device_store = None
        self.store_served = 0
        self.host_served = 0
        self.last_fallback_reason: Optional[str] = None

    def attach_device_store(self, store) -> "Reports":
        """Serve ``find``/``top_files``/``du`` from a
        :class:`~repro.core.device_store.DeviceColumnStore`.

        Enables the store's reports plane (sorted-path rank row + host
        path mirrors beside the resident columns). Host folds stay
        available as the automatic fallback for queries the plane cannot
        express — and as the differential oracle.
        """
        if store.catalog is not self.catalog:
            raise ValueError("device store is bound to a different catalog")
        store.enable_reports_plane()
        self.device_store = store
        return self

    def _shard_indexes(self) -> List[_PathIndex]:
        """(Re)build the per-shard sorted path indexes that went stale.

        A rebuild snapshots only the columns the index reads (type/size/
        blocks + the path gather) — not the shard's full column stack.
        """
        out = []
        for sid, shard in enumerate(self.catalog.shards):
            version = shard.version
            if self._pversions.get(sid) != version:
                cols, snap = shard.snapshot(names=("type", "size", "blocks"))
                cols["_paths"] = snap.gather("_paths")  # type: ignore
                self._pindexes[sid] = _PathIndex(cols)
                self._pversions[sid] = version
                self.index_rebuilds += 1
            out.append(self._pindexes[sid])
        return out

    # -- rbh-report ---------------------------------------------------------------
    def _backend(self):
        if self.profiles is not None:
            return self.profiles
        if self.stats is None:
            raise RuntimeError("no stats aggregator or profile cube attached")
        return self.stats

    def report_user(self, user: str) -> List[dict]:
        """O(1) per-user summary (pre-aggregated / profile cube)."""
        return self._backend().report_user(user)

    def report_group(self, grp: str) -> List[dict]:
        return self._backend().report_group(grp)

    def report_types(self) -> Dict[str, dict]:
        return self._backend().report_types()

    def report_hsm(self) -> Dict[str, dict]:
        return self._backend().report_hsm()

    def user_size_profile(self, user: str) -> Dict[str, int]:
        return self._backend().user_size_profile(user)

    def top_users(self, by: str = "volume", k: int = 10,
                  type_: FsType = FsType.FILE) -> List[dict]:
        return self._backend().top_users(by=by, k=k, type_=type_)

    def age_profile(self, user: Optional[str] = None) -> Dict[str, dict]:
        """Data-age profile (profile-cube only — the scalar aggregator
        keeps no age axis)."""
        if self.profiles is None:
            raise RuntimeError("age profiles need an attached ProfileCube")
        return self.profiles.age_profile(user)

    def format_user_report(self, user: str) -> str:
        rows = self.report_user(user)
        lines = ["user, type, count, spc_used, avg_size"]
        for r in rows:
            lines.append(f"{r['user']}, {r['type']}, {r['count']}, "
                         f"{format_size(r['spc_used'])}, "
                         f"{format_size(r['avg_size'])}")
        return "\n".join(lines)

    # -- rbh-find -----------------------------------------------------------------
    def find(self, criteria: str, limit: int = 0) -> List[str]:
        """DB-backed `find`: returns matching paths.

        Store-backed when a device store is attached: the predicate runs
        as one mesh program over the resident columns and only winning
        rows' paths return (same order as the host fold). Predicates the
        kernel can't compile (e.g. name globs) fall back to the host."""
        expr = parse_expr(criteria)
        if self.device_store is not None:
            try:
                out = self.device_store.find_paths(expr, self.clock(),
                                                   limit=limit)
                self.store_served += 1
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"find: {exc}"
        self.host_served += 1
        cols = self.catalog.arrays()
        mask = expr.mask(cols, self.catalog.strings, self.clock())
        idx = np.nonzero(mask)[0]
        if limit:
            idx = idx[:limit]
        paths = cols["_paths"]
        return [paths[i] for i in idx]

    # -- rbh-du --------------------------------------------------------------------
    def du(self, path_prefix: str) -> dict:
        """DB-backed `du -s`: subtree aggregate via sorted-prefix-range.

        Answers from per-shard sorted path indexes + prefix sums cached
        per :attr:`CatalogShard.version` — two binary searches per shard
        per query, rebuilding only the indexes of shards that churned
        (see ``benchmarks/bench_find_du.py``).

        Store-backed when a device store is attached: rank bounds from
        the host path mirrors, one fused on-device range-aggregate psum.
        """
        if self.device_store is not None:
            try:
                out = self.device_store.du(path_prefix)
                self.store_served += 1
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"du: {exc}"
        self.host_served += 1
        out = {"count": 0, "files": 0, "volume": 0, "spc_used": 0}
        for index in self._shard_indexes():
            part = index.du(path_prefix)
            for k in out:
                out[k] += part[k]
        return out

    def du_many(self, path_prefixes: List[str]) -> List[dict]:
        """Batched `du -s`: one index refresh amortized over many subtrees
        (the store-backed path needs no host index prefetch)."""
        if self.device_store is None:
            self._shard_indexes()
        return [self.du(p) for p in path_prefixes]

    def bind_dir_usage(self, du: DirUsage) -> DirUsage:
        """Route a :class:`DirUsage`'s deeper-than-``max_depth`` queries to
        the index-backed :meth:`du` (the documented depth contract)."""
        du.deep_du = self.du
        return du

    # -- top-N listings (paper SII-B3) ----------------------------------------------
    def top_files(self, by: str = "size", k: int = 10,
                  desc: bool = True) -> List[dict]:
        """Top-N files by any kernel column (size/atime/...), exact ties.

        Store-backed when a device store is attached: per-device top-k
        establishes the global threshold, a mask pass recovers every
        candidate (incl. cross-device ties), and only those rows' paths
        come back — ordering matches the host fold byte-for-byte."""
        if self.device_store is not None and by in KERNEL_COLUMNS:
            try:
                out = self.device_store.top_files(by=by, k=k, desc=desc,
                                                  now=self.clock())
                self.store_served += 1
                return out
            except PolicyError as exc:
                self.last_fallback_reason = f"top_files: {exc}"
        self.host_served += 1
        cols = self.catalog.arrays()
        fidx = np.nonzero(cols["type"] == int(FsType.FILE))[0]
        vals = cols[by][fidx]
        if vals.size == 0:
            return []
        k = min(k, vals.size)
        order = np.argsort(vals, kind="stable")
        order = order[::-1][:k] if desc else order[:k]
        paths = cols["_paths"]
        return [{"path": paths[fidx[o]], by: float(vals[o]),
                 "fid": int(cols["fid"][fidx[o]])} for o in order]

    def top_dirs_by_count(self, k: int = 10) -> List[dict]:
        """Top directories by direct child count (one vector groupby)."""
        cols = self.catalog.arrays()
        parents = cols["parent_fid"]
        uniq, counts = np.unique(parents[parents >= 0], return_counts=True)
        if uniq.size == 0:
            return []
        k = min(k, uniq.size)
        top = np.argsort(counts)[::-1][:k]
        out = []
        for i in top:
            e = self.catalog.get(int(uniq[i]))
            out.append({"path": e.path if e else f"fid:{int(uniq[i])}",
                        "children": int(counts[i])})
        return out

    def oldest_files(self, k: int = 10) -> List[dict]:
        return self.top_files(by="atime", k=k, desc=False)
