"""Pallas TPU kernel: decode attention over HSM-tiered KV pages.

Serving hot spot of the Robinhood adaptation: the hot tier of the KV cache
lives as fixed-size pages in a global pool (kvcache/paged.py); sequences
reference pages through a page table, so K/V for one sequence are NOT
contiguous in HBM. This kernel walks the page list with an online-softmax
accumulator, one (page, kv-head-group) block at a time.

Tiling:
* grid = (B, max_pages): each step processes one page of one sequence;
* q block (1, H, hd) VMEM — revisited across the page axis;
* page K/V blocks (1, P, K, hd) VMEM, selected through the page table via
  the BlockSpec index_map (scalar-prefetch style indirection: the page id
  lookup happens at block-fetch time, the kernel body never sees HBM);
* accumulators (m, l, acc) carried in VMEM across grid steps of the same
  sequence (axis 1 is the reduction axis).

Dims: hd is lane-aligned (128/256 for the assigned archs); P defaults to
64 sublanes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, page_size: int, G: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page_id = pt_ref[b, j]
    length = len_ref[b]
    valid_page = page_id >= 0

    q = q_ref[0].astype(jnp.float32)              # (H, hd)
    hd = q.shape[-1]
    k = k_ref[0].astype(jnp.float32)              # (P, K, hd)
    v = v_ref[0].astype(jnp.float32)
    if G > 1:
        k = jnp.repeat(k, G, axis=1)              # (P, H, hd)
        v = jnp.repeat(v, G, axis=1)

    s = jnp.einsum("hd,phd->hp", q / jnp.sqrt(float(hd)), k)  # (H, P)
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    ok = (pos < length) & valid_page
    s = jnp.where(ok, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[0], l_ref[0], acc_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))          # (H,)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s > -0.5e30, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_new = acc_prev * corr[:, None] + jnp.einsum("hp,phd->hd", p, v)
    m_ref[0], l_ref[0], acc_ref[0] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-20)[:, None]
                    ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """q: (B,H,hd); pages: (n_pages,P,K,hd); table: (B,max_pages) int32."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, hd = q.shape
    n_pages, P, K, _ = k_pages.shape
    G = H // K
    max_pages = page_table.shape[1]

    kernel = functools.partial(_paged_attn_kernel, page_size=P, G=G)

    def page_map(b, j, pt, ln):
        return (jnp.maximum(pt[b, j], 0), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, P, K, hd), page_map),
            pl.BlockSpec((1, P, K, hd), page_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, pt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, H), jnp.float32),        # m
            pltpu.VMEM((1, H), jnp.float32),        # l
            pltpu.VMEM((1, H, hd), jnp.float32),    # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
