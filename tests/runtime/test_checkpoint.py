"""Checkpoint lifecycle: atomicity, retention policies, undelete, recovery."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager


def _state(step):
    return {"params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.arange(3.0)},
            "step": jnp.int32(step)}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=5)
    cm.save(_state(1), 1)
    cm.save(_state(2), 2)
    restored, step = cm.restore(like=_state(0))
    assert step == 2
    assert float(restored["params"]["w"][0, 0]) == 2.0
    restored1, _ = cm.restore(like=_state(0), step=1)
    assert float(restored1["params"]["w"][0, 0]) == 1.0


def test_atomic_no_partial_checkpoints(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"))
    cm.save(_state(1), 1)
    # simulate a crash mid-write: stage dir left behind without manifest
    stale = str(tmp_path / "ck" / "ckpt_00000002.tmp")
    os.makedirs(stale)
    with open(os.path.join(stale, "shard_00000.npy"), "wb") as f:
        f.write(b"garbage")
    assert cm.steps() == [1]               # partial write invisible
    restored, step = cm.restore(like=_state(0))
    assert step == 1


def test_retention_keep_archive_trash(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=2,
                           archive_every=4, trash_capacity=2)
    for s in range(1, 9):
        cm.save(_state(s), s)
    live = cm.steps()
    assert live[-2:] == [7, 8] and len(live) == 2
    cold = cm.steps(include_cold=True)
    assert 4 in cold and 8 in cold         # every-4th archived to cold tier
    # archived checkpoints restorable
    r, step = cm.restore(like=_state(0), step=4)
    assert float(r["params"]["w"][0, 0]) == 4.0


def test_undelete(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=1,
                           trash_capacity=5)
    for s in (1, 2, 3):
        cm.save(_state(s), s)
    assert cm.steps() == [3]
    assert cm.undelete(2)                  # bring step 2 back from trash
    assert 2 in cm.steps()
    r, _ = cm.restore(like=_state(0), step=2)
    assert float(r["params"]["w"][0, 0]) == 2.0


def test_artifact_catalog_tracks_shards(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"), keep_last=3)
    cm.save(_state(1), 1)
    usage = cm.store.usage()
    assert usage["count"] >= 3             # 2 shards + manifest
    # disaster recovery: rebuild the artifact catalog by rescanning
    cm.store.catalog = type(cm.store.catalog)(n_shards=2)
    from repro.core.stats import StatsAggregator
    cm.store.stats = StatsAggregator(cm.store.catalog.strings)
    cm.store.catalog.add_delta_hook(cm.store.stats.on_delta)
    n = cm.store.rescan()
    assert n >= 3


def test_dtype_and_structure_checks(tmp_path):
    cm = CheckpointManager(str(tmp_path / "ck"))
    state = {"params": {"w": jnp.ones((2, 2), jnp.bfloat16)}}
    cm.save(state, 1)
    restored, _ = cm.restore(like=state)
    assert restored["params"]["w"].dtype == jnp.bfloat16
    with pytest.raises(AssertionError):
        cm.restore(like={"params": {"w": 1, "extra": 2}})
