"""Changelog-consumption pipeline: sync + async dirty-tag modes (C4/C11)."""
import time

from repro.core import (Catalog, ChangelogCounters, EventPipeline,
                        PipelineConfig, Scanner)
from repro.fs import LustreSim


def _fs_with_files(n=30):
    fs = LustreSim(n_mdts=1)
    d = fs.mkdir(fs.root_fid(), "dir")
    fids = []
    for i in range(n):
        f = fs.create(d, f"f{i}", owner="u", uid="u")
        fs.write(f, 100 * (i + 1))
        fids.append(f)
    return fs, d, fids


def test_sync_pipeline_mirrors_fs():
    fs, d, fids = _fs_with_files()
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    n = pipe.process_once(100000)
    assert n > 0
    assert len(cat) == fs.count() - 1      # root not in changelog
    assert cat.get(fids[3]).size == 400
    # acks happened: nothing pending
    assert fs.changelog.stream(0).pending() == 0


def test_incremental_updates_no_rescan():
    fs, d, fids = _fs_with_files(10)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    pipe.process_once(100000)
    fs.write(fids[0], 5000, uid="u")
    fs.unlink(fids[1])
    new = fs.create(d, "fresh", owner="u")
    fs.write(new, 7)
    pipe.process_once()
    assert cat.get(fids[0]).size == 100 + 5000
    assert cat.get(fids[1]) is None
    assert cat.get(new).size == 7


def test_async_dirty_tag_dedups():
    """Paper SIII-A2 future work: repeated changes fold into one refresh."""
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    cfg = PipelineConfig(async_updates=True)
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), cfg)
    pipe.process_once(100000)
    for _ in range(20):                    # 20 writes to the same file
        fs.write(fids[2], 10, uid="u")
    n = pipe.process_once()
    assert n == 20
    assert pipe.dedup_hits >= 18           # tagged once, folded repeatedly
    assert cat.get(fids[2]).size == 300 + 200


def test_threaded_pipeline_drains():
    fs, d, fids = _fs_with_files(40)
    cat = Catalog()
    counters = ChangelogCounters()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(n_workers=3), counters)
    pipe.start()
    try:
        assert pipe.drain(timeout=20)
        for i in range(10):
            fs.write(fids[i], 1, uid="live")
        assert pipe.drain(timeout=20)
    finally:
        pipe.stop()
    assert cat.get(fids[0]).size == 101
    assert counters.snapshot()["per_user"]["live"]


def test_scan_and_changelog_agree():
    """DB built by scan == DB built by changelog replay."""
    fs, d, fids = _fs_with_files(25)
    by_scan = Catalog()
    Scanner(fs, by_scan).scan()
    by_log = Catalog()
    EventPipeline(fs, by_log, fs.changelog.stream(0),
                  PipelineConfig()).process_once(100000)
    for fid in fids:
        a, b = by_scan.get(fid), by_log.get(fid)
        assert a.size == b.size and a.owner == b.owner and a.path == b.path
