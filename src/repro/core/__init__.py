"""Robinhood Policy Engine core — the paper's contribution.

Collect (scanner/changelog/pipeline) -> store (catalog) -> exploit
(stats/reports/policies/alerts/HSM).
"""
from .types import (AGE_PROFILE_EDGES, AGE_PROFILE_LABELS, ChangelogRecord,
                    ChangelogType, Entry, FsType, HsmState,
                    SIZE_PROFILE_EDGES, SIZE_PROFILE_LABELS,
                    age_profile_bucket, format_size, parse_duration,
                    parse_size, size_profile_bucket)
from .catalog import Catalog, CatalogShard, ColumnBatch, StringTable
from .changelog import ChangelogHub, ChangelogStream, ColumnarRecords
from .device_store import DeviceColumnStore, MeshMatch
from .fidtable import FidTable
from .grants import GrantTable, Subject
from .scanner import Scanner, multi_client_scan, prune_missing
from .pipeline import (DeltaBatch, EventPipeline, FoldResult, PipelineConfig,
                       fold_columnar)
from .policy import (ALWAYS, And, Cmp, Const, Expr, Not, Or, PolicyError,
                     compile_program, parse_expr, KERNEL_COLUMNS)
from .policy_engine import (PolicyDefinition, PolicyEngine, Rule, RunReport,
                            UsageWatermarkTrigger)
from .profiles import GroupIndex, ProfileCube
from .stats import ChangelogCounters, DirUsage, StatsAggregator
from .telemetry import (Counter, Gauge, Histogram, MetricRegistry, Span,
                        parse_prometheus)
from .reports import Reports
from .alerts import AlertManager, AlertRule
from .hsm import HsmCoordinator
from .plugins import PLUGIN_REGISTRY, register_plugin

__all__ = [
    "AGE_PROFILE_EDGES", "AGE_PROFILE_LABELS", "ChangelogRecord",
    "ChangelogType", "Entry", "FsType", "HsmState",
    "SIZE_PROFILE_EDGES", "SIZE_PROFILE_LABELS",
    "age_profile_bucket", "format_size", "parse_duration", "parse_size",
    "size_profile_bucket",
    "Catalog", "CatalogShard", "ColumnBatch", "StringTable",
    "ChangelogHub", "ChangelogStream", "ColumnarRecords",
    "DeviceColumnStore", "FidTable",
    "GrantTable", "MeshMatch", "Subject",
    "GroupIndex", "ProfileCube",
    "Scanner", "multi_client_scan", "prune_missing",
    "DeltaBatch", "EventPipeline", "FoldResult", "PipelineConfig",
    "fold_columnar",
    "ALWAYS", "And", "Cmp", "Const", "Expr", "Not", "Or", "PolicyError",
    "compile_program", "parse_expr", "KERNEL_COLUMNS",
    "PolicyDefinition", "PolicyEngine", "Rule", "RunReport",
    "UsageWatermarkTrigger",
    "ChangelogCounters", "DirUsage", "StatsAggregator",
    "Counter", "Gauge", "Histogram", "MetricRegistry", "Span",
    "parse_prometheus",
    "Reports", "AlertManager", "AlertRule", "HsmCoordinator",
    "PLUGIN_REGISTRY", "register_plugin",
]
