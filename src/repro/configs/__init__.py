"""Assigned architecture configs (exact published shapes) + smoke twins."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from ..models.config import ModelConfig

ARCH_IDS = (
    "recurrentgemma_9b",
    "mixtral_8x22b",
    "llama4_maverick_400b_a17b",
    "rwkv6_1p6b",
    "gemma2_9b",
    "chatglm3_6b",
    "codeqwen1p5_7b",
    "deepseek_coder_33b",
    "whisper_large_v3",
    "llama3p2_vision_11b",
)

# CLI aliases matching the assignment spelling
ALIASES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "gemma2-9b": "gemma2_9b",
    "chatglm3-6b": "chatglm3_6b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-11b": "llama3p2_vision_11b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
