"""Pallas TPU kernel: RWKV6 decode state update (serving hot path).

At decode time rwkv6's cost is dominated by the per-head state update:
S' = diag(w) S + k v^T with readout y = r.(S + u k v^T). The state
(B, H, hd, hd) f32 is the serving-time "KV cache" of the SSM family and is
managed by the same HSM page-tier machinery; this kernel performs the
update in one pass per (batch, head) with everything resident in VMEM:
one HBM read + one write of S per token — the bandwidth optimum.

Grid: (B, H). Blocks: S tile (1, 1, hd, hd) [hd is 64 for rwkv6-1.6b —
lane-padded to 128 by Mosaic]; r/k/v/w/u vectors (1, 1, hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rwkv6_step_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref,
                       y_ref, s_out_ref):
    r = r_ref[0, 0].astype(jnp.float32)            # (hd,)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)
    s = s_ref[0, 0]                                 # (hd, hd) f32

    kv = k[:, None] * v[None, :]                    # (hd_k, hd_v)
    y = (r[None, :] @ (s + u[:, None] * kv))[0]     # (hd_v,)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    s_out_ref[0, 0] = w[:, None] * s + kv


def rwkv6_step_pallas(r, k, v, w, u, state, *, interpret: bool = True):
    """r,k,v,w: (B,H,hd); u: (H,hd); state: (B,H,hd,hd) f32."""
    B, H, hd = r.shape
    vec = pl.BlockSpec((1, 1, hd), lambda b, h: (b, h, 0))
    y, s_new = pl.pallas_call(
        _rwkv6_step_kernel,
        grid=(B, H),
        in_specs=[
            vec, vec, vec, vec,
            pl.BlockSpec((1, hd), lambda b, h: (h, 0)),            # u
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, s_new
