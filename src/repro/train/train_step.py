"""Training step: microbatched gradient accumulation + AdamW update.

The batch carries a leading ``accum`` dimension; microbatches are consumed
by ``lax.scan`` so activation memory is that of one microbatch (each model
superblock is additionally rematerialized — see models/transformer.py).
Gradients accumulate in f32.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
TrainState = Dict[str, PyTree]   # {"params", "opt", "step"}


def init_train_state(model, opt, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(model, opt, grad_pspecs=None):
    """grad_pspecs: optional PartitionSpec tree for the f32 grad accumulator.

    Without it XLA may keep the accumulator replicated, turning the
    per-microbatch gradient reduction into full-tensor all-reduces; with
    ZeRO-style (data+model) specs it becomes a reduce-scatter into shards
    (measured in EXPERIMENTS.md SPerf).
    """

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def _constrain(tree):
        if grad_pspecs is None:
            return tree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            tree, grad_pspecs)

    def train_step(state: TrainState, batch: PyTree
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        params = state["params"]
        accum = jax.tree.leaves(batch)[0].shape[0]

        def mb_body(gsum, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gsum = _constrain(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return gsum, (loss, metrics["ce"], metrics["aux"])

        g0 = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        gsum, (losses, ces, auxes) = jax.lax.scan(mb_body, g0, batch)
        grads = jax.tree.map(lambda g: (g / accum).astype(jnp.bfloat16), gsum)

        new_params, new_opt = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": jnp.mean(losses), "ce": jnp.mean(ces),
                   "aux": jnp.mean(auxes)}
        return new_state, metrics

    return train_step
