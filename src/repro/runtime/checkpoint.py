"""Checkpointing with a Robinhood-managed artifact lifecycle.

This is the paper's engine applied to the framework's own storage problem:
a long training run writes thousands of checkpoint shard files; nobody
scans the checkpoint directory to manage them. Instead:

* every shard write/delete emits a **changelog** record consumed into an
  **artifact catalog** (core.Catalog) — the mirror stays fresh without
  directory walks (C1+C3);
* **retention** is a policy run: "purge checkpoints beyond the last k,
  except every nth which is archived to cold storage" (C5/C8 analogue);
* **undelete**: purged checkpoints move to a trash tier first, and can be
  restored from it (paper SII-C3);
* **disaster recovery**: the catalog can be rebuilt by a parallel scan of
  the checkpoint root (C2).

Writes are crash-safe: a checkpoint directory is staged under a temp name
and atomically renamed; a checkpoint is visible iff its manifest exists.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core.catalog import Catalog
from ..core.changelog import ChangelogStream
from ..core.stats import StatsAggregator
from ..core.types import ChangelogType, Entry, FsType

PyTree = Any


class ArtifactStore:
    """Catalog-mirrored view of a real directory of training artifacts."""

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.catalog = Catalog(n_shards=2)
        self.stats = StatsAggregator(self.catalog.strings)
        self.catalog.add_delta_hook(self.stats.on_delta)
        self.changelog = ChangelogStream(mdt=0)
        self._next_fid = 1
        self._fid_by_path: Dict[str, int] = {}

    # -- event emission (the "MDT" side) ------------------------------------
    def _fid(self, path: str) -> int:
        if path not in self._fid_by_path:
            self._fid_by_path[path] = self._next_fid
            self._next_fid += 1
        return self._fid_by_path[path]

    def record_write(self, path: str, kind: str = "shard",
                     owner: str = "trainer") -> None:
        fid = self._fid(path)
        st = os.stat(path)
        self.changelog.emit(ChangelogType.CLOSE, fid, name=path,
                            uid=owner, attrs={"size": st.st_size})
        rel = os.path.relpath(path, self.root)
        self.catalog.upsert(Entry(
            fid=fid, name=os.path.basename(path), path=rel,
            type=FsType.FILE, size=st.st_size, blocks=st.st_size,
            owner=owner, status=kind, atime=st.st_atime, mtime=st.st_mtime,
            ctime=st.st_ctime))

    def record_delete(self, path: str) -> None:
        fid = self._fid_by_path.get(path)
        if fid is None:
            return
        self.changelog.emit(ChangelogType.UNLNK, fid, name=path)
        self.catalog.remove(fid)

    def rescan(self) -> int:
        """Disaster recovery: rebuild the catalog by walking the root."""
        n = 0
        for dirpath, _dirs, files in os.walk(self.root):
            for f in files:
                p = os.path.join(dirpath, f)
                self.record_write(p, kind="recovered")
                n += 1
        return n

    def usage(self) -> dict:
        return self.stats.report_types().get("file",
                                             {"count": 0, "volume": 0})


class CheckpointManager:
    """Sharded, atomic, policy-retained checkpoints of a train state."""

    def __init__(self, directory: str, keep_last: int = 3,
                 archive_every: int = 0, trash_capacity: int = 2) -> None:
        self.dir = os.path.abspath(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.trash_dir = os.path.join(self.dir, ".trash")
        self.cold_dir = os.path.join(self.dir, "cold")   # the "HSM" tier
        os.makedirs(self.trash_dir, exist_ok=True)
        os.makedirs(self.cold_dir, exist_ok=True)
        self.keep_last = keep_last
        self.archive_every = archive_every
        self.trash_capacity = trash_capacity
        self.store = ArtifactStore(self.dir)

    # -- save ----------------------------------------------------------------
    def _ckpt_name(self, step: int) -> str:
        return f"ckpt_{step:08d}"

    def save(self, state: PyTree, step: int) -> str:
        """Atomically write a checkpoint; returns its directory."""
        name = self._ckpt_name(step)
        final = os.path.join(self.dir, name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(state)
        manifest = {"step": step, "time": time.time(),
                    "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            logical_dtype = str(arr.dtype)
            if arr.dtype.name == "bfloat16":   # numpy can't round-trip bf16
                arr = arr.view(np.uint16)
            path = os.path.join(tmp, f"shard_{i:05d}.npy")
            np.save(path, arr)
            manifest["leaves"].append({
                "index": i, "shape": list(arr.shape),
                "dtype": logical_dtype, "file": os.path.basename(path)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)                     # atomic commit
        for leaf_info in manifest["leaves"]:
            self.store.record_write(os.path.join(final, leaf_info["file"]))
        self.store.record_write(os.path.join(final, "manifest.json"),
                                kind="manifest")
        self.apply_retention()
        return final

    # -- enumerate -----------------------------------------------------------
    def steps(self, include_cold: bool = False) -> List[int]:
        out = []
        dirs = [self.dir] + ([self.cold_dir] if include_cold else [])
        for d in dirs:
            for name in os.listdir(d):
                if name.startswith("ckpt_") and not name.endswith(".tmp") \
                        and os.path.exists(os.path.join(d, name,
                                                        "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(set(out))

    def _path_for(self, step: int) -> Optional[str]:
        name = self._ckpt_name(step)
        for d in (self.dir, self.cold_dir, self.trash_dir):
            p = os.path.join(d, name)
            if os.path.exists(os.path.join(p, "manifest.json")):
                return p
        return None

    # -- restore ---------------------------------------------------------------
    def restore(self, like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
        """Load a checkpoint into the structure of ``like``.

        ``shardings``: optional NamedSharding tree — enables *elastic*
        restore onto a different mesh than the one that saved (arrays are
        stored logically, resharding happens at device_put).
        """
        steps = self.steps(include_cold=True)
        if not steps:
            raise FileNotFoundError("no checkpoints")
        step = step if step is not None else steps[-1]
        path = self._path_for(step)
        if path is None:
            raise FileNotFoundError(f"checkpoint step {step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == len(manifest["leaves"]), \
            "checkpoint/state structure mismatch"
        sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                     else [None] * len(leaves))
        out = []
        for info, ref_leaf, sh in zip(manifest["leaves"], leaves, sh_leaves):
            arr = np.load(os.path.join(path, info["file"]))
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            tgt_dtype = getattr(ref_leaf, "dtype", arr.dtype)
            arr = arr.astype(tgt_dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), step

    # -- retention / archive / undelete (the Robinhood policies) ---------------
    def apply_retention(self) -> dict:
        """keep_last live; archive every nth to cold; purge rest to trash."""
        report = {"archived": [], "trashed": [], "purged": []}
        live = self.steps()
        victims = live[:-self.keep_last] if self.keep_last else []
        for step in victims:
            name = self._ckpt_name(step)
            src = os.path.join(self.dir, name)
            if not os.path.exists(src):
                continue
            if self.archive_every and step % self.archive_every == 0:
                shutil.move(src, os.path.join(self.cold_dir, name))
                report["archived"].append(step)
            else:
                shutil.move(src, os.path.join(self.trash_dir, name))
                report["trashed"].append(step)
            for leaf in os.listdir(os.path.join(
                    self.cold_dir if step in report["archived"]
                    else self.trash_dir, name)):
                self.store.record_delete(os.path.join(src, leaf))
        # bound the trash tier (true purge)
        trash = sorted(os.listdir(self.trash_dir))
        while len(trash) > self.trash_capacity:
            victim = trash.pop(0)
            shutil.rmtree(os.path.join(self.trash_dir, victim))
            report["purged"].append(int(victim.split("_")[1]))
        return report

    def undelete(self, step: int) -> bool:
        """Bring a trashed checkpoint back (paper's undelete)."""
        name = self._ckpt_name(step)
        src = os.path.join(self.trash_dir, name)
        if not os.path.exists(src):
            return False
        shutil.move(src, os.path.join(self.dir, name))
        for leaf in os.listdir(os.path.join(self.dir, name)):
            self.store.record_write(os.path.join(self.dir, name, leaf))
        return True
