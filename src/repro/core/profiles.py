"""On-device analytics subsystem: the incremental **profile cube** (C6).

The paper's second pillar is "a synthetic understanding of file systems
contents ... overall statistics about data ownership, age and size
profiles". :class:`ProfileCube` holds exactly that as one dense tensor

    ``cube[measure, group, size_bucket, age_bucket]`` (int64)

where *measure* is count / volume / spc_used, *group* is a dense code for
one (owner, group, type, hsm_state) combination (:class:`GroupIndex`),
*size_bucket* follows robinhood's file-size profile ranges and
*age_bucket* the age-profile ranges (``core.types``). Every ``rbh-report``
query — per-user, per-group, per-type, per-HSM-state, size profile, age
profile, top users — is a small masked reduction over the cube instead of
a scalar dict fold per entry per dimension.

Maintenance is **incremental and shard-partitioned**:

* each catalog shard owns a partial cube plus a per-entry
  :class:`~repro.core.fidtable.FidTable` (bucket membership + age-rollover
  schedule); partial cubes are merged on query, so churn in one shard
  never touches the others' state;
* catalog delta hooks buffer signed updates per shard; queries flush the
  buffer **vectorized** (dedup per fid, one ``np.add.at`` per phase) —
  the cube never recomputes on query;
* age buckets drift with wall-clock time without any delta arriving: each
  entry schedules its next bucket-boundary instant (``atime + edge``),
  mirroring the policy engine's age-flip machinery, and queries move only
  the **due** rows to their new bucket before answering;
* full rebuilds run per shard from a columnar snapshot — host groupby
  (exact int64, the default) or the fused ``profile_cube`` Pallas kernel
  (:mod:`repro.kernels.profile_cube`) which bucketizes and
  segment-reduces the whole column stack in a single launch (opt-in:
  f32 accumulation, see :attr:`ProfileCube.use_kernel`);
* cubes persist beside the catalog's sqlite mirror
  (``<db>.profiles.npz``) for restart, and :meth:`record_trend` appends
  compact time-series snapshots for capacity trending.

The scalar :class:`~repro.core.stats.StatsAggregator` fold survives as
the differential oracle; pass ``cube=`` to it to serve its reports from
here instead.

**Shared delta fan-out contract.** A ProfileCube consumes exactly ONE
delta feed, claimed via :meth:`ProfileCube.claim_delta_feed`. Three
mutually exclusive wirings exist: (a) :meth:`ProfileCube.attach` hooks
the catalog directly; (b) a cube-backed ``StatsAggregator`` forwards its
own hook; (c) :meth:`ProfileCube.attach_device_store` hands maintenance
to the :class:`~repro.core.device_store.DeviceColumnStore` cube plane —
the store's single catalog hook then fans one dirty batch out to the
resident columns, the partial cubes, and the plane mirrors in the same
scatter pass, and :meth:`ProfileCube.on_delta` becomes a no-op so a fid
dirtied in a pipeline batch is applied exactly once.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .fidtable import FidTable
from .telemetry import counter_attr
from .types import (AGE_PROFILE_EDGES, AGE_PROFILE_LABELS, FsType, HsmState,
                    SIZE_PROFILE_EDGES, SIZE_PROFILE_LABELS)

N_MEASURES = 3                        # count, volume, spc_used
S = len(SIZE_PROFILE_LABELS)          # size-profile buckets
A = len(AGE_PROFILE_LABELS)           # age-profile buckets

_SIZE_EDGES = np.asarray(SIZE_PROFILE_EDGES, dtype=np.int64)
_AGE_EDGES = np.asarray(AGE_PROFILE_EDGES, dtype=np.float64)
# next bucket-boundary age per bucket; the last bucket never flips again
_FLIP_EDGES = np.append(_AGE_EDGES[1:], np.inf)


def size_buckets_np(size: np.ndarray) -> np.ndarray:
    """Vectorized ``core.types.size_profile_bucket`` (identical results)."""
    return np.clip(np.searchsorted(_SIZE_EDGES, size, side="right") - 1,
                   0, S - 1)


def age_buckets_np(age: np.ndarray) -> np.ndarray:
    """Vectorized ``core.types.age_profile_bucket`` (identical results)."""
    return np.clip(np.searchsorted(_AGE_EDGES, age, side="right") - 1,
                   0, A - 1)


HOT_AGE_BUCKETS = 2   # leading age buckets counted as "hot" for placement


def hot_volume_fraction(ab: np.ndarray, sizes: np.ndarray) -> float:
    """Fraction of total volume sitting in the young age buckets — the
    ProfileCube side of the device store's placement signal (recently
    accessed bytes predict upcoming policy work on the group)."""
    total = float(np.asarray(sizes, np.float64).sum())
    if total <= 0.0:
        return 0.0
    hot = float(np.asarray(sizes, np.float64)[
        np.asarray(ab) < HOT_AGE_BUCKETS].sum())
    return hot / total


def _bincount_i64(flat: np.ndarray, vals: np.ndarray, k: int,
                  counts: np.ndarray) -> np.ndarray:
    """Exact int64 weighted bincount.

    ``np.bincount`` accumulates weights in float64 (exact only to 2**53
    per cell); splitting each value into 32-bit halves keeps both partial
    sums exact whenever no cell aggregates more than 2**21 rows, which
    ``counts`` (the already-computed per-cell row counts) certifies —
    beyond that the slow-but-exact ``np.add.at`` path runs instead.
    """
    if counts.size and int(counts.max()) >= (1 << 21):
        out = np.zeros(k, dtype=np.int64)
        np.add.at(out, flat, vals)
        return out
    lo = np.bincount(flat, weights=(vals & 0xffffffff).astype(np.float64),
                     minlength=k)[:k]
    hi = np.bincount(flat, weights=(vals >> 32).astype(np.float64),
                     minlength=k)[:k]
    return (hi.astype(np.int64) << 32) + lo.astype(np.int64)


class GroupIndex:
    """Dense gid <-> (owner_code, group_code, type, hsm_state) (append-only).

    Shared across shards so per-shard partial cubes merge by plain array
    addition. Thread-safe; ``columns()`` caches the key matrix as numpy
    arrays for vectorized report masks (invalidated on growth).
    """

    FIELDS = ("owner", "group", "type", "hsm")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._gids: Dict[Tuple[int, int, int, int], int] = {}
        self._keys: List[Tuple[int, int, int, int]] = []
        self._cols: Optional[Dict[str, np.ndarray]] = None

    def __len__(self) -> int:
        return len(self._keys)

    def get_or_add(self, key: Tuple[int, int, int, int]) -> int:
        with self._lock:
            gid = self._gids.get(key)
            if gid is None:
                gid = len(self._keys)
                self._gids[key] = gid
                self._keys.append(key)
                self._cols = None
            return gid

    def get_or_add_many(self, owners: np.ndarray, groups: np.ndarray,
                        types: np.ndarray, hsms: np.ndarray) -> np.ndarray:
        """Vectorized gid assignment: unique combos first (few), then a
        dense LUT gather — no per-row dict lookup.

        Keys pack into one int64 with per-call bases (an int sort is ~10x
        an ``np.unique(axis=1)`` void sort); astronomically large interned
        code spaces fall back to the axis unique.
        """
        o = np.asarray(owners, np.int64)
        g = np.asarray(groups, np.int64)
        t = np.asarray(types, np.int64)
        h = np.asarray(hsms, np.int64)
        if o.size == 0:
            return np.zeros(0, dtype=np.int64)
        kg = int(g.max()) + 1
        kt = int(t.max()) + 1
        kh = int(h.max()) + 1
        if (((int(o.max()) + 1) * kg) * kt) * kh < (1 << 62):
            packed = ((o * kg + g) * kt + t) * kh + h
            _uniq, first, inv = np.unique(packed, return_index=True,
                                          return_inverse=True)
        else:
            mat = np.stack([o, g, t, h])
            _uniq, first, inv = np.unique(mat, axis=1, return_index=True,
                                          return_inverse=True)
        lut = np.array([self.get_or_add((int(o[j]), int(g[j]), int(t[j]),
                                         int(h[j]))) for j in first.tolist()],
                       dtype=np.int64)
        return lut[inv.reshape(-1)]

    def columns(self) -> Dict[str, np.ndarray]:
        """Key matrix as parallel arrays: ``{"owner"|"group"|"type"|"hsm":
        (B,) int64}`` — the mask side of every report reduction."""
        with self._lock:
            if self._cols is None:
                mat = (np.array(self._keys, dtype=np.int64).reshape(-1, 4)
                       if self._keys else np.zeros((0, 4), np.int64))
                self._cols = {f: mat[:, i].copy()
                              for i, f in enumerate(self.FIELDS)}
            return self._cols

    def export(self) -> np.ndarray:
        with self._lock:
            return (np.array(self._keys, dtype=np.int64).reshape(-1, 4)
                    if self._keys else np.zeros((0, 4), np.int64))

    def restore(self, mat: np.ndarray) -> None:
        with self._lock:
            self._keys = [tuple(row) for row in mat.astype(np.int64).tolist()]
            self._gids = {k: i for i, k in enumerate(self._keys)}
            self._cols = None


class _ShardCube:
    """One shard's partial cube + per-entry table + pending delta buffer.

    All methods expect :attr:`lock` held by the caller (``ProfileCube``
    routes every access through it). ``ref_now`` is the age reference of
    the cube's A axis: every row's stored age bucket is its bucket *as of*
    ``ref_now``; :meth:`sweep` advances it, moving only the rows whose
    scheduled boundary instant passed.
    """

    _TABLE_SPECS = (("gid", np.int64), ("sb", np.int64), ("ab", np.int64),
                    ("size", np.int64), ("blocks", np.int64),
                    ("stamp", np.float64), ("flip", np.float64))

    def __init__(self, ref_now: float) -> None:
        self.lock = threading.Lock()
        self.cube = np.zeros((N_MEASURES, 0, S, A), dtype=np.int64)
        self.table = FidTable(self._TABLE_SPECS)
        self.pending: List[Tuple[int, Optional[tuple]]] = []
        self.ref_now = float(ref_now)
        # earliest scheduled rollover instant (lower bound — removals may
        # leave it stale-low): sweeps before it skip the due scan entirely
        self.min_flip = np.inf

    # -- storage -------------------------------------------------------------
    def ensure_groups(self, b: int) -> None:
        cur = self.cube.shape[1]
        if b <= cur:
            return
        cap = max(b, cur * 2, 8)
        cube = np.zeros((N_MEASURES, cap, S, A), dtype=np.int64)
        cube[:, :cur] = self.cube
        self.cube = cube

    def apply_signed(self, sign: int, gid: np.ndarray, sb: np.ndarray,
                     ab: np.ndarray, size: np.ndarray, blocks: np.ndarray
                     ) -> None:
        """Vectorized signed bucket update: one ``np.add.at`` per measure."""
        flat = (gid * S + sb) * A + ab
        c = self.cube.reshape(N_MEASURES, -1)
        np.add.at(c[0], flat, sign)
        np.add.at(c[1], flat, sign * size)
        np.add.at(c[2], flat, sign * blocks)

    # -- incremental maintenance ----------------------------------------------
    def push(self, fid: int, new: Optional[tuple]) -> None:
        self.pending.append((fid, new))

    def flush(self, groups: GroupIndex) -> None:
        """Fold buffered deltas, deduped per fid, in two vector phases.

        Subtract uses the **stored** table row (the exact cells the cube
        holds for that fid — by construction consistent even when several
        deltas for one fid collapsed in the buffer), then the last new
        state per fid is bucketized at ``ref_now`` and added.
        """
        if not self.pending:
            return
        items, self.pending = self.pending, []
        last: Dict[int, Optional[tuple]] = {}
        for fid, new in items:
            last[fid] = new
        fids = list(last)
        present, rows = self.table.gather(fids)
        if present.any():
            self.apply_signed(-1, rows["gid"][present], rows["sb"][present],
                              rows["ab"][present], rows["size"][present],
                              rows["blocks"][present])
            # only true deletions release their rows; updates keep theirs
            # and are overwritten in place by the add phase below
            gone = [f for f, p in zip(fids, present.tolist())
                    if p and last[f] is None]
            if gone:
                self.table.remove_many(gone)
        adds = [(f, t) for f, t in last.items() if t is not None]
        if adds:
            n = len(adds)
            owners = np.fromiter((t[1] for _, t in adds), np.int64, n)
            grps = np.fromiter((t[2] for _, t in adds), np.int64, n)
            types = np.fromiter((t[3] for _, t in adds), np.int64, n)
            sizes = np.fromiter((t[4] for _, t in adds), np.int64, n)
            blocks = np.fromiter((t[5] for _, t in adds), np.int64, n)
            hsms = np.fromiter((t[6] for _, t in adds), np.int64, n)
            stamps = np.fromiter((t[7] for _, t in adds), np.float64, n)
            gids = groups.get_or_add_many(owners, grps, types, hsms)
            sb = size_buckets_np(sizes)
            ab = age_buckets_np(self.ref_now - stamps)
            flips = stamps + _FLIP_EDGES[ab]
            self.ensure_groups(int(gids.max()) + 1)
            self.apply_signed(+1, gids, sb, ab, sizes, blocks)
            self.table.upsert_many([f for f, _ in adds], gid=gids, sb=sb,
                                   ab=ab, size=sizes, blocks=blocks,
                                   stamp=stamps, flip=flips)
            if np.isfinite(flips).any():
                self.min_flip = min(self.min_flip, float(flips.min()))
        self.table.maybe_compact()

    def sweep(self, now: float, groups: GroupIndex) -> int:
        """Advance the age reference to ``now``: fold pending deltas, then
        move only the rows whose next bucket boundary passed. Returns the
        number of rolled-over rows. Before the cached ``min_flip`` instant
        nothing can be due, so the common no-rollover query skips the
        table scan entirely."""
        self.flush(groups)
        if now <= self.ref_now:
            return 0
        moved = 0
        if now >= self.min_flip:
            due = self.table.select_le("flip", now)
            if due.size:
                fids = due.tolist()
                _present, rows = self.table.gather(fids)
                new_ab = age_buckets_np(now - rows["stamp"])
                self.apply_signed(-1, rows["gid"], rows["sb"], rows["ab"],
                                  rows["size"], rows["blocks"])
                self.apply_signed(+1, rows["gid"], rows["sb"], new_ab,
                                  rows["size"], rows["blocks"])
                self.table.upsert_many(
                    fids, ab=new_ab, flip=rows["stamp"] + _FLIP_EDGES[new_ab])
                moved = int(due.size)
            # re-derive the exact bound (clears staleness from removals)
            self.min_flip = self.table.min_col("flip")
        self.ref_now = now
        return moved

    # -- bulk load (full rebuild / restore) -----------------------------------
    def load(self, fids: np.ndarray, gids: np.ndarray, sizes: np.ndarray,
             blocks: np.ndarray, stamps: np.ndarray, now: float,
             cube: Optional[np.ndarray] = None) -> None:
        """Replace this shard's state from per-row arrays; ``cube=None``
        aggregates on the host (exact int64 groupby)."""
        sb = size_buckets_np(sizes)
        ab = age_buckets_np(now - stamps)
        b = int(gids.max()) + 1 if gids.size else 0
        if cube is not None:
            # a prebuilt cube may span the full global group axis even
            # when this shard's rows use fewer gids
            b = max(b, cube.shape[1])
        self.cube = np.zeros((N_MEASURES, 0, S, A), dtype=np.int64)
        self.ensure_groups(b)
        if cube is not None:
            self.cube[:, : cube.shape[1]] = cube
        elif gids.size:
            flat = (gids * S + sb) * A + ab
            k = self.cube.shape[1] * S * A
            c = self.cube.reshape(N_MEASURES, -1)
            c[0, :] = np.bincount(flat, minlength=k)[:k]
            c[1, :] = _bincount_i64(flat, sizes, k, c[0])
            c[2, :] = _bincount_i64(flat, blocks, k, c[0])
        flips = stamps + _FLIP_EDGES[ab]
        self.table.bulk_load(fids, gid=gids, sb=sb, ab=ab, size=sizes,
                             blocks=blocks, stamp=stamps, flip=flips)
        finite = np.isfinite(flips)
        self.min_flip = float(flips[finite].min()) if finite.any() \
            else np.inf
        self.ref_now = now


class ProfileCube:
    """Incremental, shard-partitioned ownership/age/size profile cube."""

    rollovers = counter_attr(
        "cube_rollovers", "age-bucket moves served (host sweeps, or the "
        "device store's on-device count when one is attached)")

    def __init__(self, catalog, clock=time.time,
                 use_kernel: bool = False) -> None:
        self.catalog = catalog
        self.strings = catalog.strings
        self.clock = clock
        self.telemetry = catalog.telemetry
        self._tlabels = {"cube": catalog.telemetry.instance("cube")}
        # True: full rebuilds run through the Pallas kernel (on TPU; the
        # interpret-mode kernel off-TPU is for differential tests). The
        # kernel accumulates in f32 — exact only while per-cell sums stay
        # below 2**24 — so the DEFAULT is the int64 host groupby; opt in
        # for on-device builds where that precision envelope holds (or
        # approximate trends are acceptable).
        self.use_kernel = use_kernel
        self.groups = GroupIndex()
        now = float(clock())
        self._shards = [_ShardCube(now) for _ in range(catalog.n_shards)]
        self.rollovers = 0            # age-bucket moves served (observability)
        # a cube consumes exactly ONE delta feed: either attach() hooks it
        # to the catalog directly, or a cube-backed StatsAggregator
        # forwards its hook — never both (updates would double-count)
        self._attached = False
        # mesh-resident serving: attach_device_store() hands maintenance
        # to the DeviceColumnStore's cube plane (same dirty-row scatter
        # path that refreshes the resident columns); cube() then answers
        # from the on-device partials and this object's per-shard host
        # cubes go quiet
        self.device_store = None
        # multi-tenant scoping: attach_grants() wires the shared
        # GrantTable; report methods then accept subject= and serve a
        # per-subject cube (store-backed via the permissions plane, or
        # the host grant-filtered fold)
        self.grants = None
        # scoped-cube burst cache: one subject typically reads several
        # reports in a row (report_user, report_types, ...) off the SAME
        # scoped cube — cache it per subject, keyed on every input that
        # can change it (time, catalog tick, grant set, group-axis width)
        self._scoped_cache: Dict[str, Tuple[tuple, np.ndarray]] = {}

    # -- wiring ---------------------------------------------------------------
    def attach(self, resume: bool = False, path: Optional[str] = None
               ) -> "ProfileCube":
        """Subscribe to catalog deltas and build the initial cube.

        The hook is registered *before* the rebuild/restore snapshots each
        shard: a delta racing the snapshot is re-folded from the buffer,
        and the table-based subtract phase makes that replay a no-op.

        ``resume=True`` tries :meth:`load` first (restart resumes the
        saved cube instead of a cold rebuild — mutations applied while the
        process was down must be replayed through the catalog, e.g. via a
        durable changelog subscriber, exactly like the engine's
        ``.incstate.npz`` contract); a missing/mismatched snapshot falls
        back to the rebuild.

        Raises when this cube already consumes a delta feed (a second
        subscription would double-count every mutation).
        """
        self.claim_delta_feed("ProfileCube.attach")
        self.catalog.add_delta_hook(self.on_delta, batch=self.on_delta_batch)
        if resume:
            try:
                if self.load(path):
                    return self
            except ValueError:
                pass                      # no state path: cold rebuild
        if len(self.catalog):
            self.rebuild()
        return self

    def attach_device_store(self, store) -> "ProfileCube":
        """Serve this cube from a :class:`~.device_store.DeviceColumnStore`.

        Claims this cube's single delta feed (shared fan-out contract:
        the store's catalog hook is the one consumer — its warm-scatter
        refresh updates resident columns, the cube partials, and the
        plane mirrors from the same dirty batch, so no mutation ever
        folds twice). After attaching, :meth:`cube` answers from the
        mesh-resident partial cubes (``store.analytics_cube``) and every
        report method rides on it — host columns are never re-read.
        """
        if store.catalog is not self.catalog:
            raise ValueError("device store is bound to a different catalog")
        self.claim_delta_feed("ProfileCube.attach_device_store")
        store.enable_cube_plane(self.groups, self.clock)
        self.device_store = store
        if self.grants is not None:
            store.enable_permissions_plane(self.grants)
        return self

    def attach_grants(self, grants) -> "ProfileCube":
        """Wire a :class:`~repro.core.grants.GrantTable` so reports accept
        ``subject=``. With a device store attached this enables its
        permissions plane (scoped cubes run as one fused
        ``mesh_scoped_cube`` launch); without one the scoped queries fold
        the grant-filtered host columns."""
        self.grants = grants
        if self.device_store is not None:
            self.device_store.enable_permissions_plane(grants)
        return self

    def claim_delta_feed(self, who: str) -> None:
        """Mark this cube's single delta feed as taken (attach() or a
        cube-backed StatsAggregator); a second claim raises."""
        if self._attached:
            raise ValueError(
                f"{who}: this ProfileCube already consumes a delta feed — "
                "wire either attach() or one cube-backed StatsAggregator, "
                "never both (every mutation would fold twice)")
        self._attached = True

    def on_delta(self, old: Optional[tuple], new: Optional[tuple]) -> None:
        """Catalog delta hook: buffer a signed update on the owning shard."""
        if self.device_store is not None:
            return            # store's refresh path maintains the cube plane
        src = new if new is not None else old
        if src is None:
            return
        fid = src[0]
        shard = self._shards[self.catalog._shard_id(fid)]
        with shard.lock:
            shard.push(fid, new)

    def on_delta_batch(self, pairs) -> None:
        """Single fan-out arm: buffer one committed delta batch with one
        lock acquisition per *touched* shard instead of one per mutation
        (``Catalog.add_delta_hook(..., batch=...)`` routes batched
        commits here; scalar mutations still arrive via
        :meth:`on_delta`)."""
        if self.device_store is not None:
            return
        shard_id = self.catalog._shard_id
        by_shard: Dict[int, list] = {}
        for old, new in pairs:
            src = new if new is not None else old
            if src is None:
                continue
            by_shard.setdefault(shard_id(src[0]), []).append((src[0], new))
        for sid, items in by_shard.items():
            shard = self._shards[sid]
            with shard.lock:
                push = shard.push
                for fid, new in items:
                    push(fid, new)

    # -- full rebuild ----------------------------------------------------------
    def rebuild(self, now: Optional[float] = None,
                use_kernel: Optional[bool] = None) -> None:
        """Per-shard full recompute from columnar shard snapshots.

        Each shard aggregates independently (numeric columns only — no
        path/name gather): host ``np.bincount`` groupby (exact int64, the
        default), or the fused Pallas kernel when opted in (f32 sums —
        see :attr:`use_kernel` for the precision envelope). Buffered
        deltas are kept; the next flush reconciles anything that raced
        the snapshot.
        """
        if self.device_store is not None:
            # store-backed cube: a "rebuild" is just an invalidation — the
            # next query re-launches mesh_profile_cube over the resident
            # blocks (host columns are never re-read)
            self.device_store.invalidate_cube()
            return
        now = float(self.clock()) if now is None else float(now)
        use_kernel = self.use_kernel if use_kernel is None else use_kernel
        kernel_fn = None
        max_groups = 0
        if use_kernel:
            from ..kernels.profile_cube.ops import MAX_GROUPS, profile_cube
            kernel_fn = profile_cube
            max_groups = MAX_GROUPS
        needed = ("fid", "owner", "group", "type", "hsm_state", "size",
                  "blocks", "atime")
        for sid, shard in enumerate(self._shards):
            with shard.lock:
                cols, _snap = self.catalog.shards[sid].snapshot(
                    names=needed, with_strings=False)
                gids = self.groups.get_or_add_many(
                    cols["owner"], cols["group"], cols["type"],
                    cols["hsm_state"])
                cube = None
                if kernel_fn is not None and gids.size \
                        and len(self.groups) <= max_groups:
                    # bucket indices computed host-side (exact — matching
                    # the int64 entry tables); the kernel does the fused
                    # segment reduction
                    age = now - cols["atime"]
                    cube_f = kernel_fn(
                        gids, cols["size"], cols["blocks"], age,
                        sb=size_buckets_np(cols["size"]),
                        ab=age_buckets_np(age), n_groups=len(self.groups))
                    cube = np.rint(cube_f).astype(np.int64)
                shard.load(np.asarray(cols["fid"], np.int64), gids,
                           np.asarray(cols["size"], np.int64),
                           np.asarray(cols["blocks"], np.int64),
                           np.asarray(cols["atime"], np.float64), now,
                           cube=cube)

    # -- query ----------------------------------------------------------------
    def _scoped_cube_host(self, now: float, subject: str) -> np.ndarray:
        """Grant-filtered host fold: the scalar oracle for ``subject=``
        scoping — bins only the rows the subject may see into the shared
        group axis (exact int64, same bucket tables as the shard cubes).
        Serves host-only scoped queries and the store's PolicyError
        fallback; the differential suite pins the device path to it."""
        if self.grants is None:
            raise RuntimeError(
                "subject= scoping needs attach_grants(GrantTable)")
        cols = self.catalog.arrays()
        vis = self.grants.visible_mask(subject, cols, self.strings)
        idx = np.nonzero(vis)[0]
        gids = self.groups.get_or_add_many(
            cols["owner"][idx], cols["group"][idx], cols["type"][idx],
            cols["hsm_state"][idx])
        b = len(self.groups)
        out = np.zeros((N_MEASURES, b, S, A), dtype=np.int64)
        if not idx.size:
            return out
        sizes = np.asarray(cols["size"], np.int64)[idx]
        blocks = np.asarray(cols["blocks"], np.int64)[idx]
        sb = size_buckets_np(sizes)
        ab = age_buckets_np(now - np.asarray(cols["atime"],
                                             np.float64)[idx])
        flat = (gids * S + sb) * A + ab
        k = b * S * A
        c = out.reshape(N_MEASURES, -1)
        c[0, :] = np.bincount(flat, minlength=k)[:k]
        c[1, :] = _bincount_i64(flat, sizes, k, c[0])
        c[2, :] = _bincount_i64(flat, blocks, k, c[0])
        return out

    def cube(self, now: Optional[float] = None,
             subject: Optional[str] = None) -> np.ndarray:
        """Merged (N_MEASURES, B, S, A) int64 cube as of ``now``.

        Flushes each shard's pending deltas and processes due age-bucket
        rollovers first; merging is plain per-shard array addition. With
        a device store attached the merge is served entirely from the
        mesh-resident partial cubes instead. ``subject=`` returns the
        per-subject scoped cube (store permissions plane when available,
        the grant-filtered host fold otherwise)."""
        now = float(self.clock()) if now is None else float(now)
        if subject is not None:
            gver = self.grants.version if self.grants is not None else -1
            key = (now, self.catalog.version, gver, len(self.groups))
            hit = self._scoped_cache.get(subject)
            if hit is not None and hit[0] == key:
                return hit[1].copy()          # burst: one compute, N reports
            cube = None
            if self.device_store is not None:
                from .policy import PolicyError
                try:
                    cube = self.device_store.analytics_cube(
                        now, subject=subject)
                    self.rollovers = self.device_store.rollovers
                except PolicyError:
                    pass              # plane not enabled: host fold below
            if cube is None:
                cube = self._scoped_cube_host(now, subject)
            # the fold itself may have grown the group axis; catalog/grant
            # versions stay the PRE-compute ones, so a mutation racing the
            # fold forces a miss (never a stale hit) on the next call
            key = (now, key[1], gver, len(self.groups))
            self._scoped_cache[subject] = (key, cube.copy())
            return cube
        if self.device_store is not None:
            cube = self.device_store.analytics_cube(now)
            self.rollovers = self.device_store.rollovers
            return cube
        for shard in self._shards:            # sweeps may grow the index
            with shard.lock:
                self.rollovers += shard.sweep(now, self.groups)
        b = len(self.groups)
        out = np.zeros((N_MEASURES, b, S, A), dtype=np.int64)
        for shard in self._shards:
            with shard.lock:
                sb = min(shard.cube.shape[1], b)
                out[:, :sb] += shard.cube[:, :sb]
        return out

    # -- rbh-report queries (dict-identical to the scalar StatsAggregator) ----
    def _cube_and_cols(self, now: Optional[float],
                       subject: Optional[str] = None
                       ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Merged cube + group key columns, sliced to one consistent group
        axis: a concurrent flush may grow the index between the two reads,
        and a group born after this cube merged has no cells in it."""
        cube = self.cube(now, subject=subject)
        b = cube.shape[1]
        cols = {k: v[:b] for k, v in self.groups.columns().items()}
        return cube, cols

    def _acc_dict(self, cube: np.ndarray, mask: np.ndarray) -> dict:
        cnt = int(cube[0][mask].sum())
        vol = int(cube[1][mask].sum())
        spc = int(cube[2][mask].sum())
        return {"count": cnt, "volume": vol, "spc_used": spc,
                "avg_size": vol / cnt if cnt else 0.0}

    def _report_by(self, field: str, code: int, label_key: str,
                   label: str, now: Optional[float],
                   subject: Optional[str] = None) -> List[dict]:
        cube, cols = self._cube_and_cols(now, subject)
        out = []
        for t in sorted(FsType, key=int):
            mask = (cols[field] == code) & (cols["type"] == int(t))
            if not mask.any():
                continue
            d = self._acc_dict(cube, mask)
            if not d["count"]:
                continue
            d[label_key] = label
            d["type"] = t.name.lower()
            out.append(d)
        return out

    def report_user(self, user: str, now: Optional[float] = None,
                    subject: Optional[str] = None) -> List[dict]:
        """`rbh-report -u user`: per-type count/volume/avg from the cube.
        ``subject=`` restricts every measure to that subject's grants."""
        code = self.strings.code_of(user)
        if code is None:
            return []
        return self._report_by("owner", code, "user", user, now, subject)

    def report_group(self, grp: str, now: Optional[float] = None,
                     subject: Optional[str] = None) -> List[dict]:
        code = self.strings.code_of(grp)
        if code is None:
            return []
        return self._report_by("group", code, "group", grp, now, subject)

    def report_types(self, now: Optional[float] = None,
                     subject: Optional[str] = None) -> Dict[str, dict]:
        cube, cols = self._cube_and_cols(now, subject)
        out = {}
        for t in sorted(FsType, key=int):
            mask = cols["type"] == int(t)
            if mask.any():
                d = self._acc_dict(cube, mask)
                if d["count"]:
                    out[t.name.lower()] = d
        return out

    def report_hsm(self, now: Optional[float] = None,
                   subject: Optional[str] = None) -> Dict[str, dict]:
        cube, cols = self._cube_and_cols(now, subject)
        out = {}
        for h in sorted(HsmState, key=int):
            mask = cols["hsm"] == int(h)
            if mask.any():
                d = self._acc_dict(cube, mask)
                if d["count"]:
                    out[h.name.lower()] = d
        return out

    def user_size_profile(self, user: str, now: Optional[float] = None,
                          subject: Optional[str] = None) -> Dict[str, int]:
        out = {lbl: 0 for lbl in SIZE_PROFILE_LABELS}
        code = self.strings.code_of(user)
        if code is None:
            return out
        cube, cols = self._cube_and_cols(now, subject)
        mask = (cols["owner"] == code) & (cols["type"] == int(FsType.FILE))
        if mask.any():
            per_s = cube[0][mask].sum(axis=(0, 2))         # (S,)
            for i, lbl in enumerate(SIZE_PROFILE_LABELS):
                out[lbl] += int(per_s[i])
        return out

    def age_profile(self, user: Optional[str] = None,
                    now: Optional[float] = None,
                    subject: Optional[str] = None) -> Dict[str, dict]:
        """The paper's data-age profile: per age bucket count/volume/spc
        (optionally restricted to one user) — new over the scalar path."""
        cube, cols = self._cube_and_cols(now, subject)
        mask = np.ones(cube.shape[1], dtype=bool)
        if user is not None:
            code = self.strings.code_of(user)
            mask &= (cols["owner"] == code) if code is not None else False
        sub = cube[:, mask].sum(axis=(1, 2))               # (3, A)
        return {lbl: {"count": int(sub[0, i]), "volume": int(sub[1, i]),
                      "spc_used": int(sub[2, i])}
                for i, lbl in enumerate(AGE_PROFILE_LABELS)}

    def top_users(self, by: str = "volume", k: int = 10,
                  type_: FsType = FsType.FILE,
                  now: Optional[float] = None,
                  subject: Optional[str] = None) -> List[dict]:
        cube, cols = self._cube_and_cols(now, subject)
        tmask = cols["type"] == int(type_)
        rows = []
        for code in np.unique(cols["owner"][tmask]).tolist():
            d = self._acc_dict(cube, tmask & (cols["owner"] == code))
            if not d["count"]:
                continue
            d["user"] = self.strings.lookup(code)
            rows.append(d)
        rows.sort(key=lambda d: d.get(by, 0), reverse=True)
        return rows[:k]

    def totals(self) -> Tuple[int, int, int]:
        """(count, volume, spc_used) over the whole cube."""
        cube = self.cube()
        return (int(cube[0].sum()), int(cube[1].sum()), int(cube[2].sum()))

    # -- persistence + trend snapshots ----------------------------------------
    def _state_path(self, path: Optional[str], suffix: str) -> str:
        if path is not None:
            return path
        if self.catalog.db_path:
            return self.catalog.db_path + suffix
        raise ValueError("no profile-state path: pass one explicitly or "
                         "attach a sqlite mirror to the catalog")

    def save(self, path: Optional[str] = None) -> str:
        """Serialize the cube state beside the sqlite mirror (atomic).

        Default path ``<catalog.db_path>.profiles.npz`` — the analytics
        sibling of the engine's ``.incstate.npz``. Pending deltas are
        flushed first so the snapshot is self-consistent.
        """
        path = self._state_path(path, ".profiles.npz")
        for shard in self._shards:            # flushes may grow the index
            with shard.lock:
                shard.flush(self.groups)
        payload: Dict[str, np.ndarray] = {
            "groups": self.groups.export(),
            "n_shards": np.array([len(self._shards)], np.int64),
        }
        for sid, shard in enumerate(self._shards):
            with shard.lock:
                fids, cols = shard.table.live()
                payload[f"s{sid}::cube"] = shard.cube
                payload[f"s{sid}::ref_now"] = np.array([shard.ref_now])
                payload[f"s{sid}::fids"] = fids
                for name, arr in cols.items():
                    payload[f"s{sid}::{name}"] = arr
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **payload)
        os.replace(tmp, path)
        return path

    def load(self, path: Optional[str] = None) -> bool:
        """Restore a saved cube (restart resumes incrementally instead of a
        cold rebuild). Returns False on missing file / shard-count mismatch
        (caller then falls back to :meth:`rebuild`)."""
        path = self._state_path(path, ".profiles.npz")
        if not os.path.exists(path):
            return False
        with np.load(path, allow_pickle=False) as z:
            if int(z["n_shards"][0]) != len(self._shards):
                return False
            self.groups.restore(z["groups"])
            for sid, shard in enumerate(self._shards):
                with shard.lock:
                    fids = z[f"s{sid}::fids"].astype(np.int64)
                    gids = z[f"s{sid}::gid"].astype(np.int64)
                    shard.load(fids, gids, z[f"s{sid}::size"],
                               z[f"s{sid}::blocks"], z[f"s{sid}::stamp"],
                               float(z[f"s{sid}::ref_now"][0]),
                               cube=z[f"s{sid}::cube"])
        return True

    def record_trend(self, path: Optional[str] = None,
                     now: Optional[float] = None) -> str:
        """Append a compact time-series snapshot (totals + per-age volume +
        per-size counts + per-type counts) — capacity trending across
        restarts without retaining full cubes."""
        path = self._state_path(path, ".profiles.trend.npz")
        now = float(self.clock()) if now is None else float(now)
        cube, cols = self._cube_and_cols(now)
        type_counts = np.array([int(cube[0][cols["type"] == int(t)].sum())
                                for t in sorted(FsType, key=int)], np.int64)
        row = {
            "time": np.array([now]),
            "count": np.array([int(cube[0].sum())], np.int64),
            "volume": np.array([int(cube[1].sum())], np.int64),
            "spc_used": np.array([int(cube[2].sum())], np.int64),
            "age_volume": cube[1].sum(axis=(0, 1))[None, :],      # (1, A)
            "size_count": cube[0].sum(axis=(0, 2))[None, :],      # (1, S)
            "type_count": type_counts[None, :],
        }
        if os.path.exists(path):
            with np.load(path, allow_pickle=False) as z:
                row = {k: np.concatenate([z[k], v]) for k, v in row.items()}
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **row)
        os.replace(tmp, path)
        return path

    @staticmethod
    def load_trend(path: str) -> Dict[str, np.ndarray]:
        with np.load(path, allow_pickle=False) as z:
            return {k: z[k].copy() for k in z.files}
