"""End-to-end training driver: train an LM for a few hundred steps with
the full stack (data pipeline, AdamW, Robinhood-managed checkpoints,
restart-capable loop).

Default preset is CPU-sized (~3M params, 200 steps, minutes). The ``100m``
preset instantiates a ~100M-param gemma2-family model — the same code path
deployed on the production mesh by src/repro/launch/train.py.

    PYTHONPATH=src python examples/train_lm.py [--preset small|100m]
        [--steps N]
"""
import argparse
import sys
import tempfile

sys.argv0 = sys.argv[0]

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    if args.preset == "small":
        steps = args.steps or 200
        argv = ["--arch", "chatglm3-6b", "--smoke", "--steps", str(steps),
                "--batch", "8", "--seq", "64", "--lr", "3e-3",
                "--ckpt-dir", ckpt, "--ckpt-interval", "50"]
    else:
        # ~100M params: gemma2-family, 12 layers, d_model 512
        import dataclasses
        from repro.configs import gemma2_9b
        from repro.models.config import ModelConfig
        cfg = dataclasses.replace(
            gemma2_9b.SMOKE, name="gemma2_100m", n_layers=12, d_model=512,
            n_heads=8, n_kv=4, head_dim=64, d_ff=2048, vocab=32768,
            window=256)
        gemma2_9b.SMOKE = cfg  # install the preset
        steps = args.steps or 300
        argv = ["--arch", "gemma2-9b", "--smoke", "--steps", str(steps),
                "--batch", "8", "--seq", "256", "--lr", "1e-3",
                "--ckpt-dir", ckpt, "--ckpt-interval", "100"]
    sys.argv = [sys.argv[0]] + argv
    train_mod.main()


if __name__ == "__main__":
    main()
