"""Pure-jnp oracle for the columnar policy-scan kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

N_AGG = 14   # count, volume, spc_used, 10 size-profile buckets, matched_max

# size-profile bucket edges (log-ish, matches core.types.SIZE_PROFILE_EDGES)
_EDGES = jnp.array([0, 1, 32, 1 << 10, 32 << 10, 1 << 20, 32 << 20, 1 << 30,
                    32 << 30, 1 << 40], dtype=jnp.float32)

# opcodes (shared with core.policy)
OP_EQ, OP_NE, OP_GT, OP_GE, OP_LT, OP_LE, OP_AND, OP_OR, OP_NOT = range(9)
OP_NOP = -1


def eval_program(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                 operands: jax.Array, max_stack: int = 8) -> jax.Array:
    """Evaluate a postfix predicate program.

    cols: (n_cols, N) f32 columnar attributes; ops/colidx/operands: (P,)
    program (OP_NOP padded). Returns (N,) f32 mask in {0, 1}.
    """
    n = cols.shape[1]
    stack = jnp.zeros((max_stack, n), dtype=jnp.float32)
    sp = jnp.zeros((), jnp.int32)

    def step(carry, instr):
        stack, sp = carry
        op, col, val = instr
        vec = jnp.take(cols, col, axis=0)                   # (N,)
        cmps = jnp.stack([
            (vec == val), (vec != val), (vec > val), (vec >= val),
            (vec < val), (vec <= val)], axis=0).astype(jnp.float32)
        cmp = jnp.take(cmps, jnp.clip(op, 0, 5), axis=0)
        a = jnp.take(stack, jnp.maximum(sp - 1, 0), axis=0)
        b = jnp.take(stack, jnp.maximum(sp - 2, 0), axis=0)
        is_cmp = op < 6
        is_and = op == OP_AND
        is_or = op == OP_OR
        is_not = op == OP_NOT
        is_nop = op < 0
        # value written and its position
        new_val = jnp.where(is_cmp, cmp,
                            jnp.where(is_and, a * b,
                                      jnp.where(is_or,
                                                jnp.clip(a + b, 0, 1),
                                                1.0 - a)))
        write_pos = jnp.where(is_cmp, sp,
                              jnp.where(is_not, sp - 1, sp - 2))
        write_pos = jnp.clip(write_pos, 0, max_stack - 1)
        new_stack = jnp.where(is_nop, stack,
                              stack.at[write_pos].set(new_val))
        new_sp = jnp.where(is_nop, sp,
                           jnp.where(is_cmp, sp + 1,
                                     jnp.where(is_not, sp, sp - 1)))
        return (new_stack, new_sp), None

    (stack, sp), _ = jax.lax.scan(step, (stack, sp),
                                  (ops, colidx, operands))
    return jnp.take(stack, jnp.maximum(sp - 1, 0), axis=0)


def aggregate(mask: jax.Array, size: jax.Array, spc: jax.Array) -> jax.Array:
    """Fused aggregates for a match mask: (N_AGG,) f32.

    [count, volume, spc_used, hist0..hist9, any_match].
    """
    count = jnp.sum(mask)
    volume = jnp.sum(mask * size)
    spc_used = jnp.sum(mask * spc)
    # size-profile histogram of matched rows
    bucket = jnp.sum((size[None, :] >= _EDGES[:, None]).astype(jnp.int32),
                     axis=0) - 1
    bucket = jnp.clip(bucket, 0, 9)
    hist = jnp.zeros((10,), jnp.float32).at[bucket].add(mask)
    any_match = jnp.max(mask, initial=0.0)    # zero-row tables match nothing
    return jnp.concatenate([jnp.stack([count, volume, spc_used]), hist,
                            any_match[None]])


def policy_scan_ref(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                    operands: jax.Array, size_col: int = 0,
                    blocks_col: int = 1, valid_col: int = -1
                    ) -> Tuple[jax.Array, jax.Array]:
    """Oracle: (mask (N,) f32, aggregates (N_AGG,) f32).

    Aggregates: [count, volume, spc_used, hist0..hist9, any_match].
    ``valid_col``: column of 0/1 row validity (-1 = all valid).
    """
    mask = eval_program(cols, ops, colidx, operands)
    if valid_col >= 0:
        mask = mask * cols[valid_col]
    return mask, aggregate(mask, cols[size_col], cols[blocks_col])


def policy_scan_multi_ref(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                          operands: jax.Array, size_col: int = 0,
                          blocks_col: int = 1
                          ) -> Tuple[jax.Array, jax.Array]:
    """Evaluate R padded programs in one columnar pass (vmapped oracle).

    ops/colidx/operands: (R, P) with OP_NOP padding. Returns
    (masks (R, N) f32, agg (N_AGG,) f32 for program 0) — program 0 is, by
    convention, the policy's combined scope∧rules∧extra criteria; the
    remaining rows are per-rule masks used for vectorized attribution.
    """
    masks = jax.vmap(
        lambda o, c, v: eval_program(cols, o, c, v))(ops, colidx, operands)
    agg = aggregate(masks[0], cols[size_col], cols[blocks_col])
    return masks, agg


def attribute_ref(masks: jax.Array) -> jax.Array:
    """First-match-wins rule attribution over (R, N) program masks.

    Program 0 is the combined criteria; programs 1..R-1 are the per-rule
    conditions in priority order. Returns (N,) i32: the index of the first
    rule whose mask is set (0-based into the rule list, i.e. program r maps
    to rule r-1), or -1 where no rule matches. Mirrors
    ``PolicyEngine._attribute`` exactly — attribution ignores program 0;
    callers gate by it separately.
    """
    n = masks.shape[1]
    if masks.shape[0] <= 1:
        return jnp.full((n,), -1, jnp.int32)
    rules = masks[1:] > 0.5                       # (R-1, N)
    first = jnp.argmax(rules, axis=0).astype(jnp.int32)
    return jnp.where(jnp.any(rules, axis=0), first, -1)


def aggregate_multi(masks: jax.Array, size: jax.Array, spc: jax.Array
                    ) -> jax.Array:
    """Per-program fused aggregates: (R, N_AGG) f32, one row per mask."""
    return jax.vmap(lambda m: aggregate(m, size, spc))(masks)


def policy_scan_batch_ref(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                          operands: jax.Array, size_col: int = 0,
                          blocks_col: int = 1, valid_col: int = -1
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the single-launch batch matcher.

    Returns (masks (R, N) f32, rule_idx (N,) i32, agg (R, N_AGG) f32):
    every program's mask, fused first-match-wins attribution over programs
    1..R-1, and per-program size/blocks reductions — the full match→plan
    payload of one policy run in one columnar pass.
    """
    masks = jax.vmap(
        lambda o, c, v: eval_program(cols, o, c, v))(ops, colidx, operands)
    if valid_col >= 0:
        masks = masks * cols[valid_col][None, :]
    return (masks, attribute_ref(masks),
            aggregate_multi(masks, cols[size_col], cols[blocks_col]))
