"""Live `robinhood --top`-style status board off the telemetry registry.

Runs the full pipeline (changelog ingest -> catalog -> device store ->
policy runs -> report serving) against the simulated Lustre while a
background mutator keeps the filesystem churning, and every refresh
interval repaints one status frame computed *entirely* from
``catalog.telemetry`` — counter deltas for rates, callback gauges for
backlog/lag, histograms for serve latency — plus the usual top-files
table. Nothing here reaches into component internals: if the board can
show it, an external Prometheus scrape of ``render_prometheus()`` can
too.

    PYTHONPATH=src python examples/fs_top.py            # 5 frames
    PYTHONPATH=src python examples/fs_top.py 20         # more frames
"""
import random
import sys
import time

from repro.core import (Catalog, DeviceColumnStore, EventPipeline,
                        PipelineConfig, PolicyDefinition, PolicyEngine,
                        Reports, StatsAggregator, format_size)
from repro.fs import LustreSim

INTERVAL = 0.5          # seconds per frame
N_FILES = 2_000


def build():
    fs = LustreSim(n_osts=4, n_mdts=1)
    proj = fs.mkdir(fs.root_fid(), "proj")
    rng = random.Random(7)
    fids = []
    for i in range(N_FILES):
        f = fs.create(proj, f"f{i}.dat", owner=f"u{i % 5}",
                      uid=f"u{i % 5}")
        fs.write(f, rng.randrange(100, 1_000_000))
        fids.append(f)

    cat = Catalog(n_shards=4)
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig())
    pipe.process_once(10 * N_FILES)

    store = DeviceColumnStore(cat, mesh=None)
    store.refresh()
    rep = Reports(cat, stats).attach_device_store(store)
    eng = PolicyEngine(cat)
    eng.attach_device_store(store)
    eng.register(PolicyDefinition.from_config(
        "sweep", lambda e, params: True, scope="size > 500k",
        evaluator="policy_scan_mesh", mutates=False, dry_run=True))
    return fs, proj, fids, rng, stream, pipe, store, rep, eng


def churn(fs, proj, fids, rng):
    """One tick of filesystem activity for the pipeline to chase."""
    for _ in range(200):
        fs.write(rng.choice(fids), rng.randrange(100, 1_000_000))
    f = fs.create(proj, f"new{rng.randrange(1 << 30)}.dat", owner="u0",
                  uid="u0")
    fs.write(f, rng.randrange(100, 1_000_000))
    fids.append(f)


def _hist(snap, name):
    fam = snap.get(name, {}).get("series", {})
    out = {}
    for labels, s in fam.items():
        out[labels] = s
    return out


def frame(i, reg, prev_counters, dt, rep):
    snap = reg.snapshot()
    cur = reg.counter_values()
    rate = {k: (cur.get(k, 0) - prev_counters.get(k, 0)) / dt
            for k in cur}

    def r(prefix):
        return sum(v for k, v in rate.items() if k.startswith(prefix))

    def tot(prefix):
        return int(sum(v for k, v in cur.items() if k.startswith(prefix)))

    lag = max((v for k, f in snap.items() if k.startswith("changelog_lag")
               for v in f["series"].values()), default=0.0)
    backlog = int(sum(v for k, f in snap.items()
                      if k.startswith("changelog_backlog")
                      for v in f["series"].values()))

    print(f"\x1b[2J\x1b[H== fs_top — frame {i} "
          f"(every {INTERVAL:.1f}s, all numbers from the registry) ==")
    print(f"ingest   {r('pipeline_events_folded'):8.0f} ev/s folded   "
          f"backlog {backlog:6d} rec   lag {lag:6.2f}s")
    print(f"refresh  {r('store_rows_scattered'):8.0f} rows/s scattered "
          f" bytes {format_size(int(r('store_bytes_moved')))}/s   "
          f"full uploads {tot('store_full_uploads')}")
    print(f"matching {r('store_queries'):8.0f} store queries/s   "
          f"fallbacks {tot('fallback')}   "
          f"alerts {tot('alerts_fired')}")
    lat = _hist(snap, "reports_serve_seconds")
    if lat:
        print("serve latency (per query kind):")
        for labels, s in sorted(lat.items()):
            if not s["count"]:
                continue
            print(f"  {labels:<55} n={s['count']:<5d} "
                  f"p50={s['p50'] * 1e3:7.2f}ms p99={s['p99'] * 1e3:7.2f}ms")
    print("top consumers (Reports.top_files, served from the store):")
    for e in rep.top_files(by="size", k=5):
        print(f"  {format_size(int(e['size'])):>10}  {e['path']}")
    return cur


def main(n_frames: int = 5) -> None:
    fs, proj, fids, rng, stream, pipe, store, rep, eng = build()
    reg = rep.telemetry
    prev = reg.counter_values()
    t_prev = time.perf_counter()
    for i in range(n_frames):
        churn(fs, proj, fids, rng)
        pipe.process_once(100_000)
        store.refresh()
        eng.run("sweep", matching="full")
        rep.du("/proj")
        rep.find("size > 800k")
        now = time.perf_counter()
        prev = frame(i, reg, prev, max(now - t_prev, 1e-9), rep)
        t_prev = now
        time.sleep(max(0.0, INTERVAL - (time.perf_counter() - now)))
    print("\nPrometheus exposition (first 12 lines of "
          "registry.render_prometheus()):")
    for line in reg.render_prometheus().splitlines()[:12]:
        print(" ", line)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 5)
