"""Generic policy engine (C5, C7, C10) — robinhood v3 plugin architecture.

A *policy* is: a **scope** (criteria restricting which entries it may ever
touch), ordered **rules** (criteria -> parameters), an **action** (plugin
callable), **triggers** (periodic / usage-watermark / manual), and run
options (sort order, rate limits, target volume/count).

This is the paper's v3 "generic policies": archive/purge/rmdir are just
shipped plugin configurations; users register custom actions the same way
(see ``plugins.py``). Watermark triggers reproduce the per-OST purge (C7):
when an OST exceeds ``high_wm``, the engine runs the policy restricted to
entries striped on that OST until usage is projected below ``low_wm``.

Execution is **batched and shard-parallel** (paper SII-B1: policy runs over
billions of entries must never degenerate into per-entry scans):

* **matching** goes through a pluggable evaluator backend — ``"numpy"``
  (vectorized column masks) or ``"policy_scan"`` (the Pallas TPU kernel,
  falling back to its jitted oracle off-TPU) — and rule **attribution** is
  vectorized too: one mask per rule, first-match-wins by rule order, no
  per-entry Python re-evaluation;
* **budgets** (target volume / max actions) are planned on batch
  boundaries: the engine takes the minimal prefix of the sorted candidate
  list whose projected volume meets the remaining target, executes it, and
  only re-plans if failures left the target unmet. The actioned set is a
  pure function of the catalog snapshot — deterministic across
  ``n_threads``, with no overshoot races;
* **execution** draws work in fid chunks from a deque; each chunk is
  fetched with :meth:`Catalog.get_batch` (one lock acquisition per shard
  group) and applied either through an action's optional batch interface
  (``action.action_batch(entries, params) -> list[bool]``) or the scalar
  callable.

The pre-batching scalar path is kept as ``execution="scalar"`` so
``benchmarks/bench_policy.py`` can report the speedup honestly.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog
from .policy import ALWAYS, Expr, PolicyError, all_of, any_of, parse_expr
from .types import Entry, FsType

Action = Callable[[Entry, dict], bool]   # returns True on success
# Optional vectorized form, attached to the Action callable as the
# ``action_batch`` attribute: (entries, shared params) -> per-entry success.
BatchAction = Callable[[List[Entry], dict], List[bool]]

EVALUATORS = ("numpy", "policy_scan")


@dataclasses.dataclass
class Rule:
    name: str
    condition: Expr
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PolicyDefinition:
    name: str
    action: Action
    scope: Expr = dataclasses.field(default_factory=lambda: ALWAYS)
    rules: List[Rule] = dataclasses.field(default_factory=list)
    # run behaviour
    sort_by: str = "atime"          # LRU by default, like robinhood purge
    sort_desc: bool = False
    max_actions_per_run: int = 0    # 0 = unlimited
    max_volume_per_run: int = 0     # 0 = unlimited (bytes)
    n_threads: int = 1
    dry_run: bool = False
    batch_size: int = 512           # entries per execution chunk
    evaluator: str = "numpy"        # default matching backend

    @classmethod
    def from_config(cls, name: str, action: Action, scope: str = "true",
                    rules: Optional[Sequence[Tuple[str, str, dict]]] = None,
                    **kw) -> "PolicyDefinition":
        """Build from string criteria — 'a few lines of configuration'."""
        pd = cls(name=name, action=action, scope=parse_expr(scope), **kw)
        for rname, cond, params in rules or []:
            pd.rules.append(Rule(rname, parse_expr(cond), params))
        return pd


@dataclasses.dataclass
class RunReport:
    policy: str
    matched: int = 0
    succeeded: int = 0
    failed: int = 0
    volume: int = 0          # bytes touched (e.g. freed / archived)
    elapsed: float = 0.0
    trigger: str = "manual"
    matched_volume: int = 0  # total bytes of all matched entries
    skipped: int = 0         # matched but gone from the catalog by exec time
    evaluator: str = "numpy"
    rounds: int = 0          # budget re-planning rounds executed


class UsageWatermarkTrigger:
    """Per-resource usage trigger (OST / pool / HBM page pool).

    ``usage_fn()`` returns a list of (resource_key, used, capacity); when
    ``used/capacity`` exceeds ``high_pct``, the policy runs with a target of
    freeing down to ``low_pct``, restricted by ``restrict_fn(resource_key)``.
    """

    def __init__(self, usage_fn: Callable[[], List[Tuple[object, int, int]]],
                 high_pct: float, low_pct: float,
                 restrict_fn: Callable[[object], Expr]) -> None:
        self.usage_fn = usage_fn
        self.high_pct = high_pct
        self.low_pct = low_pct
        self.restrict_fn = restrict_fn

    def check(self) -> List[Tuple[object, Expr, int]]:
        """Returns (resource, extra_criteria, bytes_to_free) per firing."""
        out = []
        for key, used, cap in self.usage_fn():
            if cap <= 0:
                continue
            if 100.0 * used / cap >= self.high_pct:
                target = used - int(cap * self.low_pct / 100.0)
                out.append((key, self.restrict_fn(key), target))
        return out


@dataclasses.dataclass
class _Plan:
    """One execution round: parallel arrays of planned work, sorted order."""
    fids: np.ndarray        # int64
    sizes: np.ndarray       # int64 (match-time snapshot, used for budgets)
    rule_idx: np.ndarray    # int32, -1 = no rule (empty params)


class PolicyEngine:
    """Evaluates policies over the catalog and applies actions."""

    def __init__(self, catalog: Catalog, clock: Callable[[], float] = time.time
                 ) -> None:
        self.catalog = catalog
        self.clock = clock
        self.policies: Dict[str, PolicyDefinition] = {}
        self.triggers: List[Tuple[str, UsageWatermarkTrigger]] = []
        self.history: List[RunReport] = []
        self._lock = threading.Lock()

    def register(self, policy: PolicyDefinition) -> None:
        self.policies[policy.name] = policy

    def add_watermark_trigger(self, policy_name: str,
                              trigger: UsageWatermarkTrigger) -> None:
        self.triggers.append((policy_name, trigger))

    # -- matching -----------------------------------------------------------------
    def _match(self, policy: PolicyDefinition, extra: Optional[Expr],
               now: float, evaluator: str = "numpy"
               ) -> Tuple[np.ndarray, np.ndarray, Dict[str, np.ndarray], str]:
        """One columnar pass: final mask + vectorized rule attribution.

        Returns (mask, rule_idx, cols, evaluator_used). ``rule_idx[i]`` is
        the index of the first (highest-priority) rule matching row i, or -1
        when the policy has no rules. The ``policy_scan`` backend silently
        falls back to numpy for host-only (glob) predicates.
        """
        if evaluator not in EVALUATORS:
            raise PolicyError(f"unknown evaluator {evaluator!r}")
        cols = self.catalog.arrays()
        rule_exprs = [r.condition for r in policy.rules]
        if evaluator == "policy_scan":
            try:
                from ..kernels.policy_scan.ops import match_programs
                full = all_of([policy.scope]
                              + ([any_of(rule_exprs)] if rule_exprs else [])
                              + ([extra] if extra else []))
                masks, _agg = match_programs(cols, [full] + rule_exprs,
                                             self.catalog.strings, now)
                return (masks[0], self._attribute(masks[0], masks[1:]),
                        cols, "policy_scan")
            except PolicyError:
                pass          # glob predicates run on the host
        strings = self.catalog.strings
        mask = policy.scope.mask(cols, strings, now)
        rule_masks = [r.mask(cols, strings, now) for r in rule_exprs]
        if rule_masks:
            mask &= np.logical_or.reduce(rule_masks)
        if extra is not None:
            mask &= extra.mask(cols, strings, now)
        return mask, self._attribute(mask, rule_masks), cols, "numpy"

    @staticmethod
    def _attribute(mask: np.ndarray, rule_masks: List[np.ndarray]
                   ) -> np.ndarray:
        """First-match-wins rule index per row (np.select-style priority)."""
        if not rule_masks:
            return np.full(mask.shape, -1, dtype=np.int32)
        stacked = np.stack(rule_masks)
        idx = np.argmax(stacked, axis=0).astype(np.int32)   # first True wins
        idx[~stacked.any(axis=0)] = -1
        return idx

    def _rule_params(self, policy: PolicyDefinition, e: Entry, now: float) -> dict:
        for rule in policy.rules:
            if rule.condition.evaluate(e, now):
                return rule.params
        return {}

    # -- execution -----------------------------------------------------------------
    def run(self, policy_name: str, extra_criteria: Optional[Expr] = None,
            target_volume: int = 0, trigger: str = "manual",
            evaluator: Optional[str] = None,
            execution: str = "batched") -> RunReport:
        """One policy run: match -> sort -> apply until targets met.

        ``evaluator`` overrides the policy's matching backend for this run;
        ``execution="scalar"`` keeps the legacy per-entry path (benchmarks /
        bisection only).
        """
        policy = self.policies[policy_name]
        now = self.clock()
        t0 = time.perf_counter()
        mask, rule_idx, cols, used_eval = self._match(
            policy, extra_criteria, now, evaluator or policy.evaluator)
        fids = cols["fid"][mask]
        sizes = cols["size"][mask]
        report = RunReport(policy=policy_name, matched=int(fids.size),
                           trigger=trigger, evaluator=used_eval,
                           matched_volume=int(sizes.sum()) if fids.size else 0)

        if fids.size:
            order = np.argsort(cols[policy.sort_by][mask], kind="stable")
            if policy.sort_desc:
                order = order[::-1]
            plan = _Plan(fids=fids[order], sizes=sizes[order],
                         rule_idx=rule_idx[mask][order])
            budget_volume = target_volume or policy.max_volume_per_run
            budget_count = policy.max_actions_per_run
            if execution == "scalar":
                self._run_scalar(policy, plan, now, report,
                                 budget_volume, budget_count)
            else:
                self._run_batched(policy, plan, now, report,
                                  budget_volume, budget_count)

        report.elapsed = time.perf_counter() - t0
        self.history.append(report)
        return report

    # -- batched execution --------------------------------------------------------
    def _run_batched(self, policy: PolicyDefinition, plan: _Plan, now: float,
                     report: RunReport, budget_volume: int,
                     budget_count: int) -> None:
        """Budgeted rounds of chunk-parallel execution.

        Each round takes the minimal prefix of the remaining sorted work
        whose projected (match-time) volume/count meets the remaining
        budget, so the stop decision happens on batch boundaries and the
        actioned set never depends on thread timing. A follow-up round only
        happens when failures/skips left a budget unmet.
        """
        n = len(plan.fids)
        pos = 0
        while pos < n:
            take = n - pos
            if budget_volume:
                remaining = budget_volume - report.volume
                if remaining <= 0:
                    break
                csum = np.cumsum(plan.sizes[pos:])
                take = min(take, int(np.searchsorted(csum, remaining)) + 1)
            if budget_count:
                remaining_n = budget_count - report.succeeded
                if remaining_n <= 0:
                    break
                take = min(take, remaining_n)
            self._execute_round(policy, plan, pos, pos + take, now, report)
            report.rounds += 1
            pos += take
            if not budget_volume and not budget_count:
                break                      # single round covers everything

    def _execute_round(self, policy: PolicyDefinition, plan: _Plan,
                       lo: int, hi: int, now: float,
                       report: RunReport) -> None:
        """Execute plan[lo:hi] in chunks drawn from a deque by N workers."""
        chunk = max(1, policy.batch_size)
        work: "deque[slice]" = deque(slice(i, min(i + chunk, hi))
                                     for i in range(lo, hi, chunk))

        def worker() -> None:
            while True:
                try:
                    sl = work.popleft()    # atomic; IndexError ends worker
                except IndexError:
                    return
                self._apply_chunk(policy, plan, sl, now, report)

        n_threads = min(max(1, policy.n_threads), len(work))
        if n_threads <= 1:
            worker()
            return
        threads = [threading.Thread(target=worker, daemon=True)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _apply_chunk(self, policy: PolicyDefinition, plan: _Plan,
                     sl: slice, now: float, report: RunReport) -> None:
        fids = plan.fids[sl]
        sizes = plan.sizes[sl]
        ridx = plan.rule_idx[sl]
        if policy.dry_run:
            with self._lock:
                report.succeeded += len(fids)
                report.volume += int(sizes.sum())
            return
        entries = self.catalog.get_batch(fids.tolist())
        ok = np.zeros(len(fids), dtype=bool)
        skipped = np.array([e is None for e in entries])
        batch_fn: Optional[BatchAction] = getattr(policy.action,
                                                  "action_batch", None)
        for ri in np.unique(ridx):
            group = np.nonzero((ridx == ri) & ~skipped)[0]
            if not group.size:
                continue
            params = policy.rules[ri].params if ri >= 0 else {}
            group_entries = [entries[i] for i in group]
            if batch_fn is not None:
                try:
                    results = batch_fn(group_entries, params)
                except Exception:
                    results = [False] * len(group_entries)
                ok[group] = results
            else:
                for i, e in zip(group, group_entries):
                    try:
                        ok[i] = policy.action(e, params)
                    except Exception:
                        ok[i] = False
        done = ok & ~skipped
        with self._lock:
            report.succeeded += int(done.sum())
            report.failed += int((~ok & ~skipped).sum())
            report.skipped += int(skipped.sum())
            report.volume += int(sizes[done].sum())

    # -- legacy scalar execution (benchmark baseline) ------------------------------
    def _run_scalar(self, policy: PolicyDefinition, plan: _Plan, now: float,
                    report: RunReport, budget_volume: int,
                    budget_count: int) -> None:
        """Pre-batching hot path: O(n) dequeues, per-entry catalog.get and
        Python rule re-evaluation, racy post-hoc budget checks."""
        work = list(plan.fids.tolist())
        work_lock = threading.Lock()
        stop = threading.Event()

        def runner() -> None:
            while not stop.is_set():
                with work_lock:
                    if not work:
                        return
                    fid = work.pop(0)
                e = self.catalog.get(fid)
                if e is None:
                    continue
                params = self._rule_params(policy, e, now)
                size = e.size
                if policy.dry_run:
                    ok = True
                else:
                    try:
                        ok = policy.action(e, params)
                    except Exception:
                        ok = False
                with self._lock:
                    if ok:
                        report.succeeded += 1
                        report.volume += size
                    else:
                        report.failed += 1
                    if budget_volume and report.volume >= budget_volume:
                        stop.set()
                    if budget_count and report.succeeded >= budget_count:
                        stop.set()

        threads = [threading.Thread(target=runner, daemon=True)
                   for _ in range(max(1, policy.n_threads))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def check_triggers(self) -> List[RunReport]:
        """Fire any watermark triggers whose threshold is exceeded (C7)."""
        reports = []
        for policy_name, trig in self.triggers:
            for key, extra, target in trig.check():
                reports.append(self.run(policy_name, extra_criteria=extra,
                                        target_volume=target,
                                        trigger=f"watermark:{key}"))
        return reports

    def run_all_periodic(self) -> List[RunReport]:
        return [self.run(name) for name in self.policies]
