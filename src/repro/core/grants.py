"""Subject → grant-set model for multi-tenant report scoping (``subject=``).

The ROADMAP's "millions of users" goal means ``find``/``du``/top-N/profile
queries arrive scoped to what one *subject* (a user, a service account, an
auditor) may see. This module is the host-side authority for that
visibility:

* a **subject** owns a set of grants — owner names (uid ownership), group
  names (gid membership) and directory subtrees (every entry at or under
  a path prefix);
* :meth:`GrantTable.visible_mask` is the scalar oracle: a boolean
  visibility mask over any catalog column dict — the fold the host report
  paths filter by, and the differential reference the device plane is
  pinned to byte-for-byte (``tests/core/test_tenant_scoping.py``);
* the :class:`~repro.core.device_store.DeviceColumnStore` permissions
  plane (``enable_permissions_plane``) pre-materializes the same
  semantics as packed per-subject ``uint32`` bitsets over resident rows
  (subtree grants resolved through the reports plane's sorted-path
  mirrors) and ANDs the unpacked subject bitset into the mesh kernels'
  match masks — tenant scoping at serving time is one fused AND, not a
  second scan.

Every mutation bumps :attr:`GrantTable.version`; consumers key
materialized state on it (the store re-materializes stale bitsets on the
next scoped query, mirroring its catalog-version refresh contract).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional

import numpy as np


class Subject:
    """One subject's grant set. Immutable — :meth:`GrantTable.grant`
    replaces the whole record so readers never see a half-updated set."""

    __slots__ = ("name", "owners", "groups", "subtrees")

    def __init__(self, name: str, owners: Iterable[str],
                 groups: Iterable[str], subtrees: Iterable[str]) -> None:
        self.name = name
        self.owners = tuple(owners)
        self.groups = tuple(groups)
        # normalized: a subtree grant covers the prefix row itself plus
        # everything under "<prefix>/" (same range shape as rbh-du)
        self.subtrees = tuple(p.rstrip("/") for p in subtrees)


class GrantTable:
    """Dense subject registry: name -> subject id -> grant set.

    Subject ids are append-only and dense (the device store's permission
    bitsets index by them); grant *content* may change at any time and
    bumps :attr:`version` so materialized bitsets know to refresh.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._subjects: List[Subject] = []
        self._ids: Dict[str, int] = {}
        self.version = 0

    def __len__(self) -> int:
        return len(self._subjects)

    def add_subject(self, name: str, owners: Optional[Iterable[str]] = None,
                    groups: Iterable[str] = (),
                    subtrees: Iterable[str] = ()) -> int:
        """Register ``name`` and return its dense subject id.

        ``owners=None`` (the default) grants ownership of ``name``'s own
        files — the common "a user sees what they own" case; pass ``()``
        for a subject with no uid grant (e.g. a subtree-only auditor).
        Re-registering raises — extend an existing subject with
        :meth:`grant` instead.
        """
        with self._lock:
            if name in self._ids:
                raise ValueError(f"subject {name!r} already registered")
            sid = len(self._subjects)
            self._ids[name] = sid
            self._subjects.append(Subject(
                name, (name,) if owners is None else owners, groups,
                subtrees))
            self.version += 1
            return sid

    def grant(self, name: str, owners: Iterable[str] = (),
              groups: Iterable[str] = (),
              subtrees: Iterable[str] = ()) -> None:
        """Extend an existing subject's grant set (bumps ``version`` —
        materialized bitsets refresh on the next scoped query)."""
        with self._lock:
            sid = self._ids[name]
            s = self._subjects[sid]
            self._subjects[sid] = Subject(
                name, s.owners + tuple(owners), s.groups + tuple(groups),
                s.subtrees + tuple(subtrees))
            self.version += 1

    def _unknown(self, name: str) -> KeyError:
        known = ", ".join(sorted(self._ids)) or "<none registered>"
        return KeyError(f"unknown subject {name!r} (known subjects: {known})")

    def subject_id(self, name: str) -> int:
        with self._lock:
            try:
                return self._ids[name]
            except KeyError:
                raise self._unknown(name) from None

    def subject(self, name: str) -> Subject:
        with self._lock:
            try:
                return self._subjects[self._ids[name]]
            except KeyError:
                raise self._unknown(name) from None

    def subjects(self) -> List[Subject]:
        """Snapshot of every subject in id order (the bitset row order)."""
        with self._lock:
            return list(self._subjects)

    def visible_mask(self, name: str, cols, strings) -> np.ndarray:
        """Boolean row visibility for ``name`` over a catalog column dict
        — the scalar oracle every accelerated scoping path must match.

        ``cols`` needs the interned ``owner``/``group`` code columns;
        subtree grants additionally read the ``_paths`` gather. Names
        that were never interned (no such owner/group exists in the
        catalog) simply match nothing.
        """
        s = self.subject(name)
        owner = np.asarray(cols["owner"])
        grp = np.asarray(cols["group"])
        vis = np.zeros(owner.shape, dtype=bool)
        ocodes = [c for c in (strings.code_of(u) for u in s.owners)
                  if c is not None]
        if ocodes:
            vis |= np.isin(owner, ocodes)
        gcodes = [c for c in (strings.code_of(g) for g in s.groups)
                  if c is not None]
        if gcodes:
            vis |= np.isin(grp, gcodes)
        if s.subtrees:
            paths = np.asarray(cols["_paths"])
            for pref in s.subtrees:
                vis |= (paths == pref) | np.char.startswith(paths,
                                                            pref + "/")
        return vis
