"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local(4096)/global alternating attention, logit softcaps (50 attn / 30
final), sandwich (pre+post) norms, head_dim 256, tied embeddings, embedding
scaling. [arXiv:2408.00118; hf]
"""
from repro.models.config import (ATTN_FULL, ATTN_LOCAL, LayerSpec,
                                 ModelConfig)

_PATTERN = (LayerSpec(mix=ATTN_LOCAL), LayerSpec(mix=ATTN_FULL))

CONFIG = ModelConfig(
    name="gemma2_9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv=8, head_dim=256,
    d_ff=14336, vocab=256000,
    pattern=_PATTERN, window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    embed_scale=True, tie_embeddings=True, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="gemma2_9b_smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN, window=32,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    embed_scale=True, tie_embeddings=True,
)
