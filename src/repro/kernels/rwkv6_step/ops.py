"""Public RWKV6 decode-step op."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rwkv6_step_pallas
from .ref import rwkv6_step_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def rwkv6_step(r, k, v, w, u, state, use_kernel: bool = True):
    if not use_kernel:
        return rwkv6_step_ref(r, k, v, w, u, state)
    return rwkv6_step_pallas(r, k, v, w, u, state,
                             interpret=not _on_tpu())
