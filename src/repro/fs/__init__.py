"""Filesystem backends: simulated Lustre (OSTs/pools/DNE/HSM) and POSIX."""
from .base import FsBackend, stat_batch
from .lustrefs import LustreSim, Ost
from .posixfs import PosixFs
from .hsm_backend import HsmBackend

__all__ = ["FsBackend", "LustreSim", "Ost", "PosixFs", "HsmBackend",
           "stat_batch"]
