"""O(1) pre-aggregated stats == recomputation from scratch (paper C6)."""
import numpy as np
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, never hard-error collection
from hypothesis import given, settings, strategies as st

from repro.core import (Catalog, ChangelogCounters, DirUsage, Entry, FsType,
                        StatsAggregator)
from repro.core.types import ChangelogRecord, ChangelogType


def _rand_ops(seed, n):
    rng = np.random.default_rng(seed)
    ops = []
    live = set()
    for i in range(n):
        kind = rng.choice(["ins", "upd", "del"])
        if kind == "ins" or not live:
            fid = 1000 + i
            live.add(fid)
            ops.append(("ins", fid, int(rng.integers(0, 10000)),
                        ["a", "b", "c"][rng.integers(0, 3)]))
        elif kind == "upd":
            fid = int(rng.choice(sorted(live)))
            ops.append(("upd", fid, int(rng.integers(0, 10000)), None))
        else:
            fid = int(rng.choice(sorted(live)))
            live.discard(fid)
            ops.append(("del", fid, 0, None))
    return ops


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500), n=st.integers(1, 120))
def test_incremental_equals_recompute(seed, n):
    cat = Catalog(n_shards=2)
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    for kind, fid, size, owner in _rand_ops(seed, n):
        if kind == "ins":
            cat.upsert(Entry(fid=fid, name=f"f{fid}", path=f"/f{fid}",
                             type=FsType.FILE, size=size, blocks=size,
                             owner=owner))
        elif kind == "upd":
            cat.update_fields(fid, size=size, blocks=size)
        else:
            cat.remove(fid)
    # recompute ground truth by full scan of the catalog
    for owner in ("a", "b", "c"):
        truth_n = truth_vol = 0
        for e in cat.entries():
            if e.owner == owner:
                truth_n += 1
                truth_vol += e.size
        rep = stats.report_user(owner)
        got_n = sum(r["count"] for r in rep)
        got_vol = sum(r["volume"] for r in rep)
        assert (got_n, got_vol) == (truth_n, truth_vol)
    # totals
    assert stats.total.count == len(cat)


def test_async_mode_converges():
    cat = Catalog(n_shards=2)
    stats = StatsAggregator(cat.strings, async_mode=True)
    cat.add_delta_hook(stats.on_delta)
    for fid in range(1, 201):
        cat.upsert(Entry(fid=fid, name=f"f{fid}", path=f"/f{fid}",
                         type=FsType.FILE, size=10, blocks=10, owner="u"))
    stats.flush()
    rep = stats.report_user("u")
    assert rep[0]["count"] == 200 and rep[0]["volume"] == 2000
    stats.close()


def test_size_profile_and_top_users():
    cat = Catalog()
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    sizes = [0, 10, 100, 2048, 50 << 10, 2 << 20, 2 << 30]
    for i, s in enumerate(sizes):
        cat.upsert(Entry(fid=i + 1, name=f"f{i}", path=f"/f{i}",
                         type=FsType.FILE, size=s, blocks=s, owner="foo"))
    prof = stats.user_size_profile("foo")
    assert prof["0"] == 1 and prof["1~31"] == 1 and prof["32~1K"] == 1
    assert prof["1K~31K"] == 1 and prof["32K~1M"] == 1
    assert prof["1M~31M"] == 1 and prof["1G~31G"] == 1
    top = stats.top_users(by="volume", k=1)
    assert top[0]["user"] == "foo"


def test_changelog_counters_per_job():
    c = ChangelogCounters()
    for i in range(5):
        c.on_record(ChangelogRecord(seq=i, type=ChangelogType.CREAT, fid=i,
                                    uid="alice", jobid="job1"))
    c.on_record(ChangelogRecord(seq=9, type=ChangelogType.UNLNK, fid=1,
                                uid="bob", jobid="job2"))
    snap = c.snapshot()
    assert snap["per_job"]["job1"][int(ChangelogType.CREAT)] == 5
    assert snap["per_user"]["bob"][int(ChangelogType.UNLNK)] == 1
    assert snap["total"] == 6


def test_dir_usage_counters():
    du = DirUsage(max_depth=2)
    du.on_file(+1, "/a/b/c/f1", 100, 100)
    du.on_file(+1, "/a/b/f2", 50, 50)
    du.on_file(+1, "/a/f3", 25, 25)
    assert du.du("/a")["volume"] == 175
    assert du.du("/a/b")["volume"] == 150
    assert du.du("/")["count"] == 3
    du.on_file(-1, "/a/b/f2", 50, 50)
    assert du.du("/a/b")["volume"] == 100
