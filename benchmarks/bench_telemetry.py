"""Telemetry plane overhead: instrumented vs uninstrumented hot paths.

Every counter, histogram and span in the pipeline is registry-backed;
the registry's contract is that observability is *cheap enough to leave
on* — warm store-backed matching and report serving must stay within 5%
of the same workload with a disabled registry (every write a no-op).
This bench runs the identical warm loop twice — once on a default
(enabled) registry, once with ``MetricRegistry(enabled=False)`` injected
into the catalog — and reports the throughput ratio, plus the raw
per-write costs of the three primitive instruments.

``run_telemetry_assertion`` is the tier-2 CI entry: asserts the ratio
and that the enabled run's Prometheus exposition round-trips through
``parse_prometheus``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState,
                        MetricRegistry, PolicyDefinition, PolicyEngine,
                        Reports, parse_prometheus)

NOW = float(2 ** 20)
FIND_EXPR = "type == file and size > 3900k and last_access > 1000s"
SCOPE = "size > 2000k and last_access > 1000s"


def _catalog(n: int, registry: MetricRegistry) -> Catalog:
    rng = np.random.default_rng(0)
    cat = Catalog(n_shards=16, telemetry=registry)
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        cat.upsert_batch([Entry(
            fid=i + 1, name=f"f{i + 1}", path=f"/fs/d{i % 64}/f{i + 1}",
            type=FsType.FILE if (i % 10) else FsType.DIR,
            size=int(rng.integers(0, 2 ** 12)) * 1024,
            blocks=int(rng.integers(0, 2 ** 10)),
            owner=f"user{i % 8}", group=f"grp{i % 4}",
            hsm_state=HsmState(int(rng.integers(0, 5))),
            atime=NOW - float(rng.integers(0, 10_000)),
            mtime=NOW - float(rng.integers(0, 10_000)),
        ) for i in range(lo, hi)])
    return cat


def _churn(cat: Catalog, n: int, frac: float, round_: int) -> None:
    # same rotating equal-per-shard dirty pattern as bench_reports: the
    # scatter buckets stay shape-stable so the warm rounds never compile
    per_shard = max(int(n * frac) // cat.n_shards, 1)
    span = n // cat.n_shards
    fids = [s + cat.n_shards * ((round_ * per_shard + j) % span)
            for s in range(cat.n_shards) for j in range(per_shard)]
    cat.update_fields_batch([f if f else cat.n_shards for f in fids],
                            size=(3 + round_) << 20)


def _warm_loop(n: int, enabled: bool, rounds: int) -> tuple:
    """One full deployment; returns (best round seconds, registry)."""
    reg = MetricRegistry(enabled=enabled)
    cat = _catalog(n, reg)
    clock = lambda: NOW                                      # noqa: E731
    store = DeviceColumnStore(cat, mesh=None)
    rep = Reports(cat, clock=clock).attach_device_store(store)
    eng = PolicyEngine(cat, clock=clock)
    eng.attach_device_store(store)
    eng.register(PolicyDefinition.from_config(
        "sweep", lambda e, params: True, scope=SCOPE,
        evaluator="policy_scan_mesh", mutates=False, dry_run=True))

    # warm every shape: upload, scatter bucket, each query kind, the run
    _churn(cat, n, 0.01, rounds)
    store.refresh()
    rep.find(FIND_EXPR)
    rep.top_files(k=25)
    rep.du("/fs/d7")
    eng.run("sweep", matching="full")

    best = float("inf")
    for round_ in range(rounds):
        _churn(cat, n, 0.01, round_)
        t0 = time.perf_counter()
        store.refresh()
        rep.find(FIND_EXPR)
        rep.top_files(k=25)
        rep.du("/fs/d7")
        eng.run("sweep", matching="full")
        best = min(best, time.perf_counter() - t0)
    assert rep.last_fallback_reason is None, rep.last_fallback_reason
    return best, reg


def _primitive_costs(iters: int = 50_000) -> list:
    """Raw per-write cost of the three instruments (the overhead floor)."""
    reg = MetricRegistry()
    c = reg.counter("bench_ctr", stage="x")
    h = reg.histogram("bench_hist")
    rows = []
    t0 = time.perf_counter()
    for _ in range(iters):
        c.inc()
    rows.append(("telemetry_counter_inc",
                 1e6 * (time.perf_counter() - t0) / iters, f"{iters}_incs"))
    t0 = time.perf_counter()
    for _ in range(iters):
        h.observe(0.01)
    rows.append(("telemetry_histogram_observe",
                 1e6 * (time.perf_counter() - t0) / iters,
                 f"{iters}_observes"))
    n_spans = iters // 10
    t0 = time.perf_counter()
    for _ in range(n_spans):
        with reg.trace("bench_span"):
            pass
    rows.append(("telemetry_span_open_close",
                 1e6 * (time.perf_counter() - t0) / n_spans,
                 f"{n_spans}_spans"))
    return rows


def _bench(n: int, rounds: int, min_ratio: float = 0.0) -> list:
    dt_off, _ = _warm_loop(n, enabled=False, rounds=rounds)
    dt_on, reg = _warm_loop(n, enabled=True, rounds=rounds)
    ratio = dt_off / max(dt_on, 1e-9)        # instrumented throughput frac

    text = reg.render_prometheus()
    samples = parse_prometheus(text)         # raises on malformed lines
    assert samples, "enabled registry rendered an empty exposition"
    run_spans = reg.spans("run")
    assert run_spans and run_spans[-1].find("run.match") is not None, \
        "warm runs left no span tree behind"

    rows = _primitive_costs()
    rows.append(("telemetry_warm_loop_on", 1e6 * dt_on,
                 f"{n}_rows_refresh+find+top+du+run"))
    rows.append(("telemetry_warm_loop_off", 1e6 * dt_off,
                 f"throughput_ratio_{ratio:.3f}x_on_vs_off"))
    rows.append(("telemetry_prometheus_render", 0.0,
                 f"{len(samples)}_samples_parse_ok"))
    if min_ratio:
        assert ratio >= min_ratio, (
            f"instrumented warm loop dropped to {ratio:.3f}x of the "
            f"uninstrumented throughput (contract: >= {min_ratio}x at "
            f"n={n})")
    return rows


def run_telemetry_assertion(n: int = 200_000, rounds: int = 5,
                            min_ratio: float = 0.95) -> list:
    """Tier-2 CI entry: overhead contract + Prometheus round-trip."""
    return _bench(n, rounds=rounds, min_ratio=min_ratio)


def run(smoke: bool = False) -> list:
    return _bench(20_000 if smoke else 200_000,
                  rounds=3 if smoke else 5)
