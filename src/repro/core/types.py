"""Shared types for the Robinhood core: entries, changelog records, HSM states.

Terminology follows the paper: an *entry* is a filesystem object (file,
directory, symlink) identified by a stable ``fid`` (Lustre FID analogue).
The catalog mirrors entry metadata; the changelog carries metadata-change
events from an MDT (or any event source) to the catalog.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Optional


class FsType(enum.IntEnum):
    FILE = 0
    DIR = 1
    SYMLINK = 2
    OTHER = 3


class HsmState(enum.IntEnum):
    """Lustre-HSM entry states, as driven by the paper's policy engine."""

    NONE = 0        # never archived
    DIRTY = 1       # modified since last archive
    ARCHIVING = 2   # archive request in flight
    ARCHIVED = 3    # clean copy exists in the HSM backend
    RELEASED = 4    # data punched from Lustre, stub remains
    RESTORING = 5   # restore in flight
    LOST = 6        # backend copy lost / unrecoverable


class ChangelogType(enum.IntEnum):
    """Subset of Lustre MDT changelog record types used by Robinhood."""

    CREAT = 0
    MKDIR = 1
    UNLNK = 2
    RMDIR = 3
    RENME = 4
    SATTR = 5   # setattr: chmod/chown/utimes
    CLOSE = 6   # close after write: size/mtime may have changed
    TRUNC = 7
    HSM = 8     # HSM state change event
    SLINK = 9
    XATTR = 10
    MTIME = 11


@dataclasses.dataclass
class Entry:
    """A filesystem entry's metadata, as mirrored in the catalog."""

    fid: int
    parent_fid: int = -1
    name: str = ""
    path: str = ""
    type: FsType = FsType.FILE
    size: int = 0
    blocks: int = 0          # allocated bytes (spc_used)
    owner: str = "root"
    group: str = "root"
    mode: int = 0o644
    nlink: int = 1
    atime: float = 0.0
    mtime: float = 0.0
    ctime: float = 0.0
    ost_idx: int = -1        # first stripe OST (-1: no data / dir)
    stripe_osts: tuple = ()  # all OSTs holding stripes
    pool: str = ""
    hsm_state: HsmState = HsmState.NONE
    archive_id: int = 0
    status: str = ""         # generic-policy status tag (v3)
    xattrs: dict = dataclasses.field(default_factory=dict)
    dirty: bool = False      # async dirty-tag mode (paper SIII-A2 future work)

    def touch(self) -> None:
        now = time.time()
        self.atime = self.mtime = self.ctime = now


@dataclasses.dataclass
class ChangelogRecord:
    """One transactional changelog record.

    ``seq`` is assigned by the emitting MDT stream; records must be acked in
    order and survive until acked (paper SII-C2).
    """

    seq: int
    type: ChangelogType
    fid: int
    parent_fid: int = -1
    name: str = ""
    time: float = 0.0
    uid: str = ""            # user performing the operation
    jobid: str = ""          # Lustre >=2.7 jobid (paper SIII-C)
    mdt: int = 0             # emitting MDT index (DNE)
    attrs: Optional[dict] = None   # optional attribute payload

    def key(self) -> tuple:
        return (self.mdt, self.seq)


# Size-profile buckets, matching robinhood's file-size profile ranges.
SIZE_PROFILE_EDGES = (
    0, 1, 32, 1 << 10, 32 << 10, 1 << 20, 32 << 20, 1 << 30, 32 << 30, 1 << 40
)
SIZE_PROFILE_LABELS = (
    "0", "1~31", "32~1K", "1K~31K", "32K~1M", "1M~31M", "32M~1G", "1G~31G",
    "32G~1T", "+1T",
)

# Age-profile buckets (paper: "overall statistics about data ownership, age
# and size profiles"). Ages are ``now - atime`` seconds; an entry's bucket
# is the largest i with age >= AGE_PROFILE_EDGES[i] (clipped to bucket 0
# for future timestamps).
AGE_PROFILE_EDGES = (
    0.0, 3600.0, 86400.0, 7 * 86400.0, 30 * 86400.0, 90 * 86400.0,
    365 * 86400.0,
)
AGE_PROFILE_LABELS = (
    "<1h", "1h~1d", "1d~7d", "7d~30d", "30d~90d", "90d~1y", "+1y",
)


def age_profile_bucket(age: float) -> int:
    """Index of ``age`` (seconds) in the age-profile histogram.

    Shares the comparison-count formula with the ``profile_cube`` kernel:
    ``clip(sum(age >= edge) - 1, 0, A-1)`` — future timestamps (negative
    age) land in bucket 0.
    """
    b = -1
    for e in AGE_PROFILE_EDGES:
        if age >= e:
            b += 1
    return max(b, 0)


def size_profile_bucket(size: int) -> int:
    """Index of ``size`` in the robinhood size-profile histogram."""
    for i in range(len(SIZE_PROFILE_EDGES) - 1, -1, -1):
        if size >= SIZE_PROFILE_EDGES[i] and (size > 0 or i == 0):
            if size == 0:
                return 0
            return i
    return 0


def parse_size(text: str) -> int:
    """Parse a size literal with units: ``1GB``, ``512MB``, ``4k``..."""
    s = text.strip().upper().rstrip("B")
    units = {"K": 1 << 10, "M": 1 << 20, "G": 1 << 30, "T": 1 << 40,
             "P": 1 << 50}
    if s and s[-1] in units:
        return int(float(s[:-1]) * units[s[-1]])
    return int(float(s)) if s else 0


def parse_duration(text: str) -> float:
    """Parse a duration literal: ``15min``, ``2h``, ``30d``, ``45s``."""
    s = text.strip().lower()
    units = (("min", 60), ("sec", 1), ("s", 1), ("m", 60), ("h", 3600),
             ("d", 86400), ("w", 7 * 86400), ("y", 365 * 86400))
    for suffix, mult in units:
        if s.endswith(suffix):
            return float(s[: -len(suffix)]) * mult
    return float(s)


def format_size(n: float) -> str:
    for unit in ("", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024 or unit == "PB":
            return f"{n:.2f} {unit}".strip() if unit else f"{int(n)}"
        n /= 1024.0
    return f"{n:.2f} PB"
