"""Paper SII-B3 + SIII-C: O(1) pre-aggregated reports vs full aggregation.

The claim: `rbh-report -u foo` is O(1) in catalog size because aggregates
are maintained at ingest. We time the query at growing catalog sizes for
both the pre-aggregated path and a from-scratch recomputation.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Catalog, Entry, FsType, Reports, StatsAggregator


def _fill(cat, stats, n):
    rng = np.random.default_rng(0)
    owners = [f"user{i}" for i in range(20)]
    entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                     type=FsType.FILE, size=int(rng.integers(0, 1 << 30)),
                     blocks=100, owner=owners[int(rng.integers(0, 20))])
               for i in range(n)]
    cat.upsert_batch(entries)


def run(smoke: bool = False) -> list:
    rows = []
    for n in ((10_000, 40_000) if smoke else (10_000, 40_000, 160_000)):
        cat = Catalog(n_shards=4)
        stats = StatsAggregator(cat.strings)
        cat.add_delta_hook(stats.on_delta)
        t0 = time.perf_counter()
        _fill(cat, stats, n)
        ingest_dt = time.perf_counter() - t0
        rep = Reports(cat, stats)
        # O(1) pre-aggregated query
        t0 = time.perf_counter()
        for _ in range(200):
            rep.report_user("user7")
        o1 = (time.perf_counter() - t0) / 200
        # from-scratch aggregation over the columns (what MySQL would do)
        cols = cat.arrays()
        code = cat.strings.code_of("user7")
        t0 = time.perf_counter()
        for _ in range(5):
            m = cols["owner"] == code
            (m.sum(), cols["size"][m].sum(), cols["blocks"][m].sum())
        full = (time.perf_counter() - t0) / 5
        rows.append((f"report_preagg_n{n}", o1 * 1e6,
                     f"flat_vs_scan_{full/o1:.0f}x"))
        rows.append((f"report_fullscan_n{n}", full * 1e6,
                     f"ingest_{n/ingest_dt:.0f}_entries_per_s"))
    return rows
