"""Paper Fig. 3 / SIII-A1: parallel depth-first scan + multi-client scan.

Rows: scan throughput (entries/s) vs worker threads, and the multi-client
mode. A small per-readdir latency models the Lustre RPC round-trip that
makes scanning I/O-bound (the paper's regime); without it a 1-core CPU
serializes everything and parallelism cannot show.
"""
from __future__ import annotations

import random
import time

from repro.core import Catalog, Scanner, multi_client_scan
from repro.fs import LustreSim

RPC_LATENCY = 0.0005   # 0.5 ms per readdir


def build_fs(n_dirs=150, files_per_dir=20, seed=0):
    fs = LustreSim()
    rng = random.Random(seed)
    dirs = [fs.root_fid()]
    for i in range(n_dirs):
        parent = rng.choice(dirs[-40:])
        d = fs.mkdir(parent, f"d{i}")
        dirs.append(d)
        for j in range(files_per_dir):
            f = fs.create(d, f"f{j}", owner=rng.choice("abc"))
            fs.write(f, rng.randint(0, 1 << 20))
    return fs


def run() -> list:
    fs = build_fs()
    rows = []
    base = None
    for threads in (1, 2, 4, 8):
        cat = Catalog()
        s = Scanner(fs, cat, n_threads=threads,
                    readdir_latency=RPC_LATENCY)
        stats = s.scan()
        rate = stats.entries / stats.elapsed
        if base is None:
            base = rate
        rows.append((f"scan_threads_{threads}",
                     1e6 * stats.elapsed / stats.entries,
                     f"{rate:.0f}_entries_per_s_speedup_{rate/base:.2f}x"))
    # multi-client (paper: cumulate client RPC throughput)
    cat = Catalog()
    t0 = time.perf_counter()
    multi_client_scan(fs, cat, n_clients=3, threads_per_client=4,
                      readdir_latency=RPC_LATENCY)
    dt = time.perf_counter() - t0
    rows.append(("scan_multi_client_3x4", 1e6 * dt / len(cat),
                 f"{len(cat)/dt:.0f}_entries_per_s"))
    return rows
