"""Unified telemetry plane: metric registry + pipeline-wide tracing.

The engine reproduces a filesystem's *synthetic understanding* — this
module gives the engine the same treatment. Every pre-existing ad-hoc
counter (``Catalog.arrays_calls``, ``Reports.store_served``, the device
store's tiering/permission counters, ...) is now a series in one
:class:`MetricRegistry`, readable through the old attribute APIs via
thin compatibility descriptors, exportable as a nested dict
(:meth:`MetricRegistry.snapshot`) or Prometheus text exposition format
(:meth:`MetricRegistry.render_prometheus`), and resettable at a scrape
boundary (:meth:`MetricRegistry.reset`).

Topology: one registry per catalog "deployment". ``Catalog`` creates (or
accepts) a registry; everything attached to that catalog — device store,
reports facade, profile cube, policy engine, event pipeline, changelog
streams — lands its series in the same registry, disambiguated by an
``instance`` style label (``store0``, ``reports1``, ...) handed out by
:meth:`MetricRegistry.instance`. Pass one shared registry to several
catalogs to aggregate a whole process; pass
``MetricRegistry(enabled=False)`` to run uninstrumented
(``benchmarks/bench_telemetry.py`` holds the overhead contract:
instrumented warm match/serve throughput >= 0.95x uninstrumented).

Metric kinds
------------
* :class:`Counter` — monotone float, ``inc``/``add``; compat writes via
  ``set_to`` keep ``obj.counter += 1`` working through
  :class:`counter_attr` descriptors.
* :class:`Gauge` — last-set value, or registered callbacks evaluated at
  collection time (:meth:`MetricRegistry.register_callback` — the
  changelog backlog/lag gauges read live stream state this way).
* :class:`Histogram` — bounded memory: fixed bucket edges chosen at
  creation, counts + sum only (no samples kept). ``percentile`` answers
  p50/p99 by linear interpolation inside the winning bucket.
* :class:`TextState` — a single descriptive string (e.g.
  ``Reports.last_fallback_reason``), rendered as an info-gauge.

Tracing
-------
:meth:`MetricRegistry.trace` opens a span: wall-clock timed, nested
per-thread (a ``trace`` inside an active trace of the same registry
becomes a child), thread-safe (each thread owns its ambient stack;
spans from other threads become root spans). Completed root spans land
in a bounded ring buffer and every span close feeds the
``span_seconds{span=...}`` histogram. Device work is dispatched async —
a span around a kernel launch times the *dispatch* unless the caller
opts in to a device sync: ``trace(name, sync=arrays)`` (or
``span.block_on(arrays)``) calls ``jax.block_until_ready`` at close and
records the wait separately, so hot paths stay async by default.

Registry-less library code (``core.segments``, ``kernels/*/ops.py``)
instruments through the **ambient** helpers :func:`span` and
:func:`ambient_counter`: they attach to whatever trace is active on the
calling thread and are no-ops (a shared null object, no allocation)
otherwise.

Labels hold no wall-clock / date values — series cardinality is bounded
by instances x enum-like label values, never by time.
"""
from __future__ import annotations

import bisect
import re
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricRegistry", "Span", "TextState",
    "ambient_counter", "ambient_registry", "counter_attr", "state_attr",
    "parse_prometheus", "span", "DEFAULT_LATENCY_EDGES",
]

# log-spaced seconds: 50us .. 10s — wide enough for a host fold at 1M
# rows, fine enough to split a warm mesh query from a cold upload
DEFAULT_LATENCY_EDGES: Tuple[float, ...] = (
    50e-6, 100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3,
    50e-3, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_RE = re.compile(r"[^a-zA-Z0-9_]")
# one exposition line: name{labels} value  (labels optional)
_PROM_LINE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(\{(?:[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*",?)*\})?'
    r' (-?(?:[0-9.eE+-]+|[Ii]nf|NaN))$')


def _sanitize_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _escape_label(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"') \
                .replace("\n", "\\n")


def _fmt(v: float) -> str:
    """Render integers without a trailing .0 (counters read naturally)."""
    if float(v).is_integer() and abs(v) < 2 ** 53:
        return str(int(v))
    return repr(float(v))


class Counter:
    """Monotone counter series. ``set_to`` exists only for the
    compatibility descriptors (``obj.counter = 0`` in legacy ``__init__``
    bodies and ``+=`` through property get/set)."""

    __slots__ = ("_lock", "value", "_enabled")

    def __init__(self, enabled: List[bool]) -> None:
        self._lock = threading.Lock()
        self.value = 0.0
        self._enabled = enabled

    def inc(self, n: float = 1.0) -> None:
        if not self._enabled[0]:
            return
        with self._lock:
            self.value += n

    add = inc

    def set_to(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def reset(self) -> None:
        self.set_to(0.0)


class Gauge:
    """Last-set-value gauge series."""

    __slots__ = ("_lock", "value", "_enabled")

    def __init__(self, enabled: List[bool]) -> None:
        self._lock = threading.Lock()
        self.value = 0.0
        self._enabled = enabled

    def set(self, value: float) -> None:
        if not self._enabled[0]:
            return
        with self._lock:
            self.value = float(value)


class Histogram:
    """Fixed-bucket histogram: bounded memory regardless of observation
    count (``len(edges) + 1`` bucket counters + sum + count)."""

    __slots__ = ("_lock", "edges", "counts", "sum", "count", "_enabled")

    def __init__(self, edges: Tuple[float, ...],
                 enabled: List[bool]) -> None:
        if list(edges) != sorted(edges) or not edges:
            raise ValueError(f"histogram edges must be sorted, non-empty: "
                             f"{edges!r}")
        self._lock = threading.Lock()
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(edges) + 1)     # last = overflow (+Inf)
        self.sum = 0.0
        self.count = 0
        self._enabled = enabled

    def observe(self, value: float) -> None:
        if not self._enabled[0]:
            return
        idx = bisect.bisect_left(self.edges, value)
        with self._lock:
            self.counts[idx] += 1
            self.sum += value
            self.count += 1

    def percentile(self, q: float) -> float:
        """Approximate quantile (0..1): linear interpolation inside the
        winning bucket; 0.0 on an empty histogram."""
        with self._lock:
            total = self.count
            if not total:
                return 0.0
            target = q * total
            seen = 0
            for i, c in enumerate(self.counts):
                if seen + c >= target and c:
                    lo = self.edges[i - 1] if i else 0.0
                    hi = self.edges[i] if i < len(self.edges) \
                        else self.edges[-1]
                    frac = (target - seen) / c
                    return lo + (hi - lo) * min(1.0, max(0.0, frac))
                seen += c
            return self.edges[-1]

    def reset(self) -> None:
        with self._lock:
            self.counts = [0] * len(self.counts)
            self.sum = 0.0
            self.count = 0


class TextState:
    """A single descriptive string (``last_fallback_reason`` style):
    ``None`` means cleared — the exporter emits nothing for it."""

    __slots__ = ("_lock", "_value", "_enabled")

    def __init__(self, enabled: List[bool]) -> None:
        self._lock = threading.Lock()
        self._value: Optional[str] = None
        self._enabled = enabled

    def set(self, value: Optional[str]) -> None:
        with self._lock:
            self._value = value

    def get(self) -> Optional[str]:
        with self._lock:
            return self._value

    def reset(self) -> None:
        self.set(None)


class Span:
    """One timed region. Built by :meth:`MetricRegistry.trace`; children
    attach from nested traces on the same thread."""

    __slots__ = ("name", "attrs", "start", "elapsed", "sync_wait",
                 "children", "_t0", "_sync")

    def __init__(self, name: str, attrs: Dict[str, object],
                 sync=None) -> None:
        self.name = name
        self.attrs = attrs
        self.start = time.time()
        self.elapsed = 0.0
        self.sync_wait = 0.0           # device-sync wait at close (opt-in)
        self.children: List["Span"] = []
        self._t0 = time.perf_counter()
        self._sync = sync

    def block_on(self, arrays) -> None:
        """Opt into a device sync at span close: ``jax.block_until_ready``
        over ``arrays`` runs before the clock is read, and the wait is
        recorded in ``sync_wait`` — so the span's wall time covers the
        device work, not just its async dispatch."""
        self._sync = arrays

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def _close(self) -> None:
        if self._sync is not None:
            t0 = time.perf_counter()
            import jax
            jax.block_until_ready(self._sync)
            self.sync_wait = time.perf_counter() - t0
            self._sync = None
        self.elapsed = time.perf_counter() - self._t0

    def to_dict(self) -> dict:
        out = {"name": self.name, "elapsed_s": self.elapsed}
        if self.sync_wait:
            out["sync_wait_s"] = self.sync_wait
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["children"] = [c.to_dict() for c in self.children]
        return out

    def find(self, name: str) -> Optional["Span"]:
        """Depth-first lookup by span name (tests/assertions)."""
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None


class _NullSpan:
    """Shared no-op span/context-manager for disabled registries and
    ambient helpers outside any trace. Stateless -> reentrant."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def block_on(self, arrays) -> None:
        pass

    def annotate(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()
_ACTIVE = threading.local()              # per-thread [(registry, span)] stack


class _TraceCtx:
    """Context manager produced by :meth:`MetricRegistry.trace`."""

    __slots__ = ("_reg", "_span", "_root")

    def __init__(self, reg: "MetricRegistry", span_: Span) -> None:
        self._reg = reg
        self._span = span_
        self._root = False

    def __enter__(self) -> Span:
        stack = getattr(_ACTIVE, "stack", None)
        if stack is None:
            stack = _ACTIVE.stack = []
        if stack and stack[-1][0] is self._reg:
            stack[-1][1].children.append(self._span)
        else:
            self._root = True
        stack.append((self._reg, self._span))
        return self._span

    def __exit__(self, *exc) -> bool:
        stack = _ACTIVE.stack
        assert stack and stack[-1][1] is self._span, "unbalanced trace()"
        stack.pop()
        self._span._close()
        self._reg._span_closed(self._span, self._root)
        return False


class MetricRegistry:
    """Process-wide but injectable registry of metric families.

    A *family* is (name, kind, help); each family holds label-keyed
    series. ``enabled=False`` turns every write and trace into a no-op
    (reads still work, returning zeros) — the benchmarked
    "uninstrumented" configuration.
    """

    def __init__(self, enabled: bool = True, max_spans: int = 256) -> None:
        self._lock = threading.Lock()
        # name -> (kind, help, {label_key_tuple: metric})
        self._families: Dict[str, Tuple[str, str, Dict[tuple, object]]] = {}
        # name -> (help, callback) — evaluated at collection time
        self._callbacks: Dict[str, Tuple[str, Callable[[], Iterable]]] = {}
        self._instances: Dict[str, int] = {}
        self._enabled = [bool(enabled)]
        self._spans: List[Span] = []
        self._max_spans = max_spans

    # -- configuration ---------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled[0]

    @enabled.setter
    def enabled(self, on: bool) -> None:
        self._enabled[0] = bool(on)

    def instance(self, prefix: str) -> str:
        """Deterministic per-registry instance label (``store0``,
        ``store1``, ...): disambiguates several objects of one kind
        sharing the registry without wall-clock/ids in labels."""
        with self._lock:
            n = self._instances.get(prefix, 0)
            self._instances[prefix] = n + 1
            return f"{prefix}{n}"

    # -- metric families -------------------------------------------------------
    def _series(self, kind: str, name: str, labels: Dict[str, str],
                help_: str, factory) -> object:
        key = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, help_, {})
                self._families[name] = fam
            elif fam[0] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam[0]}, "
                    f"requested {kind}")
            metric = fam[2].get(key)
            if metric is None:
                metric = factory()
                fam[2][key] = metric
            return metric

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._series("counter", name, labels, help,
                            lambda: Counter(self._enabled))

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._series("gauge", name, labels, help,
                            lambda: Gauge(self._enabled))

    def histogram(self, name: str,
                  edges: Tuple[float, ...] = DEFAULT_LATENCY_EDGES,
                  help: str = "", **labels) -> Histogram:
        return self._series("histogram", name, labels, help,
                            lambda: Histogram(edges, self._enabled))

    def state(self, name: str, help: str = "", **labels) -> TextState:
        return self._series("state", name, labels, help,
                            lambda: TextState(self._enabled))

    def register_callback(self, name: str,
                          fn: Callable[[], Iterable[Tuple[Dict[str, str],
                                                          float]]],
                          help: str = "") -> None:
        """Register a collection-time gauge family: ``fn()`` yields
        ``(labels_dict, value)`` pairs each time the registry is
        snapshotted or rendered (live state — backlog depths, lag
        seconds — without a write on every event)."""
        with self._lock:
            self._callbacks[name] = (help, fn)

    # -- tracing ---------------------------------------------------------------
    def trace(self, name: str, sync=None, **attrs):
        """Open a span (see module docstring). ``sync=`` opts into a
        device sync at close. Returns a context manager yielding the
        :class:`Span` (a shared no-op when the registry is disabled)."""
        if not self._enabled[0]:
            return _NULL_SPAN
        return _TraceCtx(self, Span(name, attrs, sync))

    def _span_closed(self, span_: Span, root: bool) -> None:
        self.histogram("span_seconds", span=span_.name).observe(span_.elapsed)
        if root:
            with self._lock:
                self._spans.append(span_)
                if len(self._spans) > self._max_spans:
                    del self._spans[: len(self._spans) - self._max_spans]

    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Completed root spans, newest last (bounded ring buffer)."""
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    # -- export ----------------------------------------------------------------
    def _collected_callbacks(self) -> List[Tuple[str, str,
                                                 List[Tuple[tuple, float]]]]:
        with self._lock:
            cbs = list(self._callbacks.items())
        out = []
        for name, (help_, fn) in cbs:
            series = []
            for labels, value in fn():
                key = tuple(sorted((str(k), str(v))
                            for k, v in labels.items()))
                series.append((key, float(value)))
            out.append((name, help_, series))
        return out

    def counter_values(self) -> Dict[str, float]:
        """Flat ``name{a="b",...} -> value`` view of every counter series
        — the diffable form behind ``RunReport.telemetry`` counter
        deltas."""
        out: Dict[str, float] = {}
        with self._lock:
            fams = [(n, f) for n, f in self._families.items()
                    if f[0] == "counter"]
        for name, (_k, _h, series) in fams:
            for key, metric in list(series.items()):
                out[_series_name(name, key)] = metric.value
        return out

    def snapshot(self) -> dict:
        """Nested dict of every family: machine-readable export (the
        ``fs_top`` example and ``RunReport.telemetry`` read this)."""
        out: dict = {}
        with self._lock:
            fams = list(self._families.items())
        for name, (kind, help_, series) in fams:
            fam_out: dict = {"kind": kind, "series": {}}
            if help_:
                fam_out["help"] = help_
            for key, metric in list(series.items()):
                skey = _labels_str(key)
                if kind in ("counter", "gauge"):
                    fam_out["series"][skey] = metric.value
                elif kind == "histogram":
                    fam_out["series"][skey] = {
                        "edges": list(metric.edges),
                        "counts": list(metric.counts),
                        "sum": metric.sum, "count": metric.count,
                        "p50": metric.percentile(0.50),
                        "p99": metric.percentile(0.99),
                    }
                else:                     # state
                    fam_out["series"][skey] = metric.get()
            out[name] = fam_out
        for name, help_, series in self._collected_callbacks():
            fam_out = {"kind": "gauge", "series":
                       {_labels_str(k): v for k, v in series}}
            if help_:
                fam_out["help"] = help_
            out[name] = fam_out
        return out

    def render_prometheus(self) -> str:
        """Text exposition format (the simple line-oriented subset:
        ``# TYPE``/``# HELP`` comments + ``name{labels} value`` samples;
        round-trips through :func:`parse_prometheus`)."""
        lines: List[str] = []
        with self._lock:
            fams = list(self._families.items())
        for name, (kind, help_, series) in fams:
            pname = _sanitize_name(name)
            if help_:
                lines.append(f"# HELP {pname} {help_}")
            lines.append(f"# TYPE {pname} "
                         f"{'gauge' if kind == 'state' else kind}")
            for key, metric in list(series.items()):
                if kind in ("counter", "gauge"):
                    lines.append(f"{pname}{_prom_labels(key)} "
                                 f"{_fmt(metric.value)}")
                elif kind == "histogram":
                    cum = 0
                    for edge, c in zip(metric.edges, metric.counts):
                        cum += c
                        lines.append(
                            f"{pname}_bucket"
                            f"{_prom_labels(key, le=_fmt(edge))} {cum}")
                    cum += metric.counts[-1]
                    lines.append(f"{pname}_bucket"
                                 f"{_prom_labels(key, le='+Inf')} {cum}")
                    lines.append(f"{pname}_sum{_prom_labels(key)} "
                                 f"{repr(metric.sum)}")
                    lines.append(f"{pname}_count{_prom_labels(key)} "
                                 f"{metric.count}")
                else:                     # state -> info-style gauge
                    value = metric.get()
                    if value is not None:
                        lines.append(
                            f"{pname}"
                            f"{_prom_labels(key, value=value)} 1")
        for name, help_, series in self._collected_callbacks():
            pname = _sanitize_name(name)
            if help_:
                lines.append(f"# HELP {pname} {help_}")
            lines.append(f"# TYPE {pname} gauge")
            for key, value in series:
                lines.append(f"{pname}{_prom_labels(key)} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Scrape boundary: zero every counter and histogram and clear
        every text state, across ALL instances sharing this registry
        (``Reports.reset_counters`` delegates here so serving, tiering,
        permission and fallback families clear together). Gauges and
        callbacks describe current state and are left alone."""
        with self._lock:
            fams = list(self._families.values())
        for kind, _help, series in fams:
            if kind in ("counter", "histogram", "state"):
                for metric in list(series.values()):
                    metric.reset()


def _labels_str(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


def _series_name(name: str, key: tuple) -> str:
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


def _prom_labels(key: tuple, **extra: str) -> str:
    pairs = [(_LABEL_RE.sub("_", k), _escape_label(str(v)))
             for k, v in key] + \
            [(k, _escape_label(str(v))) for k, v in extra.items()]
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def parse_prometheus(text: str) -> Dict[str, float]:
    """Line-format check for the exposition output: returns
    ``{sample_name_with_labels: value}``; raises ``ValueError`` on any
    malformed line. This is the CI round-trip parser — deliberately the
    simple subset :meth:`MetricRegistry.render_prometheus` emits."""
    out: Dict[str, float] = {}
    for i, line in enumerate(text.splitlines()):
        if not line or line.startswith("#"):
            if line.startswith("#") and not line.startswith(("# HELP ",
                                                             "# TYPE ")):
                raise ValueError(f"line {i + 1}: bad comment {line!r}")
            continue
        m = _PROM_LINE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i + 1}: unparseable sample {line!r}")
        out[m.group(1) + (m.group(2) or "")] = float(m.group(3))
    return out


# -- ambient helpers (registry-less library code) ------------------------------
def ambient_registry() -> Optional[MetricRegistry]:
    """The registry of the innermost active trace on this thread."""
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1][0] if stack else None


def span(name: str, **attrs):
    """Child span of whatever trace is active on this thread — a shared
    no-op outside any trace. Lets ``core.segments`` / kernel op wrappers
    time themselves without holding a registry reference."""
    stack = getattr(_ACTIVE, "stack", None)
    if not stack:
        return _NULL_SPAN
    return stack[-1][0].trace(name, **attrs)


def ambient_counter(name: str, n: float = 1.0, **labels) -> None:
    """Increment a counter on the ambient registry (no-op outside any
    trace)."""
    reg = ambient_registry()
    if reg is not None:
        reg.counter(name, **labels).inc(n)


# -- compatibility descriptors -------------------------------------------------
class counter_attr:
    """Class-level descriptor exposing a registry counter as a plain int
    attribute: ``self.full_uploads += 1`` and ``store.full_uploads``
    keep working, now backed by ``obj.telemetry`` with ``obj._tlabels``
    as the instance labels. The owner must assign ``self.telemetry`` and
    ``self._tlabels`` before first use."""

    __slots__ = ("metric", "help")

    def __init__(self, metric: str, help: str = "") -> None:
        self.metric = metric
        self.help = help

    def _counter(self, obj) -> Counter:
        return obj.telemetry.counter(self.metric, help=self.help,
                                     **obj._tlabels)

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return int(self._counter(obj).value)

    def __set__(self, obj, value) -> None:
        self._counter(obj).set_to(value)


class state_attr:
    """Descriptor sibling of :class:`counter_attr` for
    :class:`TextState` attributes (``Reports.last_fallback_reason``)."""

    __slots__ = ("metric", "help")

    def __init__(self, metric: str, help: str = "") -> None:
        self.metric = metric
        self.help = help

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return obj.telemetry.state(self.metric, help=self.help,
                                   **obj._tlabels).get()

    def __set__(self, obj, value) -> None:
        obj.telemetry.state(self.metric, help=self.help,
                            **obj._tlabels).set(value)


def slug(text: str, limit: int = 60) -> str:
    """Bounded label value from free text (fallback reasons): lowercase,
    word characters only — keeps series cardinality sane while staying
    greppable against the full ``RunReport.fallback_reason``."""
    s = re.sub(r"[^a-z0-9]+", "_", text.lower()).strip("_")
    return s[:limit].rstrip("_")
