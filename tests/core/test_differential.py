"""Differential suite: every execution/matching path is one engine.

On randomized catalogs and randomized policies, ``execution="scalar"``,
``execution="columnar"`` (the Entry-free default), ``execution="batched"``
under both evaluator backends (numpy and the policy_scan kernel oracle),
and the incremental planner must action the **identical fid sequence** —
same entries, same order, same report totals. A second harness proves the
ColumnBatch batch-action path byte-identical between the Entry-
materializing (``batched``) and zero-materialization (``columnar``) modes,
and a third that the single-launch (R, N) matcher, the per-rule-launch
fallback, and the numpy masks agree bit-for-bit (attribution included).

All generated values are exactly representable in float32 so the kernel
path is bit-for-bit with the int64/float64 numpy path (sizes are multiples
of 1KiB below 2^31, times are integers below 2^24).
"""
import threading

import numpy as np
import pytest

from repro.core import (Catalog, Entry, FsType, HsmState, PolicyDefinition,
                        PolicyEngine)

NOW = float(2 ** 20)          # f32-exact "now"

SCOPES = [
    "true",
    "type == file",
    "type == file and size > 0",
    "not type == dir",
]

CONDITIONS = [
    "size > 16M",
    "size <= 4M",
    "size >= 1M and size < 64M",
    "owner == 'user1'",
    "not owner == 'user2'",
    "group == 'grp0'",
    "last_access > 1000s",
    "last_access <= 5000s",
    "last_mod > 2000s",
    "hsm_state == none",
    "hsm_state == archived",
    "pool == 'ssd'",
    "size > 8M or owner == 'user0'",
    "size > 2M and last_access > 3000s",
    "not (size <= 1M or last_access <= 500s)",
]


class Recorder:
    def __init__(self):
        self.lock = threading.Lock()
        self.calls = []

    def __call__(self, e, params):
        with self.lock:
            self.calls.append(e.fid)
        return True


class BatchRecorder(Recorder):
    """Recorder exposing the ColumnBatch batch-action interface."""

    def __init__(self):
        super().__init__()

        def action_batch(batch, params):
            with self.lock:
                self.calls.extend(batch.fids.tolist())
            return [True] * len(batch)

        self.action_batch = action_batch


def _random_catalog(rng, n):
    cat = Catalog(n_shards=4)
    entries = []
    for i in range(n):
        fid = i + 1
        entries.append(Entry(
            fid=fid, name=f"f{fid}", path=f"/p/d{fid % 5}/f{fid}",
            type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
            size=int(rng.integers(0, 2 ** 15)) * 1024,       # f32-exact
            blocks=int(rng.integers(0, 2 ** 10)),
            owner=f"user{int(rng.integers(0, 4))}",
            group=f"grp{int(rng.integers(0, 3))}",
            pool=["", "ssd", "hdd"][int(rng.integers(0, 3))],
            hsm_state=HsmState(int(rng.integers(0, 5))),
            atime=NOW - float(rng.integers(0, 10_000)),      # f32-exact
            mtime=NOW - float(rng.integers(0, 10_000)),
        ))
    cat.upsert_batch(entries)
    return cat


def _random_policy(rng, action):
    n_rules = int(rng.integers(1, 4))
    conds = rng.choice(len(CONDITIONS), size=n_rules, replace=False)
    return PolicyDefinition.from_config(
        name="p", action=action,
        scope=SCOPES[int(rng.integers(0, len(SCOPES)))],
        rules=[(f"r{i}", CONDITIONS[int(c)], {"tag": f"r{i}"})
               for i, c in enumerate(conds)],
        sort_by=["atime", "size", "mtime"][int(rng.integers(0, 3))],
        sort_desc=bool(rng.integers(0, 2)),
        n_threads=1, batch_size=64, mutates=False)


def _churn(rng, cat, n):
    """Randomly mutate/remove/insert entries; returns the touched fids."""
    touched = set()
    live = [int(f) for s in cat.shards for f in s.fids()]
    for fid in rng.choice(live, size=max(1, len(live) // 10), replace=False):
        fid = int(fid)
        kind = rng.random()
        if kind < 0.2:
            cat.remove(fid)
        elif kind < 0.6:
            cat.update_fields(fid, size=int(rng.integers(0, 2 ** 15)) * 1024,
                              atime=NOW - float(rng.integers(0, 10_000)))
        else:
            cat.update_fields(fid, owner=f"user{int(rng.integers(0, 4))}",
                              hsm_state=HsmState(int(rng.integers(0, 5))))
        touched.add(fid)
    for _ in range(n // 20):
        fid = n + int(rng.integers(1, 10_000))
        cat.upsert(Entry(fid=fid, name=f"n{fid}", path=f"/p/new/n{fid}",
                         type=FsType.FILE,
                         size=int(rng.integers(0, 2 ** 15)) * 1024,
                         owner=f"user{int(rng.integers(0, 4))}",
                         atime=NOW - float(rng.integers(0, 10_000))))
        touched.add(fid)
    return sorted(touched)


def _run_path(cat, policy_factory, clock_t, **run_kw):
    rec = Recorder()
    eng = PolicyEngine(cat, clock=lambda: clock_t)
    eng.register(policy_factory(rec))
    r = eng.run("p", **run_kw)
    return r, rec.calls


def _assert_paths_agree(seed, n=600, rounds=2):
    rng = np.random.default_rng(seed)
    cat = _random_catalog(rng, n)
    policy_rng = np.random.default_rng(seed + 1)

    def factory(action, _proto=_random_policy(policy_rng, None)):
        import dataclasses
        return dataclasses.replace(_proto, action=action)

    # incremental engine lives across churn rounds; every other path is a
    # fresh full evaluation of the same catalog state
    inc_rec = Recorder()
    inc_eng = PolicyEngine(cat, clock=lambda: _assert_paths_agree.t)
    inc_eng.register(factory(inc_rec))
    inc_eng.enable_incremental()
    _assert_paths_agree.t = NOW
    inc_eng.run("p")              # cold full run primes the cache

    t = NOW
    for round_i in range(rounds):
        touched = _churn(rng, cat, n)
        inc_eng.mark_dirty(touched)
        t += float(rng.integers(0, 2_000))      # flips fire too
        _assert_paths_agree.t = t

        results = {}
        inc_rec.calls.clear()
        r = inc_eng.run("p", matching="incremental")
        results["incremental"] = (r.matched, r.succeeded, r.volume,
                                  list(inc_rec.calls))
        r, calls = _run_path(cat, factory, t, execution="scalar")
        results["scalar"] = (r.matched, r.succeeded, r.volume, calls)
        r, calls = _run_path(cat, factory, t, execution="batched",
                             evaluator="numpy")
        results["numpy"] = (r.matched, r.succeeded, r.volume, calls)
        r, calls = _run_path(cat, factory, t, execution="columnar",
                             evaluator="numpy")
        results["columnar"] = (r.matched, r.succeeded, r.volume, calls)
        r, calls = _run_path(cat, factory, t, execution="batched",
                             evaluator="policy_scan")
        results["policy_scan"] = (r.matched, r.succeeded, r.volume, calls)

        ref = results["numpy"]
        for name, got in results.items():
            assert got == ref, (
                f"seed={seed} round={round_i} path={name} diverged: "
                f"{got[:3]} vs {ref[:3]}; "
                f"sym_diff={set(got[3]) ^ set(ref[3])}")

        # ColumnBatch batch-action path: the Entry-materializing mode and
        # the zero-materialization mode must action byte-identical
        # sequences (same chunking, same rule grouping, same order)
        batch_results = {}
        for execution in ("batched", "columnar"):
            rec = BatchRecorder()
            eng = PolicyEngine(cat, clock=lambda: t)
            eng.register(factory(rec))
            r = eng.run("p", execution=execution)
            batch_results[execution] = (r.matched, r.succeeded, r.volume,
                                        list(rec.calls))
        assert batch_results["batched"] == batch_results["columnar"], (
            f"seed={seed} round={round_i} ColumnBatch path diverged")
        assert sorted(batch_results["columnar"][3]) == sorted(ref[3])
        assert batch_results["columnar"][:3] == ref[:3]


@pytest.mark.parametrize("seed", [0, 1])
def test_all_paths_action_identical_sets(seed):
    _assert_paths_agree(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(2, 12)))
def test_all_paths_action_identical_sets_deep(seed):
    _assert_paths_agree(seed, n=1500, rounds=3)


def _assert_matchers_agree(seed, n=400, use_kernel=False):
    """single-launch (R, N) matcher == per-rule launches == numpy masks,
    attribution and per-rule aggregates included."""
    from repro.core.policy import all_of, any_of
    from repro.kernels.policy_scan.ops import match_programs

    rng = np.random.default_rng(seed)
    cat = _random_catalog(rng, n)
    policy = _random_policy(np.random.default_rng(seed + 1), None)
    rule_exprs = [r.condition for r in policy.rules]
    full = all_of([policy.scope, any_of(rule_exprs)])
    arrays = cat.arrays()

    single = match_programs(arrays, [full] + rule_exprs, cat.strings, NOW,
                            use_kernel=use_kernel, single_launch=True)
    per_rule = match_programs(arrays, [full] + rule_exprs, cat.strings, NOW,
                              use_kernel=use_kernel, single_launch=False)
    for m_s, m_r in zip(single[0], per_rule[0]):
        np.testing.assert_array_equal(m_s, m_r)
    np.testing.assert_array_equal(single[2], per_rule[2])   # attribution
    assert single[1]["count"] == per_rule[1]["count"]
    assert single[1].get("rule_count") == per_rule[1].get("rule_count")
    assert single[1].get("rule_volume") == per_rule[1].get("rule_volume")

    # vs numpy Expr.mask ground truth (f32-exact catalogs: bit-for-bit)
    np_masks = [full.mask(arrays, cat.strings, NOW)] + \
        [e.mask(arrays, cat.strings, NOW) for e in rule_exprs]
    for m_s, m_n in zip(single[0], np_masks):
        np.testing.assert_array_equal(m_s, m_n)
    stacked = np.stack(np_masks[1:])
    att = np.argmax(stacked, axis=0).astype(np.int32)
    att[~stacked.any(axis=0)] = -1
    np.testing.assert_array_equal(single[2], att)
    assert single[1]["count"] == int(np_masks[0].sum())


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_single_launch_matcher_agrees(seed):
    _assert_matchers_agree(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(3, 9)))
def test_single_launch_matcher_agrees_kernel_interpret(seed):
    """Same differential through the actual Pallas kernels (interpret mode
    off-TPU): the single-launch batch kernel vs per-rule launches."""
    _assert_matchers_agree(seed, n=700, use_kernel=True)


@pytest.mark.slow
def test_budgeted_runs_agree_across_paths():
    """Volume/count budgets: deterministic prefix on every path."""
    rng = np.random.default_rng(99)
    cat = _random_catalog(rng, 800)

    def factory(action):
        return PolicyDefinition.from_config(
            name="p", action=action, scope="type == file",
            rules=[("any", "size >= 0", {})], sort_by="atime",
            n_threads=1, batch_size=32, max_actions_per_run=111,
            mutates=False)

    results = {}
    for execution in ("scalar", "batched", "columnar"):
        r, calls = _run_path(cat, factory, NOW, execution=execution)
        results[execution] = (r.succeeded, calls)
    inc_rec = Recorder()
    eng = PolicyEngine(cat, clock=lambda: NOW)
    eng.register(factory(inc_rec))
    eng.enable_incremental()
    eng.run("p")
    inc_rec.calls.clear()
    eng.mark_dirty([1, 2, 3])
    r = eng.run("p", matching="incremental")
    results["incremental"] = (r.succeeded, list(inc_rec.calls))
    assert results["scalar"] == results["batched"] == results["columnar"] \
        == results["incremental"]
