"""Paged KV storage: fixed-size pages in a global pool + per-seq tables.

The pool is the "OST" of the serving tier: a bounded device-memory region
whose usage the policy engine watches. Pages are the catalog's entries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SequencePages:
    seq_id: int
    page_ids: List[int] = dataclasses.field(default_factory=list)
    length: int = 0          # tokens written

    def table(self, max_pages: int) -> np.ndarray:
        t = np.full(max_pages, -1, np.int32)
        t[: len(self.page_ids)] = self.page_ids
        return t


class PagePool:
    """(n_pages, page_size, K, hd) K/V pool with a free list."""

    def __init__(self, n_pages: int, page_size: int, n_kv: int,
                 head_dim: int, dtype=np.float32) -> None:
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_kv = n_kv
        self.head_dim = head_dim
        self.k = np.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.v = np.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    # -- allocation ------------------------------------------------------------
    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def free(self, page_id: int) -> None:
        self.k[page_id] = 0
        self.v[page_id] = 0
        self._free.append(page_id)

    @property
    def used(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def usage_pct(self) -> float:
        return 100.0 * self.used / self.n_pages

    # -- data ---------------------------------------------------------------------
    def write_token(self, page_id: int, slot: int, k: np.ndarray,
                    v: np.ndarray) -> None:
        self.k[page_id, slot] = k
        self.v[page_id, slot] = v

    def read_page(self, page_id: int) -> Tuple[np.ndarray, np.ndarray]:
        return self.k[page_id].copy(), self.v[page_id].copy()

    def write_page(self, page_id: int, k: np.ndarray, v: np.ndarray) -> None:
        self.k[page_id] = k
        self.v[page_id] = v
