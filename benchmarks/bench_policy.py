"""Paper SII-B1: policy-criteria matching throughput over the catalog.

Four evaluators of the same expression over N entries: per-entry python
(MySQL-row analogue), vectorized numpy masks, the pure-jnp kernel oracle,
and the Pallas ``policy_scan`` kernel in interpret mode (the TPU path;
interpret mode measures correctness not speed — on-TPU it fuses the scan
with aggregation in one HBM pass).

Plus the end-to-end engine comparison on a 1M-entry catalog:
``engine_scalar`` (legacy per-entry execution: O(n) dequeues, per-entry
catalog.get, Python rule re-evaluation) vs ``engine_batched`` (columnar
match, vectorized attribution, chunked get_batch execution — every chunk
still materializes Entry objects) vs ``engine_columnar`` (the
zero-materialization path: ColumnBatch chunks flow straight to the batch
action, no ``Entry.__init__`` anywhere). All three action the identical
fid sequence — asserted — as do the numpy / per-rule-launch /
single-launch matcher backends. ``engine_incremental`` adds the
changelog-driven dirty-set matching vs a full re-scan at 1% churn.

``engine_mesh`` (the device-resident store): cold full upload vs warm
delta-scatter refresh of the per-shard-group column stacks, and a warm
``policy_scan_mesh`` run (resident columns, data-parallel over the
``("shards",)`` mesh, refresh included) vs the single-device
``policy_scan`` path that re-concats and re-uploads the full stack every
run. ``run_mesh_assertion`` is the tier-2 CI entry enforcing the >= 3x
bar at 1M entries / 1% churn on >= 4 devices.
"""
from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (Catalog, Entry, FsType, PolicyDefinition,
                        PolicyEngine, parse_expr)
from repro.core.policy import KERNEL_COLUMNS, compile_program
from repro.kernels.policy_scan.ops import match_programs, policy_scan

EXPR = "(size > 1GB or owner == 'user3') and not last_access > 30d"
N = 120_000
N_ENGINE = 1_000_000


def _catalog(n, n_shards=4):
    rng = np.random.default_rng(1)
    now = time.time()
    cat = Catalog(n_shards=n_shards)
    for lo in range(0, n, 100_000):      # chunked build bounds peak memory
        hi = min(lo + 100_000, n)
        entries = [Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                         type=FsType.FILE, size=int(rng.integers(0, 2 << 30)),
                         blocks=100, owner=f"user{int(rng.integers(0, 8))}",
                         atime=now - float(rng.integers(0, 90 * 86400)))
                   for i in range(lo, hi)]
        cat.upsert_batch(entries)
    return cat


def _bench_engine(n: int) -> list:
    """engine_scalar vs engine_batched vs engine_columnar, same catalog +
    policy + recording action; actioned fid sequences asserted identical."""
    cat = _catalog(n)

    acted: list = []
    lock = threading.Lock()

    def act(e, params):
        with lock:
            acted.append(e.fid)
        return True

    def act_batch(batch, params):
        with lock:
            acted.extend(batch.fids.tolist())
        return [True] * len(batch)

    act.action_batch = act_batch

    def drain():
        out = sorted(acted)
        acted.clear()
        return out

    eng = PolicyEngine(cat)
    # ~17% of entries match: large enough that the legacy path's O(n)
    # work.pop(0) dequeues dominate, which is exactly the per-entry-scan
    # degeneration (SII-B1) the batched pipeline removes
    eng.register(PolicyDefinition.from_config(
        name="sweep", action=act, scope="type == file",
        rules=[("big_cold", "size > 1700MB", {})],
        sort_by="atime", n_threads=4, batch_size=1024))

    rows = []
    t0 = time.perf_counter()
    r_s = eng.run("sweep", execution="scalar")
    dt_s = time.perf_counter() - t0
    fids_scalar = drain()
    rows.append(("policy_engine_scalar", 1e6 * dt_s / n,
                 f"{n/dt_s:.0f}_entries_per_s_actions_{r_s.succeeded}"))

    t0 = time.perf_counter()
    r_b = eng.run("sweep", execution="batched")
    dt_b = time.perf_counter() - t0
    fids_batched = drain()
    assert r_b.succeeded == r_s.succeeded and r_b.matched == r_s.matched
    assert fids_batched == fids_scalar
    rows.append(("policy_engine_batched", 1e6 * dt_b / n,
                 f"{n/dt_b:.0f}_entries_per_s_speedup_{dt_s/dt_b:.1f}x"))

    t0 = time.perf_counter()
    r_c = eng.run("sweep", execution="columnar")
    dt_c = time.perf_counter() - t0
    fids_col = drain()
    assert r_c.succeeded == r_b.succeeded and r_c.matched == r_b.matched
    assert fids_col == fids_batched       # Entry-free path: identical actions
    rows.append(("policy_engine_columnar", 1e6 * dt_c / n,
                 f"{n/dt_c:.0f}_entries_per_s"
                 f"_speedup_vs_batched_{dt_b/dt_c:.1f}x"))

    t0 = time.perf_counter()
    r_k = eng.run("sweep", evaluator="policy_scan", execution="columnar")
    dt_k = time.perf_counter() - t0
    fids_scan = drain()
    # f32 kernel columns: sizes within one ulp (~256 B at 2 GB) of the
    # cutoff may flip vs the int64 numpy path
    assert abs(r_k.succeeded - r_c.succeeded) <= 8
    assert len(set(fids_scan) ^ set(fids_col)) <= 8
    rows.append(("policy_engine_columnar_scan", 1e6 * dt_k / n,
                 f"{n/dt_k:.0f}_entries_per_s_backend_{r_k.evaluator}"))

    # matcher backends: per-rule launches == single launch, bit-for-bit
    policy = eng.policies["sweep"]
    exprs = [parse_expr("type == file and size > 1700MB"),
             policy.rules[0].condition]
    arrays = cat.arrays()
    now = time.time()
    m1, a1, r1 = match_programs(arrays, exprs, cat.strings, now,
                                use_kernel=False, single_launch=True)
    m2, a2, r2 = match_programs(arrays, exprs, cat.strings, now,
                                use_kernel=False, single_launch=False)
    assert all((x == y).all() for x, y in zip(m1, m2)) and (r1 == r2).all()
    assert a1["count"] == a2["count"] and a1["rule_count"] == a2["rule_count"]
    return rows


def _bench_engine_incremental(n: int, churn_frac: float = 0.01,
                              rounds: int = 3) -> list:
    """engine_incremental: changelog-driven dirty-set match vs full re-scan.

    The paper's core claim (SII-C): once changelogs feed the engine, policy
    runs stop re-scanning the namespace. Each round churns ``churn_frac``
    of a warm catalog, then times an incremental run (re-evaluates only the
    dirty rows against the cached match table) against a full columnar
    re-scan of the same catalog state. ``dry_run`` isolates match/plan cost
    (execution is identical on both paths and not under test here).
    """
    rng = np.random.default_rng(7)
    cat = _catalog(n)
    t_now = time.time()          # frozen: both paths match at the same "now"

    def _mk_engine(incremental):
        eng = PolicyEngine(cat, clock=lambda: t_now)
        eng.register(PolicyDefinition.from_config(
            name="tier", action=lambda e, p: True, scope="type == file",
            rules=[("big_cold", "size > 1945MB and last_access > 10d", {})],
            sort_by="atime", dry_run=True, mutates=False))
        if incremental:
            eng.enable_incremental()
        return eng

    eng = _mk_engine(incremental=True)
    # the full-rescan baseline runs on a state-free engine so its timing
    # excludes the incremental cache rebuild the other engine pays for
    eng_base = _mk_engine(incremental=False)
    r0 = eng.run("tier")                     # cold start: full scan + rebuild
    assert r0.mode == "full"

    all_fids = np.arange(1, n + 1)
    t_inc = t_full = 0.0
    for _ in range(rounds):
        churn = rng.choice(all_fids, size=max(1, int(n * churn_frac)),
                           replace=False)
        half = len(churn) // 2
        cat.update_fields_batch(churn[:half].tolist(), atime=t_now)  # got hot
        cat.update_fields_batch(churn[half:].tolist(),               # grew big
                                size=2040 << 20, atime=t_now - 30 * 86400)
        eng.mark_dirty(churn.tolist())

        t0 = time.perf_counter()
        r_i = eng.run("tier", matching="incremental")
        t_inc += time.perf_counter() - t0

        t0 = time.perf_counter()
        r_f = eng_base.run("tier")
        t_full += time.perf_counter() - t0
        assert r_i.mode == "incremental" and r_f.mode == "full"
        assert r_i.matched == r_f.matched and r_i.succeeded == r_f.succeeded

    t_inc /= rounds
    t_full /= rounds
    return [
        ("policy_engine_full_rescan", 1e6 * t_full / n,
         f"{n/t_full:.0f}_entries_per_s_matched_{r_f.matched}"),
        ("policy_engine_incremental", 1e6 * t_inc / n,
         f"churn_{churn_frac:.0%}_reval_{r_i.reval}"
         f"_speedup_{t_full/t_inc:.1f}x"),
    ]


def _bench_engine_mesh(n: int, churn_frac: float = 0.01, rounds: int = 3,
                       assert_speedup: float = 0.0) -> list:
    """Device-resident mesh matching vs the re-uploading policy_scan path.

    The tentpole claim: once the column stacks live on the mesh and refresh
    by delta scatter, a warm policy run stops paying the per-run host
    concat + f32 restack + host→device upload. Each round churns
    ``churn_frac`` of the catalog (updates only — the scatter path), then
    times a warm ``policy_scan_mesh`` run against the single-device
    ``policy_scan`` run that re-uploads the full stack. Both dry-run (the
    match path is what differs), both asserted to match the same entries;
    a separate recording pass asserts the actioned fid sequences are
    identical across numpy / single-launch / mesh. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a
    real shard-group fan-out on CPU hosts.

    ``assert_speedup > 0`` enforces the acceptance bar (tier-2 CI calls
    this at 1M entries / 1% churn / >= 4 devices with 3.0).
    """
    import jax

    from repro.core import DeviceColumnStore
    from repro.launch.mesh import make_shards_mesh

    n_dev = len(jax.devices())
    cat = _catalog(n, n_shards=max(8, n_dev))
    t_now = time.time()

    eng = PolicyEngine(cat, clock=lambda: t_now)
    eng.register(PolicyDefinition.from_config(
        name="tier", action=lambda e, p: True, scope="type == file",
        rules=[("big_cold", "size > 1945MB and last_access > 10d", {})],
        sort_by="atime", dry_run=True, mutates=False))
    mesh = make_shards_mesh()
    store = DeviceColumnStore(cat, mesh)
    eng.attach_device_store(store)

    # cold upload: snapshot + restack + device_put for every shard group
    t0 = time.perf_counter()
    stats = store.refresh()
    dt_cold = time.perf_counter() - t0
    assert stats["full"] == store.n_devices
    rows = [("policy_store_cold_upload", 1e6 * dt_cold / n,
             f"{n}_rows_full_restack_{store.n_devices}_devices")]

    # warm the jit caches on both paths before timing
    r = eng.run("tier", evaluator="policy_scan_mesh")
    assert r.evaluator == "policy_scan_mesh", r.fallback_reason
    eng.run("tier", evaluator="policy_scan")

    rng = np.random.default_rng(11)
    all_fids = np.arange(1, n + 1)
    t_mesh = t_up = t_refresh = 0.0
    n_churn = max(1, int(n * churn_frac))
    def _churn():
        churn = rng.choice(all_fids, size=n_churn, replace=False)
        half = len(churn) // 2
        cat.update_fields_batch(churn[:half].tolist(), atime=t_now)
        cat.update_fields_batch(churn[half:].tolist(),
                                size=2040 << 20, atime=t_now - 30 * 86400)

    for _ in range(rounds):
        _churn()
        deltas0 = store.delta_refreshes
        t0 = time.perf_counter()
        stats = store.refresh()              # isolate the scatter upload
        t_refresh += time.perf_counter() - t0
        assert stats["full"] == 0 and store.delta_refreshes > deltas0, stats

        _churn()                  # the timed mesh run pays its own refresh
        t0 = time.perf_counter()
        r_m = eng.run("tier", evaluator="policy_scan_mesh")
        t_mesh += time.perf_counter() - t0
        assert r_m.evaluator == "policy_scan_mesh", r_m.fallback_reason

        t0 = time.perf_counter()
        r_u = eng.run("tier", evaluator="policy_scan")
        t_up += time.perf_counter() - t0
        assert r_u.evaluator == "policy_scan", r_u.fallback_reason
        assert r_m.matched == r_u.matched and r_m.succeeded == r_u.succeeded

    t_mesh /= rounds
    t_up /= rounds
    t_refresh /= rounds
    speedup = t_up / t_mesh
    rows += [
        ("policy_store_warm_refresh", 1e6 * t_refresh / n,
         f"churn_{churn_frac:.0%}_scattered_{n_churn}_rows"),
        ("policy_engine_scan_reupload", 1e6 * t_up / n,
         f"{n/t_up:.0f}_entries_per_s_matched_{r_u.matched}"),
        ("policy_engine_mesh_warm", 1e6 * t_mesh / n,
         f"{n/t_mesh:.0f}_entries_per_s_speedup_vs_reupload_"
         f"{speedup:.1f}x_devices_{store.n_devices}"),
    ]

    # identical actioned fid sequences across numpy / single-launch / mesh
    acted: list = []
    lock = threading.Lock()

    def act(e, params):
        with lock:
            acted.append(e.fid)
        return True

    def act_batch(batch, params):
        with lock:
            acted.extend(batch.fids.tolist())
        return [True] * len(batch)

    act.action_batch = act_batch
    eng.register(PolicyDefinition.from_config(
        name="verify", action=act, scope="type == file",
        rules=[("big_cold", "size > 1945MB and last_access > 10d", {})],
        sort_by="atime", mutates=False))
    seqs = {}
    for ev in ("numpy", "policy_scan", "policy_scan_mesh"):
        acted.clear()
        r = eng.run("verify", evaluator=ev)
        assert not r.fallback_reason, (ev, r.fallback_reason)
        seqs[ev] = list(acted)
    assert seqs["numpy"] == seqs["policy_scan"] == seqs["policy_scan_mesh"]

    if assert_speedup:
        assert speedup >= assert_speedup, (
            f"warm mesh matching with delta-refresh no longer beats the "
            f"re-uploading policy_scan path ({speedup:.2f}x < "
            f"{assert_speedup}x at n={n}, {store.n_devices} devices)")
    return rows


def run_mesh_assertion(n: int = 1_000_000, min_devices: int = 4,
                       min_speedup: float = 3.0) -> list:
    """Tier-2 CI entry: the acceptance bar at full size.

    At ``n`` entries / 1% churn on >= ``min_devices`` (host-platform)
    devices, warm mesh matching with delta-refresh must beat the
    re-uploading single-device policy_scan path by >= ``min_speedup``,
    with identical actioned fid sequences across numpy / single-launch /
    mesh (asserted inside :func:`_bench_engine_mesh`).
    """
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= min_devices, (
        f"need >= {min_devices} devices (run under XLA_FLAGS="
        f"--xla_force_host_platform_device_count=8), have {n_dev}")
    return _bench_engine_mesh(n, churn_frac=0.01, rounds=3,
                              assert_speedup=min_speedup)


def run(smoke: bool = False) -> list:
    n = 24_000 if smoke else N
    cat = _catalog(n)
    now = time.time()
    expr = parse_expr(EXPR)
    rows = []

    t0 = time.perf_counter()
    n_match = sum(1 for e in cat.entries() if expr.evaluate(e, now))
    dt_py = time.perf_counter() - t0
    rows.append(("policy_per_entry_python", 1e6 * dt_py / n,
                 f"{n/dt_py:.0f}_entries_per_s_match_{n_match}"))

    cols = cat.arrays()
    t0 = time.perf_counter()
    for _ in range(5):
        mask = expr.mask(cols, cat.strings, now)
    dt_np = (time.perf_counter() - t0) / 5
    rows.append(("policy_numpy_mask", 1e6 * dt_np / n,
                 f"{n/dt_np:.0f}_entries_per_s_speedup_{dt_py/dt_np:.0f}x"))

    ops, ci, opr = compile_program(expr, cat.strings, now)
    kcols = jnp.stack([jnp.asarray(cols[c], jnp.float32)
                       for c in KERNEL_COLUMNS])
    args = (kcols, jnp.asarray(ops), jnp.asarray(ci), jnp.asarray(opr))
    kw = dict(size_col=KERNEL_COLUMNS.index("size"),
              blocks_col=KERNEL_COLUMNS.index("blocks"))
    m, agg = policy_scan(*args, use_kernel=False, **kw)   # warm + check
    # f32 kernel columns hold epoch seconds at ~64 s resolution; entries
    # within that window of the 30d age cutoff may flip vs the f64 path
    assert abs(int(agg[0]) - n_match) <= 8, (int(agg[0]), n_match)
    t0 = time.perf_counter()
    for _ in range(5):
        m, agg = policy_scan(*args, use_kernel=False, **kw)
        m.block_until_ready()
    dt_jnp = (time.perf_counter() - t0) / 5
    rows.append(("policy_jnp_oracle_fused_agg", 1e6 * dt_jnp / n,
                 f"{n/dt_jnp:.0f}_entries_per_s"))

    m, agg = policy_scan(*args, use_kernel=True, **kw)
    assert abs(int(agg[0]) - n_match) <= 8, (int(agg[0]), n_match)
    t0 = time.perf_counter()
    m, agg = policy_scan(*args, use_kernel=True, **kw)
    m.block_until_ready()
    dt_k = time.perf_counter() - t0
    rows.append(("policy_pallas_interpret", 1e6 * dt_k / n,
                 "correctness_path_TPU_target"))

    rows += _bench_engine(60_000 if smoke else N_ENGINE)
    rows += _bench_engine_incremental(100_000 if smoke else N_ENGINE)
    rows += _bench_engine_mesh(100_000 if smoke else N_ENGINE)
    return rows
