"""Lustre-HSM coordination (C8): archive / release / purge policies.

Implements the paper's HSM binding as shipped policy configurations over the
generic engine (v3 style):

* **archive**: copy modified (NEW/DIRTY) files older than ``archive_age`` to
  the HSM backend;
* **release**: when an OST crosses its high watermark, punch archived+cold
  file data from that OST until below the low watermark (LRU order);
* **hsm_remove**: drop backend copies of entries deleted from the FS;
* **undelete / disaster recovery** helpers: the catalog retains enough
  metadata to re-create a released/removed entry's stub and restore payload
  from the HSM backend.
"""
from __future__ import annotations

import time
from typing import List, Optional

from .catalog import Catalog
from .policy import parse_expr
from .policy_engine import (PolicyDefinition, PolicyEngine, RunReport,
                            UsageWatermarkTrigger)
from .types import Entry, FsType, HsmState


class HsmCoordinator:
    """Wires archive/release policies between a LustreSim and its HSM."""

    def __init__(self, fs, catalog: Catalog, engine: PolicyEngine,
                 archive_age: str = "0s", archive_id: int = 1,
                 high_wm: float = 80.0, low_wm: float = 60.0) -> None:
        self.fs = fs
        self.catalog = catalog
        self.engine = engine
        self.archive_id = archive_id

        # -- archive policy: new/dirty files, old enough, not released
        def do_archive(e: Entry, params: dict) -> bool:
            aid = params.get("archive_id", self.archive_id)
            self.fs.hsm_archive(e.fid, archive_id=aid)
            self.catalog.update_fields(e.fid, hsm_state=HsmState.ARCHIVED,
                                       archive_id=aid)
            return True

        def do_archive_batch(batch, params: dict) -> List[bool]:
            # Entry-free: consumes a ColumnBatch, touches only fid columns
            aid = params.get("archive_id", self.archive_id)
            oks = []
            done = []
            for fid in batch.fids.tolist():
                try:
                    self.fs.hsm_archive(fid, archive_id=aid)
                    oks.append(True)
                    done.append(fid)
                except Exception:
                    oks.append(False)
            self.catalog.update_fields_batch(
                done, hsm_state=HsmState.ARCHIVED, archive_id=aid)
            return oks

        do_archive.action_batch = do_archive_batch

        self.engine.register(PolicyDefinition.from_config(
            name="hsm_archive", action=do_archive,
            scope="type == file",
            rules=[("archive_candidates",
                    f"(hsm_state == none or hsm_state == dirty) "
                    f"and last_mod >= {archive_age}", {})],
            sort_by="mtime",
        ))

        # -- release policy: archived files, LRU by atime, targeted per OST
        def do_release(e: Entry, params: dict) -> bool:
            self.fs.hsm_release(e.fid)
            self.catalog.update_fields(e.fid, hsm_state=HsmState.RELEASED,
                                       blocks=0)
            return True

        def do_release_batch(batch, params: dict) -> List[bool]:
            # Entry-free: consumes a ColumnBatch, touches only fid columns
            oks = []
            done = []
            for fid in batch.fids.tolist():
                try:
                    self.fs.hsm_release(fid)
                    oks.append(True)
                    done.append(fid)
                except Exception:
                    oks.append(False)
            self.catalog.update_fields_batch(
                done, hsm_state=HsmState.RELEASED, blocks=0)
            return oks

        do_release.action_batch = do_release_batch

        self.engine.register(PolicyDefinition.from_config(
            name="hsm_release", action=do_release,
            scope="type == file",
            rules=[("release_candidates", "hsm_state == archived", {})],
            sort_by="atime",
        ))

        def ost_usage():
            return [(o.index, o.used, o.capacity) for o in self.fs.osts]

        self.engine.add_watermark_trigger(
            "hsm_release",
            UsageWatermarkTrigger(
                usage_fn=ost_usage, high_pct=high_wm, low_pct=low_wm,
                restrict_fn=lambda ost: parse_expr(f"ost_idx == {int(ost)}")))

    # -- convenience drivers ----------------------------------------------------
    def archive_pass(self) -> RunReport:
        return self.engine.run("hsm_archive")

    def space_check(self) -> List[RunReport]:
        """Fire watermark purges if any OST is over threshold (C7)."""
        return self.engine.check_triggers()

    # -- undelete & disaster recovery (paper SII-C3) ------------------------------
    def undelete(self, fid: int, parent: int, name: str) -> Optional[int]:
        """Re-create a removed entry from catalog+HSM knowledge.

        Works when the backend copy still exists: a fresh stub is created and
        payload restored. Returns the new fid, or None if unrecoverable.
        """
        if self.fs.hsm is None or not self.fs.hsm.has(fid):
            return None
        size = self.fs.hsm.get(fid)
        new_fid = self.fs.create(parent, name)
        self.fs.write(new_fid, size)
        # adopt the old archive object under the new fid
        self.fs.hsm.put(new_fid, size, self.archive_id)
        self.fs.hsm.remove(fid)
        self.fs._nodes[new_fid].entry.hsm_state = HsmState.ARCHIVED
        e = self.fs.stat(new_fid)
        if e is not None:
            self.catalog.upsert(e)
        return new_fid

    def rebuild_catalog(self, scanner_threads: int = 4) -> int:
        """Disaster recovery: rebuild the DB from a full scan (C2)."""
        from .scanner import Scanner
        s = Scanner(self.fs, self.catalog, n_threads=scanner_threads)
        stats = s.scan()
        return stats.entries
