"""Shipped policy plugins (C10 — robinhood v3 architecture, Fig. 4).

Each plugin is an action factory: given runtime handles it returns an
``Action`` callable usable in a :class:`PolicyDefinition`. Administrators
compose policies from these "with a few lines of configuration"; custom
plugins are just new callables registered in :data:`PLUGIN_REGISTRY`.

Batch interface (zero-materialization contract)
-----------------------------------------------

Actions may expose a vectorized form by attaching an
``action_batch(batch, params) -> list[bool]`` attribute to the callable.
``batch`` is a :class:`~repro.core.catalog.ColumnBatch` — parallel numpy
columns (``batch.fids``, ``batch.size``, ``batch.hsm_state``, interned
codes with ``batch.decode("owner")`` for lazy string access) gathered
straight from the catalog shards with **no per-entry Python object**. The
engine calls it once per rule group per chunk; actions apply their effects
with one filesystem pass plus one ``catalog.*_batch`` commit.

Actions that genuinely need full :class:`Entry` objects (names, paths,
xattrs) declare ``needs_entries = True`` next to ``action_batch``; the
engine then materializes entries for that action alone and passes
``List[Entry]`` instead. Everything else rides the Entry-free path.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from .catalog import Catalog, ColumnBatch
from .types import Entry, HsmState

PluginFactory = Callable[..., Callable[[Entry, dict], bool]]
PLUGIN_REGISTRY: Dict[str, PluginFactory] = {}


def register_plugin(name: str) -> Callable[[PluginFactory], PluginFactory]:
    def deco(fn: PluginFactory) -> PluginFactory:
        PLUGIN_REGISTRY[name] = fn
        return fn
    return deco


@register_plugin("purge")
def purge_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Delete entries (classic cleanup policy)."""

    def action(e: Entry, params: dict) -> bool:
        fs.unlink(e.fid)
        catalog.remove(e.fid)
        return True

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        oks = []
        gone = []
        for fid in batch.fids.tolist():
            try:
                fs.unlink(fid)
                oks.append(True)
                gone.append(fid)
            except Exception:
                oks.append(False)
        catalog.remove_batch(gone)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("rmdir_empty")
def rmdir_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Remove old empty directories.

    The scalar path needs a ``readdir`` per entry; the batch path derives
    a vectorized per-directory child-count column from the catalog's
    ``parent_fid`` column — the same one-vector groupby as
    ``Reports.top_dirs_by_count`` — cached per :attr:`Catalog.version`.
    Within a chunk, directories are processed in plan order with their
    counts decremented as children are removed, so a parent emptied by a
    child earlier in the chunk is removed exactly like the scalar
    readdir path would; one batched catalog commit, no per-directory
    filesystem listing.
    """

    # sorted unique parent fids + child counts, rebuilt when the catalog
    # ticks (removals inside a run can empty ancestors; the next chunk
    # re-derives)
    cache = {"version": -1, "parents": None, "counts": None}

    def _child_counts(fids: np.ndarray) -> List[int]:
        version = catalog.version
        if cache["version"] != version:
            col = catalog.arrays()["parent_fid"]
            cache["parents"], cache["counts"] = np.unique(
                col[col >= 0], return_counts=True)
            cache["version"] = version
        parents, counts = cache["parents"], cache["counts"]
        if not len(parents):
            return [0] * len(fids)
        pos_c = np.clip(np.searchsorted(parents, fids), 0, len(parents) - 1)
        hit = parents[pos_c] == fids
        return np.where(hit, counts[pos_c], 0).tolist()

    def action(e: Entry, params: dict) -> bool:
        if fs.readdir(e.fid):
            return False
        fs.unlink(e.fid)
        catalog.remove(e.fid)
        return True

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        fids = batch.fids.tolist()
        parent_of = batch.parent_fid.tolist()
        remaining = dict(zip(fids, _child_counts(batch.fids)))
        oks = [False] * len(fids)
        gone = []
        for i, fid in enumerate(fids):
            if remaining.get(fid, 0):
                continue                    # still has children
            try:
                fs.unlink(fid)
            except Exception:
                continue
            oks[i] = True
            gone.append(fid)
            if parent_of[i] in remaining:   # parent may empty in-chunk
                remaining[parent_of[i]] -= 1
        catalog.remove_batch(gone)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("archive")
def archive_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    def action(e: Entry, params: dict) -> bool:
        fs.hsm_archive(e.fid, archive_id=params.get("archive_id", 1))
        catalog.update_fields(e.fid, hsm_state=HsmState.ARCHIVED)
        return True

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        archive_id = params.get("archive_id", 1)
        oks = []
        done = []
        for fid in batch.fids.tolist():
            try:
                fs.hsm_archive(fid, archive_id=archive_id)
                oks.append(True)
                done.append(fid)
            except Exception:
                oks.append(False)
        catalog.update_fields_batch(done, hsm_state=HsmState.ARCHIVED)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("release")
def release_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    def action(e: Entry, params: dict) -> bool:
        fs.hsm_release(e.fid)
        catalog.update_fields(e.fid, hsm_state=HsmState.RELEASED, blocks=0)
        return True

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        oks = []
        done = []
        for fid in batch.fids.tolist():
            try:
                fs.hsm_release(fid)
                oks.append(True)
                done.append(fid)
            except Exception:
                oks.append(False)
        catalog.update_fields_batch(done, hsm_state=HsmState.RELEASED,
                                    blocks=0)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("migrate_pool")
def migrate_pool_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Internal data migration between OST pools (paper SIII-D: SSD<->HDD).

    Re-stripes a file's data onto the target pool's OSTs (simulated move)
    and updates pool/ost metadata — the 'data must be moved between pools of
    storage resources according to site-specific policies' case.

    The batch form takes the FS lock once per chunk and applies the space
    accounting as a **per-OST grouped restripe**: frees are summed per
    source OST and allocations per target OST, one ``free``/``alloc`` call
    per OST instead of one per file stripe, followed by a single catalog
    batch commit.
    """

    def _new_stripes(target_pool: str):
        cands = fs.pools.get(target_pool)
        if not cands:
            return None
        n = min(fs.stripe_count, len(cands))
        return tuple(cands[i % len(cands)] for i in range(n))

    def action(e: Entry, params: dict) -> bool:
        target_pool = params.get("pool", "")
        new_stripes = _new_stripes(target_pool)
        if new_stripes is None:
            return False
        node = fs._nodes.get(e.fid)
        if node is None:
            return False
        with fs._lock:
            per = node.data_len // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
            for idx in e.stripe_osts:
                fs.osts[idx].free(per)
            per_new = node.data_len // max(1, len(new_stripes))
            for idx in new_stripes:
                fs.osts[idx].alloc(per_new)
            node.entry.stripe_osts = new_stripes
            node.entry.ost_idx = new_stripes[0] if new_stripes else -1
            node.entry.pool = target_pool
        catalog.update_fields(e.fid, pool=target_pool,
                              ost_idx=new_stripes[0] if new_stripes else -1,
                              stripe_osts=new_stripes)
        return True

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        target_pool = params.get("pool", "")
        new_stripes = _new_stripes(target_pool)
        fids = batch.fids.tolist()
        if new_stripes is None:
            return [False] * len(fids)
        oks = [False] * len(fids)
        moved: List[int] = []
        freed: Dict[int, int] = {}       # per-source-OST grouped frees
        alloc_total = 0                  # per-target-OST grouped allocs
        with fs._lock:
            for i, fid in enumerate(fids):
                node = fs._nodes.get(fid)
                if node is None:
                    continue
                stripes = node.entry.stripe_osts
                per = node.data_len // max(1, len(stripes)) if stripes else 0
                for idx in stripes:
                    freed[idx] = freed.get(idx, 0) + per
                alloc_total += node.data_len // max(1, len(new_stripes))
                node.entry.stripe_osts = new_stripes
                node.entry.ost_idx = new_stripes[0] if new_stripes else -1
                node.entry.pool = target_pool
                oks[i] = True
                moved.append(fid)
            for idx, nbytes in freed.items():
                fs.osts[idx].free(nbytes)
            for idx in new_stripes:
                fs.osts[idx].alloc(alloc_total)
        catalog.update_fields_batch(
            moved, pool=target_pool,
            ost_idx=new_stripes[0] if new_stripes else -1,
            stripe_osts=new_stripes)
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("checksum")
def checksum_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Data-integrity check pass (paper SIII-D 'data integrity checks').

    The sim has no payload bytes; we verify metadata consistency instead:
    catalog size/blocks must match FS truth. The batch form compares the
    catalog's size column against FS stats in one pass and commits the
    check/corrupt verdicts with one grouped catalog update per outcome.
    """

    def action(e: Entry, params: dict) -> bool:
        truth = fs.stat(e.fid)
        if truth is None:
            return False
        ok = truth.size == e.size
        catalog.update_fields(e.fid, status="checked" if ok else "corrupt")
        return ok

    def _truth_sizes(fids: List[int]) -> List[Optional[int]]:
        """FS-truth sizes for a chunk: one FS lock when the backend exposes
        its node table (LustreSim), else a stat per fid."""
        nodes = getattr(fs, "_nodes", None)
        if nodes is not None and hasattr(fs, "_lock"):
            with fs._lock:
                return [nodes[f].entry.size if f in nodes else None
                        for f in fids]
        out: List[Optional[int]] = []
        for f in fids:
            truth = fs.stat(f)
            out.append(None if truth is None else truth.size)
        return out

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        fids = batch.fids.tolist()
        sizes = batch.size.tolist()
        oks = [False] * len(fids)
        checked: List[int] = []
        corrupt: List[int] = []
        for i, (fid, size, truth) in enumerate(
                zip(fids, sizes, _truth_sizes(fids))):
            if truth is None:
                continue
            if truth == size:
                oks[i] = True
                checked.append(fid)
            else:
                corrupt.append(fid)
        if checked:
            catalog.update_fields_batch(checked, status="checked")
        if corrupt:
            catalog.update_fields_batch(corrupt, status="corrupt")
        return oks

    action.action_batch = action_batch
    return action


@register_plugin("tag_status")
def tag_status_plugin(fs, catalog: Catalog) -> Callable[[Entry, dict], bool]:
    """Generic post-processing: set the v3 status field."""

    def action(e: Entry, params: dict) -> bool:
        return catalog.update_fields(e.fid, status=params.get("status", "seen"))

    def action_batch(batch: ColumnBatch, params: dict) -> List[bool]:
        fids = batch.fids.tolist()
        updated = set(catalog.update_fields_batch(
            fids, status=params.get("status", "seen")))
        return [fid in updated for fid in fids]

    action.action_batch = action_batch
    return action
