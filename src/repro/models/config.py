"""Model configuration: one schema covering all 10 assigned architectures.

A model is a stack of layers drawn from a repeating ``pattern`` of
:class:`LayerSpec`s (periods 1-5 cover every assigned arch). Layers inside
full pattern repetitions are executed with ``jax.lax.scan`` over stacked
parameters (compile time independent of depth); remainder layers (e.g.
recurrentgemma's 38 = 12x3 + 2) are unrolled as a tail.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# sequence-mixing kinds
ATTN_FULL = "full"        # causal full attention
ATTN_LOCAL = "local"      # sliding-window attention
ATTN_NONCAUSAL = "bidir"  # encoder self-attention
MIX_RGLRU = "rglru"       # RecurrentGemma recurrent block
MIX_RWKV6 = "rwkv6"       # RWKV-6 time-mix

# ffn kinds
FFN_DENSE = "dense"       # swiglu (or gelu for whisper)
FFN_MOE = "moe"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mix: str = ATTN_FULL        # sequence-mixing kind
    ffn: str = FFN_DENSE
    cross_attn: bool = False    # cross-attention sublayer (enc-dec / VLM)


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    num_experts: int
    top_k: int
    shared_expert: bool = False   # llama4-style always-on expert
    capacity_factor: float = 1.25
    router_jitter: bool = False


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Whisper-style encoder (conv frontend stubbed to frame embeddings)."""

    n_layers: int
    n_frames: int = 1500          # 30 s of audio at 50 Hz post-conv


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # attention details
    window: int = 4096            # for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # chatglm 2d-rope: 0.5
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    post_norms: bool = False      # gemma2 sandwich norms
    norm: str = "rms"             # rms | ln
    ffn_act: str = "swiglu"       # swiglu | gelu
    embed_scale: bool = False     # gemma*: x *= sqrt(d_model)
    tie_embeddings: bool = False
    # recurrent details
    d_rnn: int = 0                # rglru width (0 -> d_model)
    conv_width: int = 4           # rglru temporal conv taps
    rwkv_lora_mix: int = 32
    rwkv_lora_decay: int = 64
    # moe
    moe: Optional[MoeSpec] = None
    moe_groups: int = 1           # dispatch groups (set = dp degree; SPerf)
    moe_pspec: Optional[object] = None   # PartitionSpec for (G,E,cap,D) buf
    # modality extras
    encoder: Optional[EncoderSpec] = None   # whisper
    n_img_tokens: int = 0                    # vlm cross-attn K/V length
    max_position: int = 1 << 19
    # numerics
    norm_eps: float = 1e-6
    kv_cache_dtype: str = "bf16"   # "int8": quantized decode KV (SPerf)

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        """The full resolved per-layer spec list (pattern + tail)."""
        p = len(self.pattern)
        reps, rem = divmod(self.n_layers, p)
        return self.pattern * reps + self.pattern[:rem]

    @property
    def n_super(self) -> int:
        """Number of complete pattern repetitions (scanned)."""
        return self.n_layers // len(self.pattern)

    @property
    def tail_specs(self) -> Tuple[LayerSpec, ...]:
        rem = self.n_layers % len(self.pattern)
        return self.pattern[:rem]

    def param_count(self) -> int:
        """Approximate total parameter count (for MODEL_FLOPS, reporting)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        qk = self.n_heads * self.head_dim
        kv = self.n_kv * self.head_dim
        total = V * D + (0 if self.tie_embeddings else D * V) + D
        for spec in self.layers:
            n = 2 * D                           # norms
            if spec.mix in (ATTN_FULL, ATTN_LOCAL, ATTN_NONCAUSAL):
                n += D * qk + 2 * D * kv + qk * D
            elif spec.mix == MIX_RGLRU:
                R = self.rnn_width
                n += 2 * D * R + 2 * R * R + R * D + R * self.conv_width + 2 * R
            elif spec.mix == MIX_RWKV6:
                n += 4 * D * D + D * self.head_dim  # r,k,v,g,o + u; loras small
                n += D * self.rwkv_lora_mix * 10 + 2 * D * self.rwkv_lora_decay
            if spec.cross_attn:
                n += D * qk + 2 * D * kv + qk * D + D
            if spec.ffn == FFN_MOE and self.moe is not None:
                e = self.moe.num_experts
                n += D * e + e * 3 * D * F
                if self.moe.shared_expert:
                    n += 3 * D * F
            elif spec.mix == MIX_RWKV6:
                n += 2 * D * F                      # rwkv channel-mix (no gate)
            else:
                n += 3 * D * F if self.ffn_act == "swiglu" else 2 * D * F
            total += n
        if self.encoder is not None:
            enc_layer = 2 * D + D * qk + 2 * D * kv + qk * D + 2 * D * F
            total += self.encoder.n_layers * enc_layer + self.encoder.n_frames * D
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared instead of all)."""
        if self.moe is None:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        e, k = self.moe.num_experts, self.moe.top_k
        inactive = 0
        for spec in self.layers:
            if spec.ffn == FFN_MOE:
                inactive += (e - k) * 3 * D * F
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Input shape sets (assignment): per-arch cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = {s.name: s for s in
              (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """Which of the 4 assigned shapes apply to this arch (see DESIGN.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if is_subquadratic(cfg):
        out.append(LONG_500K)
    return tuple(out)


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True if decode state is bounded (no full-attention layer)."""
    return all(s.mix in (MIX_RGLRU, MIX_RWKV6, ATTN_LOCAL) and not s.cross_attn
               for s in cfg.layers)
