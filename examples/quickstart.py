"""Quickstart: Robinhood over a real directory tree in 40 lines.

Builds a temp POSIX tree, scans it in parallel into the catalog, then
answers find/du/report queries from the DB (never re-touching the FS).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.core import Catalog, Reports, Scanner, StatsAggregator
from repro.fs import PosixFs


def main() -> None:
    root = tempfile.mkdtemp(prefix="rbh_quickstart_")
    for d in ("projects/alpha", "projects/beta", "scratch"):
        os.makedirs(os.path.join(root, d), exist_ok=True)
    for i in range(20):
        sub = ("projects/alpha", "projects/beta", "scratch")[i % 3]
        with open(os.path.join(root, sub, f"file{i}.dat"), "wb") as f:
            f.write(b"#" * (1000 * (i + 1)))

    # 1. collect: parallel depth-first scan into the catalog
    fs = PosixFs(root)
    catalog = Catalog(n_shards=2)
    stats = StatsAggregator(catalog.strings)
    catalog.add_delta_hook(stats.on_delta)
    scan = Scanner(fs, catalog, n_threads=4).scan()
    print(f"scanned {scan.entries} entries in {scan.elapsed*1e3:.1f} ms "
          f"with 4 threads")

    # 2. exploit: queries answered from the DB
    rep = Reports(catalog, stats)
    big = rep.find(f"type == file and size > 10k")
    print(f"\nrbh-find 'size > 10k': {len(big)} files")
    for p in big[:5]:
        print("  ", p)
    print("\nrbh-du projects/:",
          rep.du(os.path.join(root, "projects")))
    print("\ntop-3 largest files:")
    for row in rep.top_files(k=3):
        print(f"   {row['path']}  {int(row['size'])} bytes")
    uid = str(os.getuid())
    print(f"\nrbh-report -u {uid} (O(1), pre-aggregated):")
    print(rep.format_user_report(uid))


if __name__ == "__main__":
    main()
