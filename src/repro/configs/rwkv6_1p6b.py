"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — data-dependent decay; 32 heads of dim 64. [arXiv:2404.05892]
"""
from repro.models.config import MIX_RWKV6, LayerSpec, ModelConfig

_PATTERN = (LayerSpec(mix=MIX_RWKV6),)

CONFIG = ModelConfig(
    name="rwkv6_1p6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, head_dim=64,
    d_ff=7168, vocab=65536,
    pattern=_PATTERN,
    rwkv_lora_mix=32, rwkv_lora_decay=64,
)

SMOKE = ModelConfig(
    name="rwkv6_smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN,
    rwkv_lora_mix=8, rwkv_lora_decay=8,
)
