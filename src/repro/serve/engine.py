"""Batched serving engine over the tiered, paged KV cache.

A compact GQA attention LM whose decode path reads K/V through the
:class:`TieredKvCache` page tables and the ``paged_attention`` kernel —
the end-to-end demonstration that policy-driven page tiering (DESIGN SS2)
serves real traffic: requests admit/prefill/decode/finish while the policy
engine moves pages between HBM and host tiers underneath them.

(The production 10-arch zoo serves through ``serve/serve_step.py`` with
dense ring caches — this engine is the paged/tiered specialization.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.paged_attention.ops import paged_attention
from ..kvcache.paged import PagePool
from ..kvcache.tiering import TieredKvCache


@dataclasses.dataclass
class PagedLMConfig:
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv: int = 2
    head_dim: int = 16
    d_ff: int = 128
    page_size: int = 16
    n_pages: int = 64         # hot-pool capacity (per layer)
    high_wm: float = 80.0
    low_wm: float = 50.0


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: PagedLMConfig, seed: int = 0) -> None:
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 2 + 6 * cfg.n_layers)
        s = 1.0 / np.sqrt(cfg.d_model)
        self.embed = jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.05
        self.head = jax.random.normal(ks[1], (cfg.d_model, cfg.vocab)) * 0.05
        self.layers = []
        qk = cfg.n_heads * cfg.head_dim
        kv = cfg.n_kv * cfg.head_dim
        for i in range(cfg.n_layers):
            b = 2 + 6 * i
            self.layers.append({
                "wq": jax.random.normal(ks[b], (cfg.d_model, qk)) * s,
                "wk": jax.random.normal(ks[b + 1], (cfg.d_model, kv)) * s,
                "wv": jax.random.normal(ks[b + 2], (cfg.d_model, kv)) * s,
                "wo": jax.random.normal(ks[b + 3], (qk, cfg.d_model)) * s,
                "w1": jax.random.normal(ks[b + 4], (cfg.d_model, cfg.d_ff)) * s,
                "w2": jax.random.normal(ks[b + 5], (cfg.d_ff, cfg.d_model))
                * (1.0 / np.sqrt(cfg.d_ff)),
            })
        # one tiered cache per layer (pages are per-layer entries)
        self.caches = [
            TieredKvCache(PagePool(cfg.n_pages, cfg.page_size, cfg.n_kv,
                                   cfg.head_dim), cfg.high_wm, cfg.low_wm)
            for _ in range(cfg.n_layers)]
        self.requests: Dict[int, Request] = {}
        self._lengths: Dict[int, int] = {}

    # -- model math -----------------------------------------------------------
    def _token_qkv(self, layer: dict, x: jnp.ndarray):
        cfg = self.cfg
        q = (x @ layer["wq"]).reshape(cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(cfg.n_kv, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(cfg.n_kv, cfg.head_dim)
        return q, k, v

    def _step_token(self, req: Request, token: int) -> int:
        """Run one token through all layers for one request."""
        cfg = self.cfg
        x = self.embed[token]
        pos = self._lengths[req.req_id]
        max_pages = -(-(pos + 1) // cfg.page_size)
        for li, layer in enumerate(self.layers):
            cache = self.caches[li]
            q, k, v = self._token_qkv(layer, x)
            cache.append_token(req.req_id, np.asarray(k), np.asarray(v))
            pt = cache.page_table(req.req_id, max_pages)
            out = paged_attention(
                q[None], jnp.asarray(cache.pool.k), jnp.asarray(cache.pool.v),
                jnp.asarray(pt[None]), jnp.asarray([pos + 1], np.int32))
            cache.unpin()
            attn = out[0].reshape(-1) @ layer["wo"]
            x = x + attn
            x = x + jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]
        self._lengths[req.req_id] = pos + 1
        logits = x @ self.head
        return int(jnp.argmax(logits))

    # -- request lifecycle ------------------------------------------------------
    def admit(self, req: Request) -> None:
        self.requests[req.req_id] = req
        self._lengths[req.req_id] = 0
        for cache in self.caches:
            cache.admit(req.req_id)

    def run(self, requests: List[Request],
            policy_interval: int = 4) -> List[Request]:
        """Serve a batch of requests to completion (greedy decoding)."""
        for r in requests:
            self.admit(r)
        # prefill: feed prompts token by token (writes pages)
        for r in requests:
            nxt = 0
            for t in r.prompt:
                nxt = self._step_token(r, t)
            r.generated.append(nxt)
        # decode rounds (interleaved across requests = continuous batching)
        step = 0
        while any(not r.done for r in requests):
            for r in requests:
                if r.done:
                    continue
                nxt = self._step_token(r, r.generated[-1])
                r.generated.append(nxt)
                if len(r.generated) >= r.max_new:
                    r.done = True
            step += 1
            if step % policy_interval == 0:
                for cache in self.caches:
                    cache.maybe_run_policies()
        for r in requests:
            for cache in self.caches:
                cache.finish(r.req_id)
        return requests

    def tier_report(self) -> List[dict]:
        return [c.tier_report() for c in self.caches]
