"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array
                        ) -> jax.Array:
    """Decode attention over a paged KV pool.

    q:          (B, H, hd)            — one query token per sequence
    k_pages:    (n_pages, P, K, hd)   — global page pool (P = page size)
    v_pages:    (n_pages, P, K, hd)
    page_table: (B, max_pages) int32  — page ids per sequence, -1 = unused
    lengths:    (B,) int32            — tokens in each sequence's cache
    Returns (B, H, hd). GQA: H = K * G.
    """
    B, H, hd = q.shape
    n_pages, P, K, _ = k_pages.shape
    G = H // K
    max_pages = page_table.shape[1]

    # gather each sequence's pages -> contiguous (B, max_pages*P, K, hd)
    safe_ids = jnp.maximum(page_table, 0)
    k_seq = k_pages[safe_ids]                  # (B, max_pages, P, K, hd)
    v_seq = v_pages[safe_ids]
    k_seq = k_seq.reshape(B, max_pages * P, K, hd)
    v_seq = v_seq.reshape(B, max_pages * P, K, hd)
    if G > 1:
        k_seq = jnp.repeat(k_seq, G, axis=2)
        v_seq = jnp.repeat(v_seq, G, axis=2)

    pos = jnp.arange(max_pages * P)[None, :]                # (1, L)
    page_valid = jnp.repeat(page_table >= 0, P, axis=1)     # (B, L)
    valid = (pos < lengths[:, None]) & page_valid

    s = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32) / jnp.sqrt(float(hd)),
                   k_seq.astype(jnp.float32))
    s = jnp.where(valid[:, None, :], s, -1e30)
    mx = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - mx)
    p = jnp.where(s > -0.5e30, p, 0.0)
    o = jnp.einsum("bhl,blhd->bhd", p, v_seq.astype(jnp.float32))
    return (o / jnp.maximum(p.sum(-1)[..., None], 1e-20)).astype(q.dtype)
