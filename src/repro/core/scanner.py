"""Parallel namespace scanner (C2) — multi-threaded depth-first traversal.

Reproduces the paper's Fig. 3 design: the traversal is decomposed into
per-directory *tasks*; a pool of worker threads services them from a shared
LIFO stack, which yields the depth-first priority the paper illustrates
(deep directories are drained before siblings, bounding the frontier —
a FIFO would grow the frontier to the namespace's width).

Also implements the paper's **multi-client** mode: the namespace is split
at a chosen depth into disjoint subtrees, each assigned to a *client* (its
own scanner instance with its own thread pool, simulating one Lustre client
node's RPC stream), all feeding the same catalog.

The scan is the *initial population* path; steady-state freshness comes from
the changelog (C3). A completed scan also reconciles: entries present in the
catalog but absent from the FS are dropped (``prune_missing``) — this is what
makes the scan usable for disaster recovery of the catalog.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

from .catalog import Catalog
from .types import Entry, FsType


class _TaskStack:
    """LIFO work stack with completion tracking (depth-first priority)."""

    def __init__(self) -> None:
        self._lock = threading.Condition()
        self._stack: List[int] = []
        self._outstanding = 0

    def push(self, fid: int) -> None:
        with self._lock:
            self._stack.append(fid)
            self._outstanding += 1
            self._lock.notify()

    def pop(self) -> Optional[int]:
        """Next task, or None when the whole traversal is complete."""
        with self._lock:
            while not self._stack:
                if self._outstanding == 0:
                    return None
                self._lock.wait(timeout=0.1)
            return self._stack.pop()

    def done(self) -> None:
        with self._lock:
            self._outstanding -= 1
            if self._outstanding == 0:
                self._lock.notify_all()


class ScanStats:
    def __init__(self) -> None:
        self.entries = 0
        self.dirs = 0
        self.errors = 0
        self.elapsed = 0.0
        self._lock = threading.Lock()

    def bump(self, entries: int = 0, dirs: int = 0, errors: int = 0) -> None:
        with self._lock:
            self.entries += entries
            self.dirs += dirs
            self.errors += errors


class Scanner:
    """Multi-threaded depth-first scanner feeding a catalog (or a sink)."""

    def __init__(self, fs, catalog: Optional[Catalog] = None,
                 n_threads: int = 4,
                 sink: Optional[Callable[[Entry], None]] = None,
                 readdir_latency: float = 0.0) -> None:
        self.fs = fs
        self.catalog = catalog
        self.n_threads = max(1, n_threads)
        self.sink = sink
        self.readdir_latency = readdir_latency  # simulated per-RPC latency
        self.stats = ScanStats()

    def _emit(self, e: Entry) -> None:
        if self.sink is not None:
            self.sink(e)
        elif self.catalog is not None:
            self.catalog.upsert(e)
        self.stats.bump(entries=1)

    def _worker(self, stack: _TaskStack) -> None:
        while True:
            fid = stack.pop()
            if fid is None:
                return
            try:
                if self.readdir_latency:
                    time.sleep(self.readdir_latency)
                children = self.fs.readdir(fid)
                self.stats.bump(dirs=1)
                for _name, cfid in children:
                    e = self.fs.stat(cfid)
                    if e is None:
                        self.stats.bump(errors=1)
                        continue
                    self._emit(e)
                    if e.type == FsType.DIR:
                        stack.push(cfid)
            except Exception:
                self.stats.bump(errors=1)
            finally:
                stack.done()

    def scan(self, root_fid: Optional[int] = None) -> ScanStats:
        """Full traversal from ``root_fid`` (default: FS root)."""
        t0 = time.perf_counter()
        stack = _TaskStack()
        root = self.fs.root_fid() if root_fid is None else root_fid
        root_entry = self.fs.stat(root)
        if root_entry is not None:
            self._emit(root_entry)
        stack.push(root)
        threads = [threading.Thread(target=self._worker, args=(stack,),
                                    daemon=True)
                   for _ in range(self.n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self.stats.elapsed = time.perf_counter() - t0
        return self.stats


def multi_client_scan(fs, catalog: Catalog, n_clients: int = 2,
                      threads_per_client: int = 4,
                      readdir_latency: float = 0.0) -> List[ScanStats]:
    """Paper SIII-A1: split the namespace across clients, one DB.

    Top-level subtrees are round-robined over ``n_clients`` scanner
    instances running concurrently; their cumulated RPC throughput is what
    beats the single-client limit.
    """
    root = fs.root_fid()
    top = fs.readdir(root)
    root_entry = fs.stat(root)
    if root_entry is not None:
        catalog.upsert(root_entry)
    # assign top-level children round-robin to clients
    assignments: List[List[int]] = [[] for _ in range(n_clients)]
    for i, (_name, fid) in enumerate(top):
        e = fs.stat(fid)
        if e is None:
            continue
        catalog.upsert(e)
        if e.type == FsType.DIR:
            assignments[i % n_clients].append(fid)

    scanners = [Scanner(fs, catalog, n_threads=threads_per_client,
                        readdir_latency=readdir_latency)
                for _ in range(n_clients)]

    def run(client: int) -> None:
        for fid in assignments[client]:
            # each subtree scan reuses the client's thread pool
            s = scanners[client]
            stack = _TaskStack()
            stack.push(fid)
            threads = [threading.Thread(target=s._worker, args=(stack,),
                                        daemon=True)
                       for _ in range(s.n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()

    drivers = [threading.Thread(target=run, args=(c,), daemon=True)
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for d in drivers:
        d.start()
    for d in drivers:
        d.join()
    elapsed = time.perf_counter() - t0
    for s in scanners:
        s.stats.elapsed = elapsed
    return [s.stats for s in scanners]


def prune_missing(fs, catalog: Catalog) -> int:
    """Drop catalog entries that no longer exist in the FS (post-scan GC)."""
    removed = 0
    for shard in catalog.shards:
        for fid in shard.fids():
            if fs.stat(fid) is None:
                if catalog.remove(fid):
                    removed += 1
    return removed
