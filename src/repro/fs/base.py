"""Minimal backend interface the scanner and pipeline consume."""
from __future__ import annotations

from typing import Iterable, List, Optional, Protocol, Tuple

from ..core.types import Entry


class FsBackend(Protocol):
    """What Robinhood needs from a filesystem: readdir + stat, by fid."""

    def root_fid(self) -> int: ...

    def readdir(self, fid: int) -> List[Tuple[str, int]]:
        """(name, child_fid) pairs of a directory."""
        ...

    def stat(self, fid: int) -> Optional[Entry]: ...
