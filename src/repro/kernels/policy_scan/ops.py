"""Public policy-scan op: pads, dispatches kernel/oracle, unpads."""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANE, policy_scan_batch_pallas, policy_scan_pallas
from .ref import (N_AGG, OP_AND, OP_NOP, OP_NOT, OP_OR, aggregate_multi,
                  policy_scan_batch_ref, policy_scan_multi_ref,
                  policy_scan_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                operands: jax.Array, size_col: int = 0, blocks_col: int = 1,
                valid_col: int = -1, use_kernel: bool = True,
                tile: int = 8 * LANE) -> Tuple[jax.Array, jax.Array]:
    """Evaluate a predicate program over a columnar table + aggregates.

    cols: (n_cols, N) f32. Returns (mask (N,) f32, agg (N_AGG,) f32).
    Rows are padded to the tile size with an all-invalid pad (mask forced 0
    via a validity column the wrapper appends when ``valid_col`` < 0).
    """
    n_cols, n = cols.shape
    if n == 0:            # zero-row table: nothing to scan (grid would be 0)
        return jnp.zeros((0,), jnp.float32), jnp.zeros((N_AGG,), jnp.float32)
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    mask, agg = policy_scan_pallas(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col, tile=tile,
        interpret=not _on_tpu()) if use_kernel else policy_scan_ref(
        cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
        operands.astype(jnp.float32), size_col=size_col,
        blocks_col=blocks_col, valid_col=valid_col)
    return mask[:n], agg


@partial(jax.jit, static_argnames=("size_col", "blocks_col"))
def policy_scan_multi(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                      operands: jax.Array, size_col: int = 0,
                      blocks_col: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Evaluate R padded predicate programs over one column stack.

    cols: (n_cols, N) f32; ops/colidx/operands: (R, P), OP_NOP padded.
    Returns (masks (R, N) f32, agg (N_AGG,) f32 for program 0). One
    columnar pass: matching and size/blocks aggregation fuse in one scan.
    """
    return policy_scan_multi_ref(cols, ops.astype(jnp.int32),
                                 colidx.astype(jnp.int32),
                                 operands.astype(jnp.float32),
                                 size_col=size_col, blocks_col=blocks_col)


@partial(jax.jit, static_argnames=("size_col", "blocks_col", "valid_col",
                                   "use_kernel", "tile"))
def policy_scan_batch(cols: jax.Array, ops: jax.Array, colidx: jax.Array,
                      operands: jax.Array, size_col: int = 0,
                      blocks_col: int = 1, valid_col: int = -1,
                      use_kernel: bool = True, tile: int = 8 * LANE
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-launch batch matcher over a columnar table.

    cols: (n_cols, N) f32; ops/colidx/operands: (R, P) OP_NOP-padded
    programs (program 0 = combined criteria, 1..R-1 = per-rule conditions).
    Returns (masks (R, N) f32, rule_idx (N,) i32, agg (R, N_AGG) f32): all
    program masks, fused first-match-wins attribution, and per-program
    size/blocks reductions — one kernel launch instead of R.
    """
    n_cols, n = cols.shape
    if n == 0:            # zero-row table: nothing to scan (grid would be 0)
        r = ops.shape[0]
        return (jnp.zeros((r, 0), jnp.float32), jnp.zeros((0,), jnp.int32),
                jnp.zeros((r, N_AGG), jnp.float32))
    pad = (-n) % tile
    if valid_col < 0:
        valid = jnp.ones((1, n), jnp.float32)
        cols = jnp.concatenate([cols, valid], axis=0)
        valid_col = n_cols
        n_cols += 1
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    args = (cols, ops.astype(jnp.int32), colidx.astype(jnp.int32),
            operands.astype(jnp.float32))
    kw = dict(size_col=size_col, blocks_col=blocks_col, valid_col=valid_col)
    if use_kernel:
        masks, rule, agg = policy_scan_batch_pallas(
            *args, tile=tile, interpret=not _on_tpu(), **kw)
    else:
        masks, rule, agg = policy_scan_batch_ref(*args, **kw)
    return masks[:, :n], rule[:n], agg


def _eval_unrolled(cols: jax.Array, ops: Tuple[int, ...],
                   colidx: Tuple[int, ...], operands: jax.Array) -> jax.Array:
    """Postfix program evaluation with the *program* static.

    The scan/kernel evaluators treat the program as data: every
    instruction materializes a (6, N) comparison stack and a dynamically
    indexed (max_stack, N) value stack — ~10 full passes over the column
    tile per instruction, all memory bandwidth. A policy's opcode/column
    sequence is fixed per definition though (only the *operands* move with
    ``now``), so this path unrolls the program in Python: each instruction
    lowers to exactly the one comparison it needs, the stack lives in
    tracer-land, and booleans (1 byte) replace f32 masks until the end.
    Bit-identical to :func:`repro.kernels.policy_scan.ref.eval_program` on
    {0, 1} masks — differential-tested.
    """
    stack: List[jax.Array] = []
    for i, op in enumerate(ops):
        if op == OP_NOP:
            continue
        if op < 6:
            vec = cols[colidx[i]]
            val = operands[i]
            # select the lambda BEFORE applying: one comparison traced per
            # instruction, not six
            cmp = (lambda a, b: a == b, lambda a, b: a != b,
                   lambda a, b: a > b, lambda a, b: a >= b,
                   lambda a, b: a < b, lambda a, b: a <= b)[op]
            stack.append(cmp(vec, val))
        elif op == OP_AND:
            b, a = stack.pop(), stack.pop()
            stack.append(a & b)
        elif op == OP_OR:
            b, a = stack.pop(), stack.pop()
            stack.append(a | b)
        elif op == OP_NOT:
            stack.append(~stack.pop())
    if not stack:
        return jnp.zeros(cols.shape[1], bool)
    return stack[-1]


def _unrolled_masks(cols: jax.Array, ops_t, colidx_t, operands: jax.Array,
                    valid_col: int) -> Tuple[List[jax.Array], jax.Array]:
    """Shared core of the unrolled paths: (bool program masks,
    first-match-wins rule_idx). Single semantics authority for the
    single-device oracle and the lean mesh branch — fix either behaviour
    here, never in a caller."""
    masks_b = []
    for r in range(len(ops_t)):
        m = _eval_unrolled(cols, ops_t[r], colidx_t[r], operands[r])
        if valid_col >= 0:
            m = m & (cols[valid_col] > 0.5)
        masks_b.append(m)
    if len(masks_b) > 1:
        rules = jnp.stack(masks_b[1:])
        first = jnp.argmax(rules, axis=0).astype(jnp.int32)
        rule = jnp.where(jnp.any(rules, axis=0), first, -1)
    else:
        rule = jnp.full(cols.shape[1], -1, jnp.int32)
    return masks_b, rule


@partial(jax.jit, static_argnames=("ops_t", "colidx_t", "size_col",
                                   "blocks_col", "valid_col"))
def policy_scan_batch_unrolled(cols: jax.Array, operands: jax.Array, *,
                               ops_t: Tuple[Tuple[int, ...], ...],
                               colidx_t: Tuple[Tuple[int, ...], ...],
                               size_col: int = 0, blocks_col: int = 1,
                               valid_col: int = -1
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Static-program batch matcher: the fast off-TPU single-launch path.

    Same contract as :func:`policy_scan_batch` — (masks (R, N) f32,
    rule_idx (N,) i32, agg (R, N_AGG) f32) — but the (R, P) opcode/column
    arrays are hashable tuples baked into the compilation (recompiles per
    policy *shape*, not per run: operand values, which carry ``now``-
    relative thresholds, stay dynamic). Needs no tile padding: there is no
    kernel grid, any N works.
    """
    masks_b, rule = _unrolled_masks(cols, ops_t, colidx_t, operands,
                                    valid_col)
    masks = jnp.stack(masks_b).astype(jnp.float32)
    agg = aggregate_multi(masks, cols[size_col], cols[blocks_col])
    return masks, rule, agg


def _program_tuples(ops: np.ndarray, colidx: np.ndarray
                    ) -> Tuple[Tuple[Tuple[int, ...], ...],
                               Tuple[Tuple[int, ...], ...]]:
    return (tuple(tuple(int(o) for o in row) for row in np.asarray(ops)),
            tuple(tuple(int(c) for c in row) for row in np.asarray(colidx)))


def _subject_bits(perm_local: jax.Array, sid: jax.Array) -> jax.Array:
    """Unpack one subject's packed visibility words into a per-row bool.

    ``perm_local`` is a device-local (Sp, W) uint32 permissions plane
    (one packed bitset row per subject, W = Rp // 32 words): bit ``b`` of
    word ``w`` — LSB-first — covers local row ``w * 32 + b``, matching
    the store's host-side ``np.packbits(..., bitorder="little")``
    staging. ``sid`` is a traced subject id (no recompile per subject).
    Returns the (W * 32,) bool visibility over the block's padded row
    axis — Rp is a tile multiple and the tile a multiple of 32, so the
    shapes line up exactly.
    """
    words = jax.lax.dynamic_index_in_dim(perm_local, sid, axis=0,
                                         keepdims=False)
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) \
        & jnp.uint32(1)
    return (bits != 0).reshape(-1)


@partial(jax.jit, static_argnames=("mesh", "ops_t", "colidx_t", "size_col",
                                   "blocks_col", "valid_col", "use_kernel",
                                   "tile", "with_agg"))
def mesh_policy_scan_batch(global_cols: jax.Array, operands: jax.Array, *,
                           mesh, ops_t: Tuple[Tuple[int, ...], ...],
                           colidx_t: Tuple[Tuple[int, ...], ...],
                           size_col: int = 0, blocks_col: int = 1,
                           valid_col: int = -1, use_kernel: bool = False,
                           tile: int = 8 * LANE, with_agg: bool = True,
                           perm: Optional[jax.Array] = None,
                           subject: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Data-parallel batch matcher over a device-resident sharded table.

    ``global_cols`` is (D, n_cols, Rp) f32, sharded along axis 0 over the
    1-D ``("shards",)`` mesh — one shard group's padded column stack per
    device, resident in device memory (see ``core.device_store``). Rp must
    be a tile multiple and ``valid_col`` must point at a 0/1 row-validity
    column (the store appends one), so no per-launch padding happens. The
    (R, P) opcode/column program structure rides as static tuples (only
    the replicated operand values are data — ``now``-relative thresholds
    change per run without recompiling).

    Under ``shard_map`` each device evaluates the whole program batch over
    its local (n_cols, Rp) block — the Pallas kernel
    (:func:`policy_scan_batch`) when ``use_kernel`` else the unrolled
    static-program evaluator — with masks, first-match-wins attribution
    and per-program size/blocks reductions fused on-device; the
    per-program aggregates then combine across the mesh via ``psum``
    (``pmax`` for the any_match slot). Returns (mask0 (D, Rp) f32 and
    rule_idx (D, Rp) i32, both still sharded along ``"shards"``; agg
    (R, N_AGG) f32, replicated): only the combined-criteria mask and the
    attribution ever leave the devices — the column stack itself is never
    re-uploaded or gathered.

    ``with_agg=False`` takes a leaner unrolled path that skips the fused
    size-profile aggregation and the (R, N) f32 mask materialization
    entirely (returns a bool mask0 and a zero agg) — the policy engine's
    match path, which only consumes mask + attribution.

    ``perm``/``subject`` scope the whole match to one tenant: ``perm`` is
    the store's (D, Sp, W) uint32 permissions plane sharded along
    ``"shards"`` and ``subject`` a traced subject id. Each device unpacks
    its subject bitset row (:func:`_subject_bits`) and ANDs it into every
    program mask *before* attribution and aggregation — masks, rule_idx
    and the psum'd aggregates all come back visibility-filtered, exactly
    as if invisible rows were invalid.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    have_perm = perm is not None

    def _device_scan(cols, operands_, *rest):
        c = cols[0]
        bits = _subject_bits(rest[0][0], rest[1]) if have_perm else None
        if not use_kernel:
            masks_b, rule = _unrolled_masks(c, ops_t, colidx_t, operands_,
                                            valid_col)
            if bits is not None:
                masks_b = [m & bits for m in masks_b]
                rule = jnp.where(bits, rule, jnp.int32(-1))
            if with_agg:
                masks = jnp.stack(masks_b).astype(jnp.float32)
                agg = aggregate_multi(masks, c[size_col], c[blocks_col])
                mask0 = masks[0]
            else:
                agg = jnp.zeros((len(ops_t), N_AGG), jnp.float32)
                mask0 = masks_b[0]
        else:
            masks, rule, agg = policy_scan_batch(
                c, jnp.asarray(np.asarray(ops_t), jnp.int32),
                jnp.asarray(np.asarray(colidx_t), jnp.int32), operands_,
                size_col=size_col, blocks_col=blocks_col,
                valid_col=valid_col, use_kernel=True, tile=tile)
            if bits is not None:
                # the kernel aggregated pre-AND: fold the subject bitset
                # into the masks and recompute the (cheap) reductions
                masks = masks * bits.astype(jnp.float32)
                rule = jnp.where(bits, rule, jnp.int32(-1))
                agg = aggregate_multi(masks, c[size_col], c[blocks_col])
            mask0 = masks[0]
        sums = jax.lax.psum(agg[:, : N_AGG - 1], "shards")
        anym = jax.lax.pmax(agg[:, N_AGG - 1:], "shards")
        return (mask0[None], rule[None],
                jnp.concatenate([sums, anym], axis=1))

    in_specs = (P("shards"), P()) + ((P("shards"), P()) if have_perm
                                     else ())
    args = (global_cols, operands.astype(jnp.float32))
    if have_perm:
        args = args + (perm, jnp.asarray(subject, jnp.int32))
    # check_rep=False: the program-eval scan/argmax trips shard_map's
    # replication checker (jax#mismatched-replication-types); the agg
    # output IS replicated — psum/pmax above combine it across the mesh
    return shard_map(
        _device_scan, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P("shards"), P("shards"), P()),
        check_rep=False,
    )(*args)


# -- mesh report ops (device-store-backed rbh-find / top-N / du) -------------
#
# These consume the same resident (D, n_cols, Rp) global column array as
# mesh_policy_scan_batch; only per-device top-k candidates, a row mask, or
# psum-combined aggregates ever leave the devices.

@partial(jax.jit, static_argnames=("mesh", "col", "k", "desc", "valid_col",
                                   "type_col", "file_code"))
def mesh_column_topk(global_cols: jax.Array, *, mesh, col: int, k: int,
                     desc: bool = True, valid_col: int = -1,
                     type_col: int = -1, file_code: float = 0.0,
                     perm: Optional[jax.Array] = None,
                     subject: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-device top-k over one column, restricted to valid FILE rows.

    Returns ``(vals (D, k) f32, idx (D, k) i32)``, both sharded along
    ``"shards"``: each device's k best (largest when ``desc``) column
    values and their local row indices. Rows failing the valid/type filter
    carry a ∓inf sentinel (callers drop non-finite candidates). The global
    top-k is a subset of the union of per-device top-k's, so the merged
    k-th best candidate value is an exact selection threshold for a
    follow-up :func:`mesh_threshold_rows` pass (which recovers boundary
    ties a per-device truncation could hide). ``perm``/``subject``
    (optional, see :func:`_subject_bits`) AND the subject's visibility
    bitset into the row filter — the scoped top-k ranks only rows the
    tenant may see.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    have_perm = perm is not None

    def _device(cols, *rest):
        c = cols[0]
        sel = c[valid_col] > 0.5
        if type_col >= 0:
            sel = sel & (c[type_col] == file_code)
        if have_perm:
            sel = sel & _subject_bits(rest[0][0], rest[1])
        sentinel = -jnp.inf if desc else jnp.inf
        key = jnp.where(sel, c[col], sentinel)
        vals, idx = jax.lax.top_k(key if desc else -key, k)
        vals = vals if desc else -vals
        return vals[None], idx[None].astype(jnp.int32)

    in_specs = (P("shards"),) + ((P("shards"), P()) if have_perm else ())
    args = (global_cols,) + ((perm, jnp.asarray(subject, jnp.int32))
                             if have_perm else ())
    return shard_map(_device, mesh=mesh, in_specs=in_specs,
                     out_specs=(P("shards"), P("shards")),
                     check_rep=False)(*args)


@partial(jax.jit, static_argnames=("mesh", "col", "ge", "valid_col",
                                   "type_col", "file_code"))
def mesh_threshold_rows(global_cols: jax.Array, thr: jax.Array, *, mesh,
                        col: int, ge: bool = True, valid_col: int = -1,
                        type_col: int = -1, file_code: float = 0.0,
                        perm: Optional[jax.Array] = None,
                        subject: Optional[jax.Array] = None) -> jax.Array:
    """0/1 mask of valid FILE rows whose column value passes ``thr``.

    ``thr`` is a traced f32 scalar (no recompile per threshold). Returns
    the (D, Rp) f32 mask sharded along ``"shards"`` — the winning-row
    selection of the two-pass on-device top-k (see
    :func:`mesh_column_topk`); callers gather only the nonzero rows.
    ``perm``/``subject`` apply the same visibility AND as the top-k pass
    so both passes of a scoped query select from the same row set.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    have_perm = perm is not None

    def _device(cols, t, *rest):
        c = cols[0]
        sel = c[valid_col] > 0.5
        if type_col >= 0:
            sel = sel & (c[type_col] == file_code)
        if have_perm:
            sel = sel & _subject_bits(rest[0][0], rest[1])
        cmp = (c[col] >= t) if ge else (c[col] <= t)
        return (sel & cmp).astype(jnp.float32)[None]

    in_specs = (P("shards"), P()) + ((P("shards"), P()) if have_perm
                                     else ())
    args = (global_cols, jnp.asarray(thr, jnp.float32))
    if have_perm:
        args = args + (perm, jnp.asarray(subject, jnp.int32))
    return shard_map(_device, mesh=mesh, in_specs=in_specs,
                     out_specs=P("shards"), check_rep=False)(*args)


@partial(jax.jit, static_argnames=("mesh", "ord_col", "type_col", "size_col",
                                   "blocks_col", "valid_col", "file_code"))
def mesh_range_aggregate(global_cols: jax.Array, bounds: jax.Array, *, mesh,
                         ord_col: int, type_col: int, size_col: int,
                         blocks_col: int, valid_col: int,
                         file_code: float = 0.0,
                         perm: Optional[jax.Array] = None,
                         subject: Optional[jax.Array] = None) -> jax.Array:
    """Fused subtree aggregate over sorted-path rank ranges, psum-combined.

    ``bounds`` is (D, 4) f32 sharded along ``"shards"``: per device the
    two half-open [lo, hi) ∪ [lo2, hi2) rank ranges (host binary searches
    into that group's sorted path mirror — the device-resident ``ord_col``
    holds each row's rank in that order). Returns the replicated (4,) f32
    ``[count, files, volume, spc_used]`` — ``du`` without any row leaving
    a device. ``perm``/``subject`` AND the subject's visibility bitset
    into the range mask — scoped ``du`` counts only rows the tenant may
    see, still in one fused pass.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    have_perm = perm is not None

    def _device(cols, b, *rest):
        c = cols[0]
        lo, hi, lo2, hi2 = b[0, 0], b[0, 1], b[0, 2], b[0, 3]
        o = c[ord_col]
        m = (c[valid_col] > 0.5) & (((o >= lo) & (o < hi))
                                    | ((o >= lo2) & (o < hi2)))
        if have_perm:
            m = m & _subject_bits(rest[0][0], rest[1])
        f = m & (c[type_col] == file_code)
        parts = jnp.stack([
            m.astype(jnp.float32).sum(),
            f.astype(jnp.float32).sum(),
            jnp.where(f, c[size_col], 0.0).sum(),
            jnp.where(f, c[blocks_col], 0.0).sum()])
        return jax.lax.psum(parts, "shards")

    in_specs = (P("shards"), P("shards")) + ((P("shards"), P())
                                             if have_perm else ())
    args = (global_cols, bounds.astype(jnp.float32))
    if have_perm:
        args = args + (perm, jnp.asarray(subject, jnp.int32))
    return shard_map(_device, mesh=mesh, in_specs=in_specs,
                     out_specs=P(), check_rep=False)(*args)


def column_stack(arrays) -> jax.Array:
    """Stack a Catalog.arrays() dict into the (n_cols, N) f32 kernel layout."""
    from ...core.policy import KERNEL_COLUMNS
    return jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                      for c in KERNEL_COLUMNS], axis=0)


def _attribute_np(masks: List[np.ndarray]) -> np.ndarray:
    """Host-side first-match-wins attribution (per-rule-launch fallback):
    ``masks[0]`` is the combined criteria (excluded), ``masks[1:]`` the
    rules. Delegates to the single semantics authority in core.policy."""
    from ...core.policy import attribute_rules
    n = masks[0].shape[0] if masks else 0
    return attribute_rules(masks[1:], n)


def merge_agg_partials(parts: List[np.ndarray],
                       n_programs: int) -> np.ndarray:
    """Combine per-launch (R, N_AGG) aggregate blocks from a streamed /
    tiered match into one exact (R, N_AGG) float64 block: the additive
    slots sum and the trailing ``any_match`` slot takes the max — the
    host-side analogue of the in-launch psum/pmax combine (each partial
    is integer-valued and f32-exact, so the float64 sum is exact)."""
    out = np.zeros((n_programs, N_AGG), np.float64)
    for p in parts:
        p = np.asarray(p, np.float64)
        out[:, : N_AGG - 1] += p[:, : N_AGG - 1]
        np.maximum(out[:, N_AGG - 1], p[:, N_AGG - 1],
                   out=out[:, N_AGG - 1])
    return out


def _agg_dict(agg_np: np.ndarray, per_rule: Optional[np.ndarray] = None
              ) -> dict:
    out = {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
    if per_rule is not None and per_rule.shape[0] > 1:
        out["rule_count"] = per_rule[1:, 0].tolist()
        out["rule_volume"] = per_rule[1:, 1].tolist()
        out["rule_spc_used"] = per_rule[1:, 2].tolist()
    return out


def match_programs(arrays, exprs, strings, now: float,
                   use_kernel: Optional[bool] = None,
                   single_launch: Optional[bool] = None
                   ) -> Tuple[List[np.ndarray], dict, np.ndarray]:
    """Evaluate several core.policy Exprs over catalog columns at once.

    ``exprs[0]`` is the combined match criteria (its fused aggregates are
    returned); further exprs are per-rule conditions in priority order.
    Returns ``(masks, agg, rule_idx)``: one boolean mask per program, the
    aggregate dict of program 0 (plus ``rule_count``/``rule_volume``/
    ``rule_spc_used`` per-rule reductions when rules are present), and the
    (N,) int32 first-match-wins rule attribution (-1 = no rule).

    ``use_kernel=None`` selects the Pallas kernel on TPU and the jitted
    oracle everywhere else. ``single_launch`` (default True) evaluates the
    whole (R, P) program batch in ONE launch with attribution and per-rule
    reductions fused on-device; ``single_launch=False`` keeps the legacy
    one-launch-per-program path as a fallback and differential oracle.
    Raises PolicyError if any expr contains host-only (glob) predicates —
    callers fall back to the numpy mask path.
    """
    from ...core.policy import KERNEL_COLUMNS, compile_programs
    from ...core.telemetry import span as _tspan
    with _tspan("kernel.compile"):
        ops, colidx, operands = compile_programs(exprs, strings, now)
        kcols = column_stack(arrays)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    if use_kernel is None:
        use_kernel = _on_tpu()
    if single_launch is None:
        single_launch = True
    if single_launch:
        # the launch span times the async dispatch only; the device wait
        # lands in kernel.readback where the host actually blocks
        with _tspan("kernel.launch", programs=int(ops.shape[0])):
            if use_kernel:
                m, rule, agg = policy_scan_batch(
                    kcols, jnp.asarray(ops), jnp.asarray(colidx),
                    jnp.asarray(operands), size_col=size_col,
                    blocks_col=blocks_col, use_kernel=True)
            else:
                # off-TPU oracle: the unrolled static-program evaluator
                # (same outputs, ~an order of magnitude less memory
                # traffic)
                ops_t, colidx_t = _program_tuples(ops, colidx)
                m, rule, agg = policy_scan_batch_unrolled(
                    kcols, jnp.asarray(operands), ops_t=ops_t,
                    colidx_t=colidx_t, size_col=size_col,
                    blocks_col=blocks_col)
        with _tspan("kernel.readback"):
            m = np.asarray(m) > 0.5
            masks = [m[r] for r in range(m.shape[0])]
            per_rule = np.asarray(agg)
            rule = np.asarray(rule, dtype=np.int32)
        return masks, _agg_dict(per_rule[0], per_rule), rule
    # Fallback: one launch per program (program 0 still fuses mask +
    # aggregation in a single HBM pass; rule programs reuse the resident
    # column stack), attribution on the host.
    masks, aggs = [], []
    for r in range(ops.shape[0]):
        m, a = policy_scan(kcols, jnp.asarray(ops[r]),
                           jnp.asarray(colidx[r]),
                           jnp.asarray(operands[r]), size_col=size_col,
                           blocks_col=blocks_col, use_kernel=use_kernel)
        aggs.append(np.asarray(a))
        masks.append(np.asarray(m) > 0.5)
    per_rule = np.stack(aggs)
    return masks, _agg_dict(per_rule[0], per_rule), _attribute_np(masks)


def match_programs_mesh(store, exprs, now: float,
                        use_kernel: Optional[bool] = None):
    """Mesh-parallel sibling of :func:`match_programs`: evaluate the (R, P)
    program batch over a :class:`~repro.core.device_store.DeviceColumnStore`
    instead of a freshly uploaded column stack.

    The store refreshes stale shard groups by delta scatter (or full
    re-upload), launches :func:`mesh_policy_scan_batch` over the resident
    (D, n_cols, Rp) global array, and pulls back only the program-0 mask
    and the rule attribution. Returns a ``MeshMatch`` (see device_store):
    ``.plan(sort_by)`` yields the matched (fids, sizes, sort_keys,
    rule_idx) arrays and ``.agg`` the fused aggregate dict — same
    semantics as :func:`match_programs`, differential-tested equal.
    Raises PolicyError on host-only (glob) predicates.
    """
    return store.match(exprs, now, use_kernel=use_kernel)


def scan_catalog(catalog, expr, now: float, use_kernel: bool = True,
                 store=None) -> Tuple[np.ndarray, dict]:
    """Run a core.policy expression over a Catalog via the kernel path.

    Only numeric/categorical predicates compile to the kernel program;
    glob predicates raise PolicyError (callers fall back to Expr.mask).
    Returns (matching fids, aggregate dict). When ``store`` (a
    :class:`~repro.core.device_store.DeviceColumnStore` over the same
    catalog) is given, the scan runs mesh-parallel over the device-resident
    column stacks — no host-side concat, no host→device re-upload.
    """
    if store is not None:
        if store.catalog is not catalog:
            from ...core.policy import PolicyError
            raise PolicyError("device store wraps a different catalog "
                              "than the one passed to scan_catalog")
        match = store.match([expr], now, use_kernel=use_kernel)
        fids, _sizes, _sort, _ridx = match.plan("size")
        return fids, match.agg
    from ...core.policy import KERNEL_COLUMNS, compile_program
    from ...core.telemetry import span as _tspan
    with _tspan("kernel.compile"):
        arrays = catalog.arrays()
        ops, colidx, operands = compile_program(expr, catalog.strings, now)
        cols = jnp.stack([jnp.asarray(arrays[c], jnp.float32)
                          for c in KERNEL_COLUMNS], axis=0)
    size_col = KERNEL_COLUMNS.index("size")
    blocks_col = KERNEL_COLUMNS.index("blocks")
    with _tspan("kernel.launch"):
        mask, agg = policy_scan(cols, jnp.asarray(ops),
                                jnp.asarray(colidx),
                                jnp.asarray(operands), size_col=size_col,
                                blocks_col=blocks_col,
                                use_kernel=use_kernel)
    with _tspan("kernel.readback"):
        mask_np = np.asarray(mask) > 0.5
        agg_np = np.asarray(agg)
    return arrays["fid"][mask_np], {
        "count": float(agg_np[0]), "volume": float(agg_np[1]),
        "spc_used": float(agg_np[2]),
        "size_profile": agg_np[3:13].tolist(),
        "any_match": bool(agg_np[13] > 0.5),
    }
