"""Deterministic, resumable, sharded token pipeline.

Properties a 1000-node run needs, all tested:

* **determinism**: batch(step, shard) is a pure function of (seed, step,
  shard) — any host can recompute any shard's batch (this is also what
  makes redundant-shard straggler mitigation sound, runtime/fault.py);
* **resumability**: the pipeline state is one integer (next step); restart
  from a checkpoint replays the exact token stream;
* **sharding**: host h draws only its shard of the global batch.

The synthetic stream is a seeded Markov-ish token generator; swap
``_tokens_for`` for a tokenized-corpus reader in production.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineState:
    next_step: int = 0


class DataPipeline:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 n_shards: int = 1, seed: int = 0) -> None:
        assert global_batch % n_shards == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.n_shards = n_shards
        self.seed = seed
        self.state = PipelineState()

    # -- pure batch function -------------------------------------------------
    def _tokens_for(self, step: int, shard: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        b = self.global_batch // self.n_shards
        base = rng.integers(0, self.vocab, (b, self.seq_len), dtype=np.int32)
        # inject local structure so models can actually learn: token t+1
        # correlates with token t half the time
        shift = np.roll(base, 1, axis=1)
        mask = rng.random((b, self.seq_len)) < 0.5
        return np.where(mask, (shift + 1) % self.vocab, base)

    def batch_for(self, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
        toks = self._tokens_for(step, shard)
        labels = np.concatenate(
            [toks[:, 1:], np.full((toks.shape[0], 1), -100, np.int32)],
            axis=1)
        return {"tokens": toks, "labels": labels}

    # -- stateful iteration (resumable) ----------------------------------------
    def next_batch(self, shard: int = 0) -> Dict[str, np.ndarray]:
        b = self.batch_for(self.state.next_step, shard)
        self.state.next_step += 1
        return b

    def checkpoint(self) -> dict:
        return {"next_step": self.state.next_step, "seed": self.seed}

    def restore(self, snap: dict) -> None:
        assert snap["seed"] == self.seed, "seed mismatch on restore"
        self.state.next_step = snap["next_step"]
