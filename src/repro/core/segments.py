"""Compact packed host segments — the warm tier of the catalog.

A :class:`PackedSegment` holds one demoted shard group's column stack in
host memory (or mmap-backed on disk) at a fraction of the resident
footprint, with **exact** round-trip decode:

- integer columns are dict-encoded (sorted unique values + minimal-width
  codes) when the value set is small — owner/group/type/hsm codes
  compress to one byte per row — otherwise delta+zigzag encoded at the
  minimal byte width (fids and ranks are near-sequential, so deltas are
  tiny);
- float columns (atime/mtime/size as staged) are stored raw in their
  native dtype — bit-exact, no quantization;
- unicode columns (path mirrors) are stored raw fixed-width: 4 B/char is
  not the tightest packing, but the array memory-maps straight off disk
  and binary-searches (``np.searchsorted``) without a decode pass, which
  is what the du/subtree rank-range queries need;
- bool columns are stored as raw uint8.

``save(path)`` persists the encoded arrays as an **uncompressed** ``.npz``
beside the sqlite mirror; ``load(path, mmap=True)`` maps them back in so
a demoted segment costs no RSS until it is streamed. ``decode(name)``
returns the exact original array (values *and* dtype); ``columns()``
caches decoded arrays until ``release()``.
"""
from __future__ import annotations

import json
import struct
import threading
import zipfile
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from .telemetry import ambient_counter, span as _tspan

_FORMAT = "repro-segment-v1"

# dict-encode when the unique count is small enough that codes+values
# beat delta encoding; 2**16-1 keeps codes at most uint16
_DICT_MAX_UNIQUE = (1 << 16) - 1


def _min_uint(max_value: int) -> np.dtype:
    """Smallest unsigned dtype that holds ``max_value``."""
    for dt in (np.uint8, np.uint16, np.uint32):
        if max_value <= np.iinfo(dt).max:
            return np.dtype(dt)
    return np.dtype(np.uint64)


def _zigzag(a: np.ndarray) -> np.ndarray:
    """int64 -> uint64 zigzag (small negatives stay small)."""
    a = a.astype(np.int64, copy=False)
    return ((a << 1) ^ (a >> 63)).view(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.uint64, copy=False)
    return ((z >> np.uint64(1)).view(np.int64)
            ^ -(z & np.uint64(1)).view(np.int64))


class PackedSegment:
    """Encoded column stack for one demoted shard group.

    Build with :meth:`pack`; read back with :meth:`decode` /
    :meth:`columns`. Instances are immutable after ``pack`` apart from
    the decode cache; ``meta`` carries caller bookkeeping (catalog
    versions, row count) through save/load untouched.
    """

    def __init__(self) -> None:
        self._enc: Dict[str, Dict[str, object]] = {}   # name -> scheme
        self._arrays: Dict[str, np.ndarray] = {}       # storage arrays
        self._cache: Dict[str, np.ndarray] = {}
        self._lock = threading.Lock()
        self.n_rows: int = 0
        self.meta: Dict[str, object] = {}

    # -- encode ----------------------------------------------------------

    @classmethod
    def pack(cls, columns: Mapping[str, np.ndarray],
             meta: Optional[Mapping[str, object]] = None) -> "PackedSegment":
        with _tspan("segment.pack", columns=len(columns)) as _sp:
            seg = cls._pack(columns, meta)
            _sp.annotate(rows=seg.n_rows, encoded_bytes=seg.nbytes)
            return seg

    @classmethod
    def _pack(cls, columns: Mapping[str, np.ndarray],
              meta: Optional[Mapping[str, object]] = None
              ) -> "PackedSegment":
        seg = cls()
        seg.meta = dict(meta or {})
        n_rows = None
        for name, arr in columns.items():
            arr = np.ascontiguousarray(arr)
            if arr.ndim != 1:
                raise ValueError(f"column {name!r} must be 1-D")
            if n_rows is None:
                n_rows = arr.shape[0]
            elif arr.shape[0] != n_rows:
                raise ValueError(
                    f"column {name!r} has {arr.shape[0]} rows, "
                    f"expected {n_rows}")
            kind = arr.dtype.kind
            if kind in "iu":
                seg._pack_int(name, arr)
            elif kind == "f":
                seg._store(name, "raw", arr.dtype, arr)
            elif kind in "US":
                seg._store(name, "raw", arr.dtype, arr)
            elif kind == "b":
                seg._store(name, "bool", arr.dtype, arr.view(np.uint8))
            else:
                raise TypeError(
                    f"column {name!r}: unsupported dtype {arr.dtype}")
        seg.n_rows = int(n_rows or 0)
        return seg

    def _store(self, name: str, enc: str, dtype: np.dtype,
               *arrays: np.ndarray) -> None:
        self._enc[name] = {"enc": enc, "dtype": np.dtype(dtype).str}
        for i, a in enumerate(arrays):
            self._arrays[f"{name}.{i}"] = a

    def _pack_int(self, name: str, arr: np.ndarray) -> None:
        a = arr.astype(np.int64, copy=False)
        uniq = np.unique(a)
        # dict-encode when codes+values beat the delta stream; always for
        # tiny value sets (owner/group/type/hsm), never past uint16 codes
        if uniq.size <= min(_DICT_MAX_UNIQUE, max(16, a.size // 4)):
            codes = np.searchsorted(uniq, a).astype(
                _min_uint(max(int(uniq.size) - 1, 0)))
            self._store(name, "dict", arr.dtype, codes, uniq)
        else:
            delta = np.diff(a, prepend=np.int64(0))
            z = _zigzag(delta)
            width = _min_uint(int(z.max()) if z.size else 0)
            self._store(name, "delta", arr.dtype, z.astype(width))

    # -- decode ----------------------------------------------------------

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._enc)

    def decode(self, name: str) -> np.ndarray:
        """Exact original array for ``name`` (values and dtype)."""
        with self._lock:
            out = self._cache.get(name)
            if out is None:
                with _tspan("segment.decode", column=name):
                    out = self._decode(name)
                ambient_counter("segment_bytes_decoded", out.nbytes)
                self._cache[name] = out
            return out

    def _decode(self, name: str) -> np.ndarray:
        scheme = self._enc[name]
        enc, dtype = scheme["enc"], np.dtype(scheme["dtype"])  # type: ignore
        if enc == "raw":
            return np.asarray(self._arrays[f"{name}.0"])
        if enc == "bool":
            return np.asarray(self._arrays[f"{name}.0"]).view(np.bool_)
        if enc == "dict":
            codes = np.asarray(self._arrays[f"{name}.0"])
            values = np.asarray(self._arrays[f"{name}.1"])
            return values[codes].astype(dtype, copy=False)
        if enc == "delta":
            z = np.asarray(self._arrays[f"{name}.0"])
            return np.cumsum(_unzigzag(z)).astype(dtype, copy=False)
        raise ValueError(f"unknown encoding {enc!r} for column {name!r}")

    def columns(self) -> Dict[str, np.ndarray]:
        """Decode every column (cached until :meth:`release`)."""
        return {name: self.decode(name) for name in self._enc}

    def release(self) -> None:
        """Drop the decode cache (the encoded arrays stay)."""
        with self._lock:
            self._cache.clear()

    @property
    def nbytes(self) -> int:
        """Encoded size — what the warm tier actually holds."""
        return int(sum(a.nbytes for a in self._arrays.values()))

    @property
    def decoded_nbytes(self) -> int:
        """Size of the fully decoded stack (the demote savings baseline)."""
        total = 0
        for name in self._enc:
            scheme = self._enc[name]
            if scheme["enc"] in ("raw", "bool"):
                total += int(np.asarray(self._arrays[f"{name}.0"]).nbytes)
            else:
                total += self.n_rows * np.dtype(scheme["dtype"]).itemsize
        return total

    # -- persistence -----------------------------------------------------

    def save(self, path: str) -> None:
        """Write an uncompressed ``.npz`` (arrays mmap back in)."""
        header = json.dumps({
            "format": _FORMAT, "n_rows": self.n_rows,
            "meta": self.meta, "enc": self._enc,
        })
        arrays = {k.replace(".", "__"): v for k, v in self._arrays.items()}
        np.savez(path, __header=np.asarray(header), **arrays)

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "PackedSegment":
        """Read a segment back; with ``mmap`` the storage arrays are
        memory-mapped straight out of the (stored-uncompressed) zip
        members, so loading costs no RSS until a column is streamed.
        ``np.load`` reads npz members through zipfile streams even with
        ``mmap_mode`` set, hence the explicit offset mapping here."""
        arrays = (_mmap_npz(path) if mmap
                  else dict(np.load(path, allow_pickle=False)))
        header = json.loads(str(np.asarray(arrays.pop("__header"))[()]))
        if header.get("format") != _FORMAT:
            raise ValueError(f"{path}: not a {_FORMAT} file")
        seg = cls()
        seg.n_rows = int(header["n_rows"])
        seg.meta = dict(header["meta"])
        seg._enc = {k: dict(v) for k, v in header["enc"].items()}
        for name in seg._enc:
            i = 0
            while f"{name}__{i}" in arrays:
                seg._arrays[f"{name}.{i}"] = arrays[f"{name}__{i}"]
                i += 1
        return seg


def _mmap_npz(path: str) -> Dict[str, np.ndarray]:
    """Memory-map every member of an uncompressed ``.npz``.

    ``np.savez`` stores members with ``ZIP_STORED``, so each ``.npy``
    payload sits contiguous in the file: seek past the member's local
    header, parse the npy header for dtype/shape, and ``np.memmap`` the
    data span read-only. Falls back to a regular read for any member
    that is compressed or non-contiguous (fortran order)."""
    out: Dict[str, np.ndarray] = {}
    with zipfile.ZipFile(path) as zf:
        for info in zf.infolist():
            name = info.filename[:-4] if info.filename.endswith(".npy") \
                else info.filename
            if info.compress_type != zipfile.ZIP_STORED:
                out[name] = np.load(zf.open(info.filename))  # pragma: no cover
                continue
            with open(path, "rb") as f:
                f.seek(info.header_offset)
                lh = f.read(30)                    # local file header
                n_name, n_extra = struct.unpack("<HH", lh[26:30])
                data_off = info.header_offset + 30 + n_name + n_extra
                f.seek(data_off)
                version = np.lib.format.read_magic(f)
                shape, fortran, dtype = \
                    np.lib.format._read_array_header(f, version)
                if fortran:                        # pragma: no cover
                    out[name] = np.load(zf.open(info.filename))
                    continue
                out[name] = np.memmap(path, mode="r", dtype=dtype,
                                      shape=shape, offset=f.tell())
    return out
