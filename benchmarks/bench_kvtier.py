"""Adapted C7/C8: KV-page tiering throughput + policy overhead + the
serving engine with pages migrating under load."""
from __future__ import annotations

import time

import numpy as np

from repro.kvcache import PagePool, TieredKvCache
from repro.serve.engine import PagedLMConfig, Request, ServingEngine


def run(smoke: bool = False) -> list:
    rows = []
    # raw append throughput, ample pool (no pressure)
    pool = PagePool(n_pages=512, page_size=16, n_kv=4, head_dim=32)
    tc = TieredKvCache(pool)
    tc.admit(1)
    k = np.ones((4, 32), np.float32)
    n = 1000 if smoke else 4000
    t0 = time.perf_counter()
    for t in range(n):
        tc.append_token(1, k, k)
    dt = time.perf_counter() - t0
    rows.append(("kv_append_no_pressure", 1e6 * dt / n,
                 f"{n/dt:.0f}_tokens_per_s"))
    tc.finish(1)

    # under pressure: pool sized at 40% of working set -> constant tiering
    pool = PagePool(n_pages=100, page_size=16, n_kv=4, head_dim=32)
    tc = TieredKvCache(pool, high_wm=80.0, low_wm=50.0)
    for s in range(4):
        tc.admit(s)
    t0 = time.perf_counter()
    for t in range(n):
        tc.append_token(t % 4, k, k)
        if t % 64 == 0:
            tc.maybe_run_policies()
    dt = time.perf_counter() - t0
    rep = tc.tier_report()
    rows.append(("kv_append_with_tiering", 1e6 * dt / n,
                 f"{n/dt:.0f}_tokens_per_s_cold_{rep['cold_pages']}"
                 f"_restores_{rep['restores']}"))

    # end-to-end serving with migration underneath
    cfg = PagedLMConfig(n_pages=24, page_size=8, n_layers=2,
                        high_wm=75.0, low_wm=40.0)
    eng = ServingEngine(cfg)
    reqs = [Request(req_id=i, prompt=list(range(1, 9)), max_new=12)
            for i in range(4)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.prompt) + len(r.generated) for r in reqs)
    rows.append(("paged_serving_engine", 1e6 * dt / toks,
                 f"{toks/dt:.1f}_tokens_per_s_interpret_kernel"))
    return rows
