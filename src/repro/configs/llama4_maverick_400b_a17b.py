"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + shared expert, dense/MoE
interleave (period 2, Maverick-style). Early-fusion multimodal frontend is
a stub per the assignment — text backbone only. [hf: meta-llama/Llama-4-*]
"""
from repro.models.config import (ATTN_FULL, FFN_DENSE, FFN_MOE, LayerSpec,
                                 ModelConfig, MoeSpec)

_PATTERN = (LayerSpec(mix=ATTN_FULL, ffn=FFN_DENSE),
            LayerSpec(mix=ATTN_FULL, ffn=FFN_MOE))

CONFIG = ModelConfig(
    name="llama4_maverick_400b_a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, head_dim=128,
    d_ff=8192, vocab=202048,
    pattern=_PATTERN, rope_theta=5e5,
    moe=MoeSpec(num_experts=128, top_k=1, shared_expert=True),
)

SMOKE = ModelConfig(
    name="llama4_maverick_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN,
    moe=MoeSpec(num_experts=8, top_k=1, shared_expert=True),
)
