"""Public paged-attention op (kernel on TPU / interpret elsewhere)."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_attention as _kernel
from .ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def paged_attention(q, k_pages, v_pages, page_table, lengths,
                    use_kernel: bool = True):
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, page_table, lengths)
    return _kernel(q, k_pages, v_pages, page_table, lengths,
                   interpret=not _on_tpu())
