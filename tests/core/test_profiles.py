"""Profile-cube analytics subsystem: differential + property suites.

The scalar ``StatsAggregator`` dict fold is the oracle: the cube —
maintained incrementally via signed bucket updates, rebuilt from shard
snapshots on the host, or rebuilt through the Pallas kernel (interpret
mode off-TPU) — must produce byte-identical report dicts, across catalog
churn and age-bucket rollover instants.
"""
import numpy as np
import pytest

from repro.core import (AGE_PROFILE_EDGES, Catalog, Entry, FsType, HsmState,
                        ProfileCube, Reports, StatsAggregator,
                        age_profile_bucket)

NOW = 1_700_000_000.0

# f32-exact sizes: small ints plus exact powers of two for the top buckets
# (the kernel path sums in f32; the host paths are int64 end-to-end)
SIZES = [0, 1, 31, 100, 2048, 50 << 10, 1 << 20, 1 << 25, 1 << 30,
         1 << 35, 1 << 41]
OWNERS = [f"u{i}" for i in range(6)]
GROUPS = [f"g{i}" for i in range(3)]


class _Clock:
    def __init__(self, t=NOW):
        self.t = t

    def __call__(self):
        return self.t


def _rand_entry(rng, fid):
    return Entry(
        fid=fid, name=f"f{fid}", path=f"/d{fid % 5}/f{fid}",
        type=FsType(int(rng.integers(0, 3))),
        size=int(rng.choice(SIZES)), blocks=int(rng.integers(0, 4096)),
        owner=str(rng.choice(OWNERS)), group=str(rng.choice(GROUPS)),
        hsm_state=HsmState(int(rng.integers(0, 5))),
        atime=NOW - float(rng.uniform(-10, 400 * 86400)))


def _build(seed, n=600, n_shards=3, churn=0.2):
    rng = np.random.default_rng(seed)
    clock = _Clock()
    cat = Catalog(n_shards=n_shards)
    scalar = StatsAggregator(cat.strings)
    cat.add_delta_hook(scalar.on_delta)
    cube = ProfileCube(cat, clock=clock).attach()   # incremental from empty
    for i in range(n):
        cat.upsert(_rand_entry(rng, i + 1))
    for fid in (rng.choice(n, int(n * churn), replace=False) + 1).tolist():
        if fid % 3 == 0:
            cat.remove(fid)
        else:
            cat.update_fields(fid, size=int(rng.choice(SIZES)),
                              atime=NOW - float(rng.uniform(0, 100 * 86400)))
    return cat, scalar, cube, clock


def _assert_reports_equal(a, b):
    """Byte-identical report dicts across every rbh-report surface."""
    for u in OWNERS:
        assert a.report_user(u) == b.report_user(u)
        assert a.user_size_profile(u) == b.user_size_profile(u)
    for g in GROUPS:
        assert a.report_group(g) == b.report_group(g)
    assert a.report_types() == b.report_types()
    assert a.report_hsm() == b.report_hsm()
    top_a = {(d["user"], d["count"], d["volume"], d["spc_used"])
             for d in a.top_users(k=100)}
    top_b = {(d["user"], d["count"], d["volume"], d["spc_used"])
             for d in b.top_users(k=100)}
    assert top_a == top_b


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_cube_matches_scalar_oracle(seed):
    _cat, scalar, cube, _clock = _build(seed)
    _assert_reports_equal(cube, scalar)
    assert cube.totals()[0] == scalar.total.count


@pytest.mark.parametrize("seed", [0, 3])
def test_host_rebuild_and_kernel_rebuild_match_incremental(seed):
    cat, scalar, cube, clock = _build(seed)
    host = ProfileCube(cat, clock=clock)
    host.rebuild(use_kernel=False)
    _assert_reports_equal(host, scalar)
    kern = ProfileCube(cat, clock=clock)
    kern.rebuild(use_kernel=True)       # Pallas interpret mode off-TPU
    _assert_reports_equal(kern, scalar)
    _assert_reports_equal(kern, cube)


def test_age_rollover_matches_fresh_rebuild():
    """Advancing the clock moves entries across age buckets with no delta
    arriving — the rollover schedule must agree with a from-scratch
    rebuild at the same instant, including exact boundary times."""
    cat, _scalar, cube, clock = _build(4, n=300)
    base = cube.age_profile(now=clock.t)
    for dt in (3600.0, 86400.0, 7 * 86400.0 + 1, 300 * 86400.0):
        at = NOW + dt
        fresh = ProfileCube(cat, clock=_Clock(at))
        fresh.rebuild(use_kernel=False)
        assert cube.age_profile(now=at) == fresh.age_profile(now=at)
    assert cube.rollovers > 0
    # volumes conserved across rollovers, only re-bucketed
    end = cube.age_profile(now=NOW + 300 * 86400.0)
    assert sum(d["volume"] for d in end.values()) == \
        sum(d["volume"] for d in base.values())


def test_statsaggregator_rebuilt_on_cube():
    """StatsAggregator(cube=...) serves every report from the cube."""
    clock = _Clock()
    cat = Catalog(n_shards=2)
    oracle = StatsAggregator(cat.strings)
    cat.add_delta_hook(oracle.on_delta)
    cube = ProfileCube(cat, clock=clock)
    cube_stats = StatsAggregator(cat.strings, cube=cube)
    cat.add_delta_hook(cube_stats.on_delta)
    rng = np.random.default_rng(5)
    for i in range(200):
        cat.upsert(_rand_entry(rng, i + 1))
    cat.remove(7)
    _assert_reports_equal(cube_stats, oracle)
    assert cube_stats.total.count == oracle.total.count
    assert cube_stats.total.volume == oracle.total.volume
    rep = Reports(cat, stats=None, profiles=cube, clock=clock)
    assert rep.report_user("u1") == oracle.report_user("u1")
    assert "u1" in rep.format_user_report("u1")
    assert sum(d["count"] for d in rep.age_profile().values()) == \
        oracle.total.count


def test_persistence_and_trend_roundtrip(tmp_path):
    cat, scalar, cube, clock = _build(6, n=250)
    path = str(tmp_path / "cat.db.profiles.npz")
    cube.save(path)
    restored = ProfileCube(cat, clock=clock).attach(resume=True, path=path)
    _assert_reports_equal(restored, scalar)
    # restored state keeps rolling over and folding deltas
    cat.update_fields(11, size=1 << 20, atime=NOW)
    later = NOW + 40 * 86400.0
    fresh = ProfileCube(cat, clock=_Clock(later))
    fresh.rebuild(use_kernel=False)
    assert restored.age_profile(now=later) == fresh.age_profile(now=later)
    # trend snapshots append
    tpath = str(tmp_path / "trend.npz")
    cube.record_trend(tpath, now=NOW)
    cube.record_trend(tpath, now=NOW + 60.0)
    series = ProfileCube.load_trend(tpath)
    assert series["time"].shape == (2,)
    assert int(series["count"][0]) == cube.totals()[0]
    assert series["age_volume"].shape[1] == len(AGE_PROFILE_EDGES)
    # missing file / shard mismatch -> clean False
    assert not ProfileCube(cat, clock=clock).load(str(tmp_path / "no.npz"))
    other = Catalog(n_shards=4)
    assert not ProfileCube(other, clock=clock).load(path)


def test_kernel_rebuild_with_skewed_shard_group_distribution():
    """A shard whose rows use fewer groups than the global index must
    still accept the globally-wide kernel cube (regression: broadcast
    error on skewed owner distributions)."""
    clock = _Clock()
    cat = Catalog(n_shards=2)
    oracle = StatsAggregator(cat.strings)
    cat.add_delta_hook(oracle.on_delta)
    # shard 0 (even fids) sees 20 owners; shard 1 (odd fids) only one
    for i in range(40):
        fid = 2 * i + 2
        cat.upsert(Entry(fid=fid, name=f"e{fid}", path=f"/e{fid}",
                         type=FsType.FILE, size=1000, blocks=1,
                         owner=f"u{i % 20}", atime=NOW - 50))
    cat.upsert(Entry(fid=1, name="o", path="/o", type=FsType.FILE,
                     size=2000, blocks=2, owner="u0", atime=NOW - 50))
    cube = ProfileCube(cat, clock=clock, use_kernel=True)
    cube.rebuild()
    assert cube.report_user("u0") == oracle.report_user("u0")
    assert cube.totals()[0] == oracle.total.count


def test_kernel_rebuild_exact_at_bucket_boundaries():
    """Sizes/ages that f32 would round across a bucket edge (e.g.
    (1<<30)-1 -> 2**30) must land in the host-computed bucket: the kernel
    receives precomputed bucket-index columns from ProfileCube."""
    clock = _Clock()
    cat = Catalog(n_shards=2)
    oracle = StatsAggregator(cat.strings)
    cat.add_delta_hook(oracle.on_delta)
    boundary_sizes = [(1 << 30) - 1, (1 << 20) - 1, (32 << 20) - 1,
                      (1 << 40) - 1]
    year = 365 * 86400.0
    for i, size in enumerate(boundary_sizes):
        # one entry per owner -> one row per cube cell -> f32 sums exact
        cat.upsert(Entry(fid=i + 1, name=f"b{i}", path=f"/b{i}",
                         type=FsType.FILE, size=size, blocks=1,
                         owner=f"edge{i}", atime=NOW - (year - 1.0)))
    kern = ProfileCube(cat, clock=clock, use_kernel=True)
    kern.rebuild()
    for i in range(len(boundary_sizes)):
        u = f"edge{i}"
        # bucket placement and counts are exact (volume sums remain f32 —
        # the kernel's documented precision envelope)
        assert kern.user_size_profile(u) == oracle.user_size_profile(u), u
        ks = [(d["count"], d["spc_used"], d["type"])
              for d in kern.report_user(u)]
        os_ = [(d["count"], d["spc_used"], d["type"])
               for d in oracle.report_user(u)]
        assert ks == os_, u
    # all ages sit just under the 1-year edge: none may round into "+1y"
    assert kern.age_profile()["+1y"]["count"] == 0
    # and the cube stays consistent with its own tables across churn
    cat.add_delta_hook(kern.on_delta)
    cat.remove(1)
    assert kern.report_user("edge0") == oracle.report_user("edge0") == []
    assert (kern.cube()[0] >= 0).all()


def test_single_delta_feed_guard():
    """attach() and StatsAggregator(cube=...) are mutually exclusive —
    both would fold every mutation twice."""
    cat = Catalog(n_shards=2)
    cube = ProfileCube(cat, clock=_Clock()).attach()
    with pytest.raises(ValueError):
        StatsAggregator(cat.strings, cube=cube)
    with pytest.raises(ValueError):
        cube.attach()
    cube2 = ProfileCube(cat, clock=_Clock())
    StatsAggregator(cat.strings, cube=cube2)
    with pytest.raises(ValueError):
        cube2.attach()


def test_fidtable_duplicate_fids_and_gather():
    """Duplicate fids in one upsert_many share one row (last write wins),
    matching the dict-based table this replaced."""
    from repro.core import FidTable
    t = FidTable((("v", np.float64),))
    t.upsert_many([5, 5, 9], v=np.array([1.0, 2.0, 3.0]))
    assert len(t) == 2
    fids, cols = t.live()
    assert sorted(fids.tolist()) == [5, 9]
    assert dict(zip(fids.tolist(), cols["v"].tolist())) == {5: 2.0, 9: 3.0}
    # bulk base + overlay lookups agree; removal + re-add reuses cleanly
    t.bulk_load(np.array([1, 2, 3]), v=np.array([0.1, 0.2, 0.3]))
    t.remove_many([2])
    t.upsert_many([2, 4, 4], v=np.array([9.0, 7.0, 8.0]))
    present, cols = t.gather([1, 2, 4, 99])
    assert present.tolist() == [True, True, True, False]
    assert cols["v"].tolist() == [0.1, 9.0, 8.0, 0.0]
    assert len(t) == 4
    assert sorted(t.select_le("v", 0.3).tolist()) == [1, 3]


def test_age_bucket_scalar_vector_parity():
    from repro.core.profiles import age_buckets_np, size_buckets_np
    from repro.core.types import size_profile_bucket
    ages = np.array([-5.0, 0.0, 1.0, 3600.0, 3599.9, 86400.0,
                     365 * 86400.0, 4e9])
    assert age_buckets_np(ages).tolist() == \
        [age_profile_bucket(a) for a in ages.tolist()]
    sizes = np.array(SIZES + [5, 1 << 42], dtype=np.int64)
    assert size_buckets_np(sizes).tolist() == \
        [size_profile_bucket(int(s)) for s in sizes.tolist()]


# ---------------------------------------------------------------------------
# property: incremental signed-delta maintenance == full recompute across
# random mutation sequences, including age-bucket rollover instants
# ---------------------------------------------------------------------------

def _run_mutation_sequence(ops):
    clock = _Clock()
    cat = Catalog(n_shards=2)
    scalar = StatsAggregator(cat.strings)
    cat.add_delta_hook(scalar.on_delta)
    cube = ProfileCube(cat, clock=clock).attach()
    live = set()
    for kind, fseed, sizei, dt in ops:
        fid = 100 + fseed
        if kind == "ins" or (kind in ("upd", "del") and not live):
            live.add(fid)
            cat.upsert(Entry(fid=fid, name=f"f{fid}", path=f"/p/f{fid}",
                             type=FsType(fid % 3), size=SIZES[sizei],
                             blocks=SIZES[sizei],
                             owner=OWNERS[fid % len(OWNERS)],
                             group=GROUPS[fid % len(GROUPS)],
                             atime=clock.t - dt))
        elif kind == "upd":
            fid = sorted(live)[fseed % len(live)]
            cat.update_fields(fid, size=SIZES[sizei], atime=clock.t - dt)
        elif kind == "del":
            fid = sorted(live)[fseed % len(live)]
            live.discard(fid)
            cat.remove(fid)
        elif kind == "tick":
            clock.t += dt
        else:  # "edge": jump to an exact rollover boundary of a live entry
            if live:
                fid = sorted(live)[fseed % len(live)]
                e = cat.get(fid)
                if e is not None:
                    edge = AGE_PROFILE_EDGES[fseed % len(AGE_PROFILE_EDGES)]
                    clock.t = max(clock.t, e.atime + edge)
    fresh = ProfileCube(cat, clock=clock)
    fresh.rebuild(use_kernel=False)
    _assert_reports_equal(cube, scalar)
    _assert_reports_equal(cube, fresh)
    assert cube.age_profile() == fresh.age_profile()
    assert cube.totals() == fresh.totals()


def test_mutation_sequence_with_exact_boundary_instants():
    """Deterministic rollover-boundary sequence (runs without hypothesis)."""
    _run_mutation_sequence([
        ("ins", 0, 5, 10.0), ("ins", 1, 8, 3600.0), ("edge", 0, 0, 0.0),
        ("tick", 0, 0, 86400.0), ("upd", 1, 3, 0.0), ("edge", 3, 0, 0.0),
        ("del", 0, 0, 0.0), ("ins", 2, 9, 40 * 86400.0),
        ("edge", 2, 0, 0.0), ("tick", 0, 0, 400 * 86400.0),
    ])


@pytest.mark.slow
def test_property_incremental_equals_recompute():
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings

    ops_strategy = st.lists(
        st.tuples(st.sampled_from(["ins", "upd", "del", "tick", "edge"]),
                  st.integers(0, 39),                    # fid seed
                  st.integers(0, len(SIZES) - 1),        # size choice
                  st.floats(0, 100 * 86400,
                            allow_nan=False)),           # age / advance
        min_size=1, max_size=60)

    @settings(max_examples=40, deadline=None)
    @given(ops=ops_strategy)
    def run(ops):
        _run_mutation_sequence(ops)

    run()
