"""Production meshes. Target: TPU v5e pods, 256 chips each.

single-pod: (16, 16) = ("data", "model")     — 256 chips
multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips

A FUNCTION (not module constant) so importing never touches device state.
"""
from __future__ import annotations

import numpy as np

import jax

# TPU v5e hardware constants (per chip) for the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)}; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)")
    # more devices than the mesh needs (e.g. 512 present, single-pod 256)
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape, axes):
    """Small helper for tests (arbitrary meshes on few fake devices)."""
    devs = jax.devices()
    n = int(np.prod(shape))
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_shards_mesh(n_devices: int = 0):
    """1-D ``("shards",)`` mesh for the sharded catalog data plane.

    The device-resident column store (``core.device_store``) and the
    mesh-parallel ``policy_scan`` launch partition catalog shard groups
    along this axis — one shard group per device. ``n_devices=0`` takes
    every visible device (run CPU hosts under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake N).
    """
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise RuntimeError(
            f"need {n} devices for a ({n},)-shards mesh, have {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n}")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]), ("shards",))
