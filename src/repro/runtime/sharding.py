"""Sharding rules: parameter/cache/batch PartitionSpecs per architecture.

Profiles:

* ``tp`` (default): tensor parallel over "model" (heads / d_ff / vocab
  columns), data parallel over ("pod",)+"data"; optimizer states are
  additionally sharded over "data" (ZeRO-1).
* ``fsdp``: like ``tp`` but parameters themselves are also sharded over
  "data" at rest (all-gathered per layer inside the scan) — required for
  mixtral-8x22b / llama4-400b whose TP-only shards exceed HBM.

Dims that do not divide the mesh axis are left unsharded (GSPMD padding is
legal but wasteful; we prefer explicit replication and note the cost — see
DESIGN.md SS5: deepseek 56 heads, whisper vocab 51866).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...] = ("data",)     # ("pod","data") on multi-pod
    tp: str = "model"

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        dp = tuple(n for n in names if n in ("pod", "data"))
        return cls(dp=dp, tp="model" if "model" in names else names[-1])


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


class ShardingRules:
    """Derives PartitionSpecs for a model's params/caches/batches."""

    def __init__(self, cfg, mesh: Mesh, profile: str = "tp") -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.axes = MeshAxes.from_mesh(mesh)
        self.tp_size = _axis_size(mesh, self.axes.tp)
        self.dp_size = _axis_size(mesh, self.axes.dp)
        self.profile = profile

    # -- helpers ---------------------------------------------------------------
    def _col(self, dim: int) -> Optional[str]:
        """Shard a dim over tp if it divides evenly."""
        return self.axes.tp if dim % self.tp_size == 0 else None

    def _param_rule(self, name: str, shape: Tuple[int, ...]) -> P:
        cfg = self.cfg
        tp = self.axes.tp
        c = self._col
        if name == "embed" and getattr(cfg, "tie_embeddings", False):
            # tied: vocab-sharded so the head matmul emits vocab-sharded
            # logits with no collective (lookup is a cheap masked psum)
            return P(c(shape[0]), None)
        if name in ("embed", "pos_embed", "pos"):
            return P(None, c(shape[-1]))
        if name == "lm_head":
            return P(None, c(shape[-1]))
        if name in ("wq", "wk", "wv", "w1", "w3", "s1", "s3", "w_gate",
                    "w_in", "w_a", "w_x", "wr", "wg", "maa_a", "wd_a"):
            return P(*([None] * (len(shape) - 1) + [c(shape[-1])]))
        if name in ("wo", "w2", "s2", "w_out"):
            # row-parallel: contraction dim sharded
            return P(*([None] * (len(shape) - 2) + [c(shape[-2]), None]))
        if name == "router":
            return P(None, None)
        if name in ("bq", "bk", "bv", "b1", "b_a", "b_x", "lam", "w0",
                    "gn_w"):
            return P(c(shape[-1]))
        if name == "conv_w":
            return P(None, c(shape[-1]))
        if name == "mu":
            return P(None, c(shape[-1]))
        if name in ("maa_b", "wd_b"):
            return P(*([None] * (len(shape) - 1) + [c(shape[-1])]))
        if name == "u":
            return P(c(shape[0]), None) if len(shape) == 2 else P(None)
        # rwkv wk/wv in channel-mix reuse wk/wv names (handled above);
        # norms, biases, gates, scalars: replicate
        return P(*([None] * len(shape)))

    def _moe_rule(self, name: str, shape: Tuple[int, ...]) -> Optional[P]:
        """Expert tensors (E, D, F) / (E, F, D): EP if E divides tp, else TP."""
        if name not in ("w1", "w3", "w2") or len(shape) < 3:
            return None
        E = self.cfg.moe.num_experts if self.cfg.moe else 0
        if shape[-3] != E or E == 0:
            return None
        lead = [None] * (len(shape) - 3)
        if E % self.tp_size == 0:
            return P(*lead, self.axes.tp, None, None)        # EP
        if name == "w2":
            return P(*lead, None, self._col(shape[-2]), None)  # TP rows
        return P(*lead, None, None, self._col(shape[-1]))      # TP cols

    def _fsdpify(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Also shard the largest unsharded dim over data (params at rest)."""
        if len(shape) < 2 or int(jax_prod(shape)) < (1 << 20):
            return spec
        dp = self.axes.dp
        dims = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = -1, 0
        for i, (d, s) in enumerate(zip(dims, shape)):
            if d is None and s % self.dp_size == 0 and s > best_size:
                best, best_size = i, s
        if best >= 0:
            dims[best] = dp if len(dp) > 1 else dp[0]
        return P(*dims)

    # -- public API -----------------------------------------------------------
    def param_pspecs(self, param_specs: PyTree) -> PyTree:
        """PartitionSpec tree matching the model parameter tree."""

        def rule(path, leaf):
            name = _leaf_name(path)
            stacked = any(_key_str(k) in ("scan", "layers")
                          for k in path)
            shape = tuple(leaf.shape)
            base_shape = shape[1:] if stacked else shape
            spec = self._moe_rule(name, base_shape)
            if spec is None:
                spec = self._param_rule(name, base_shape)
            if self.profile == "fsdp":
                spec = self._fsdpify(spec, base_shape)
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            return spec

        return jax.tree_util.tree_map_with_path(rule, param_specs)

    def opt_state_pspecs(self, param_specs: PyTree) -> PyTree:
        """ZeRO-1: moments sharded over data on top of the param sharding."""

        def rule(path, leaf):
            name = _leaf_name(path)
            stacked = any(_key_str(k) in ("scan", "layers")
                          for k in path)
            shape = tuple(leaf.shape)
            base_shape = shape[1:] if stacked else shape
            spec = self._moe_rule(name, base_shape)
            if spec is None:
                spec = self._param_rule(name, base_shape)
            spec = self._fsdpify(spec, base_shape)   # always ZeRO-1
            if stacked:
                spec = P(*((None,) + tuple(spec)))
            return spec

        return jax.tree_util.tree_map_with_path(rule, param_specs)

    def cache_pspecs(self, cache_specs: PyTree) -> PyTree:
        """Decode-cache sharding: batch over dp; heads (or head_dim) over tp."""

        def rule(path, leaf):
            name = _leaf_name(path)
            nd = leaf.ndim
            if name in ("k", "v", "xk", "xv"):
                # (..., B, L, K, hd)
                lead = [None] * (nd - 4)
                dp = self._dp_if(leaf.shape[-4])
                kspec = self._col(leaf.shape[-2])
                hspec = None if kspec else self._col(leaf.shape[-1])
                return P(*lead, dp, None, kspec, hspec)
            if name in ("kscale", "vscale"):     # (..., B, L, K, 1)
                lead = [None] * (nd - 4)
                return P(*lead, self._dp_if(leaf.shape[-4]), None,
                         self._col(leaf.shape[-2]), None)
            if name == "h":                     # (..., B, R)
                return P(*([None] * (nd - 2)), self._dp_if(leaf.shape[-2]),
                         self._col(leaf.shape[-1]))
            if name == "conv":                  # (..., B, w-1, R)
                return P(*([None] * (nd - 3)), self._dp_if(leaf.shape[-3]),
                         None, self._col(leaf.shape[-1]))
            if name == "s":                     # (..., B, H, hd, hd)
                return P(*([None] * (nd - 4)), self._dp_if(leaf.shape[-4]),
                         self._col(leaf.shape[-3]), None, None)
            if name in ("shift_t", "shift_c"):  # (..., B, D)
                return P(*([None] * (nd - 2)), self._dp_if(leaf.shape[-2]),
                         self._col(leaf.shape[-1]))
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(rule, cache_specs)

    def _dp_if(self, dim: int):
        """dp axis spec if the dim divides the dp size (B=1 long-context)."""
        if dim % self.dp_size != 0:
            return None
        return self.axes.dp if len(self.axes.dp) > 1 else self.axes.dp[0]

    def batch_pspecs(self, batch_specs: PyTree) -> PyTree:
        """Batch dim over dp. Supports leading grad-accum dim via name."""

        def rule(path, leaf):
            name = _leaf_name(path)
            nd = leaf.ndim
            if name in ("tokens", "labels"):
                return P(*([None] * (nd - 2)), self._dp_if(leaf.shape[-2]),
                         None)
            if name in ("frames", "img"):
                return P(*([None] * (nd - 3)), self._dp_if(leaf.shape[-3]),
                         None, self._col(leaf.shape[-1]))
            if name == "pos":
                return P()
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(rule, batch_specs)

    # -- NamedSharding wrappers ---------------------------------------------------
    def to_shardings(self, pspec_tree: PyTree) -> PyTree:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def _leaf_name(path) -> str:
    for k in reversed(path):
        s = _key_str(k)
        if not s.isdigit():
            return s
    return ""


def jax_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def profile_for(cfg) -> str:
    """fsdp for >=100B-param models, tp otherwise."""
    return "fsdp" if cfg.param_count() > 100e9 else "tp"
