"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attention image layers every 5th layer (8 of 40).
Vision tower is a STUB per the assignment: input_specs provides projected
patch embeddings (B, 1600, 4096). [hf: meta-llama/Llama-3.2-11B-Vision]
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

_PATTERN = (
    LayerSpec(mix=ATTN_FULL),
    LayerSpec(mix=ATTN_FULL),
    LayerSpec(mix=ATTN_FULL),
    LayerSpec(mix=ATTN_FULL),
    LayerSpec(mix=ATTN_FULL, cross_attn=True),
)

CONFIG = ModelConfig(
    name="llama3p2_vision_11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=128256,
    pattern=_PATTERN, rope_theta=5e5,
    n_img_tokens=1600,
)

SMOKE = ModelConfig(
    name="llama3p2_vision_smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv=2, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN,
    n_img_tokens=16,
)
