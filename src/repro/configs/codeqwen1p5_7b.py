"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416 — qwen1.5 arch (qkv bias). [hf: Qwen/CodeQwen1.5-7B]
"""
from repro.models.config import ATTN_FULL, LayerSpec, ModelConfig

_PATTERN = (LayerSpec(mix=ATTN_FULL),)

CONFIG = ModelConfig(
    name="codeqwen1p5_7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, head_dim=128,
    d_ff=13440, vocab=92416,
    pattern=_PATTERN, qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="codeqwen_smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
    d_ff=128, vocab=512,
    pattern=_PATTERN, qkv_bias=True,
)
