import numpy as np
import pytest

from repro.core import Catalog, Entry, FsType, HsmState


def _entry(fid, **kw):
    defaults = dict(parent_fid=1, name=f"f{fid}", path=f"/a/f{fid}",
                    type=FsType.FILE, size=fid * 100, blocks=fid * 100,
                    owner="foo", atime=1.0, mtime=1.0, ctime=1.0)
    defaults.update(kw)
    return Entry(fid=fid, **defaults)


def test_upsert_get_roundtrip():
    cat = Catalog(n_shards=3)
    e = _entry(42, owner="bar", pool="ssd", hsm_state=HsmState.ARCHIVED,
               xattrs={"k": "v"}, stripe_osts=(1, 2))
    cat.upsert(e)
    out = cat.get(42)
    assert out.owner == "bar" and out.pool == "ssd"
    assert out.hsm_state == HsmState.ARCHIVED
    assert out.xattrs == {"k": "v"} and out.stripe_osts == (1, 2)
    assert len(cat) == 1


def test_update_fields_and_remove():
    cat = Catalog(n_shards=2)
    cat.upsert(_entry(7))
    assert cat.update_fields(7, size=999, owner="baz")
    assert cat.get(7).size == 999 and cat.get(7).owner == "baz"
    assert cat.remove(7)
    assert cat.get(7) is None
    assert not cat.remove(7)


def test_vector_query():
    cat = Catalog(n_shards=4)
    for i in range(1, 101):
        cat.upsert(_entry(i, owner="foo" if i % 2 else "bar"))
    fids = cat.query_fids(lambda c: c["size"] > 5000)
    assert sorted(fids.tolist()) == list(range(51, 101))
    cols = cat.arrays()
    assert len(cols["_paths"]) == 100


def test_sqlite_persistence_roundtrip(tmp_path):
    db = str(tmp_path / "cat.db")
    cat = Catalog(n_shards=2, db_path=db)
    for i in range(1, 21):
        cat.upsert(_entry(i))
    cat.remove(5)
    # crash: new catalog from same file
    cat2 = Catalog(n_shards=2, db_path=db)
    n = cat2.load_from_db()
    assert n == 19
    assert cat2.get(5) is None and cat2.get(6).size == 600


def test_delta_hooks_fire():
    cat = Catalog(n_shards=1)
    deltas = []
    cat.add_delta_hook(lambda old, new: deltas.append((old, new)))
    cat.upsert(_entry(1))
    cat.update_fields(1, size=5)
    cat.remove(1)
    assert len(deltas) == 3
    assert deltas[0][0] is None and deltas[2][1] is None


def test_get_batch_roundtrip_and_missing():
    cat = Catalog(n_shards=3)
    for i in range(1, 41):
        cat.upsert(_entry(i, owner=f"u{i % 4}"))
    fids = [5, 999, 17, 2, 1000, 40]
    got = cat.get_batch(fids)
    assert got[1] is None and got[4] is None
    for fid, e in zip(fids, got):
        if e is not None:
            assert e.fid == fid
            # batch-built entries must equal scalar-built ones exactly
            assert e == cat.get(fid)


def test_get_batch_matches_get_for_all_fields():
    cat = Catalog(n_shards=2)
    cat.upsert(_entry(9, owner="bar", pool="ssd", hsm_state=HsmState.RELEASED,
                      xattrs={"k": "v"}, stripe_osts=(3, 1), dirty=True))
    (batch,) = cat.get_batch([9])
    assert batch == cat.get(9)
    assert batch.hsm_state is HsmState.RELEASED
    assert batch.type is FsType.FILE


def test_update_fields_batch_fires_hooks_and_returns_updated():
    cat = Catalog(n_shards=4)
    fired = []
    cat.add_delta_hook(lambda old, new: fired.append((old, new)))
    for i in range(1, 11):
        cat.upsert(_entry(i))
    fired.clear()
    updated = cat.update_fields_batch([3, 7, 999, 4], status="expired")
    assert sorted(updated) == [3, 4, 7]
    assert len(fired) == 3                       # one delta per updated entry
    for fid in (3, 4, 7):
        assert cat.get(fid).status == "expired"


def test_remove_batch():
    cat = Catalog(n_shards=2)
    for i in range(1, 11):
        cat.upsert(_entry(i))
    assert cat.remove_batch([2, 4, 999, 6]) == 3
    assert len(cat) == 7
    assert cat.get(4) is None


def test_column_slice_alignment():
    cat = Catalog(n_shards=4)
    for i in range(1, 21):
        cat.upsert(_entry(i))
    fids = [7, 300, 14, 1]
    cols, present = cat.column_slice(fids, ["size", "blocks"])
    assert present.tolist() == [True, False, True, True]
    assert cols["size"].tolist() == [700, 0, 1400, 100]
    assert cols["size"].dtype == np.int64


def test_column_batch_entry_free_view():
    from repro.core import ColumnBatch
    cat = Catalog(n_shards=3)
    for i in range(1, 21):
        cat.upsert(_entry(i, owner=f"u{i % 3}", pool="ssd" if i % 2 else ""))
    fids = [7, 300, 14, 1, 2]
    batch = cat.column_batch(fids)
    assert isinstance(batch, ColumnBatch) and len(batch) == 5
    assert batch.present.tolist() == [True, False, True, True, True]
    assert batch.fids.tolist() == [7, 0, 14, 1, 2]
    assert batch.size.tolist() == [700, 0, 1400, 100, 200]
    # lazy string decode through the interned codes
    assert batch.decode("owner") == ["u1", "", "u2", "u1", "u2"]
    assert batch.decode("pool") == ["ssd", "", "", "ssd", ""]
    # sub-batch slicing keeps alignment; bool masks select, not index
    sub = batch.take([0, 2])
    assert sub.fids.tolist() == [7, 14] and sub.present.all()
    assert sub.decode("owner") == ["u1", "u2"]
    masked = batch.take(batch.present)
    assert masked.fids.tolist() == [7, 14, 1, 2]
    # the materializing escape hatch equals get_batch
    assert batch.entries() == cat.get_batch(fids)


def test_column_batch_from_entries_matches_gather():
    from repro.core import ColumnBatch
    cat = Catalog(n_shards=2)
    for i in range(1, 11):
        cat.upsert(_entry(i, owner=f"u{i % 2}"))
    fids = [3, 99, 8]
    direct = cat.column_batch(fids)
    shim = ColumnBatch.from_entries(cat.get_batch(fids), cat.strings, cat)
    assert (shim.present == direct.present).all()
    for name in direct.cols:
        assert (shim.cols[name] == direct.cols[name]).all(), name


def test_catalog_version_bumps_on_every_mutation():
    cat = Catalog(n_shards=2)
    v = cat.version
    cat.upsert(_entry(1)); assert cat.version > v; v = cat.version
    cat.upsert_batch([_entry(2), _entry(3)]); assert cat.version > v
    v = cat.version
    cat.update_fields(1, size=5); assert cat.version > v; v = cat.version
    cat.update_fields_batch([2, 3], status="x"); assert cat.version > v
    v = cat.version
    cat.remove(1); assert cat.version > v; v = cat.version
    cat.remove_batch([2]); assert cat.version > v


def test_arrays_lazy_paths_still_correct():
    cat = Catalog(n_shards=3)
    for i in range(1, 16):
        cat.upsert(_entry(i))
    cols = cat.arrays()
    # _paths/_names materialize lazily but align with the numeric columns
    assert "_paths" in cols
    paths = cols["_paths"]
    assert len(paths) == len(cols["fid"])
    for fid, p in zip(cols["fid"].tolist(), paths):
        assert p == f"/a/f{fid}"


def test_arrays_cached_per_version():
    """Two arrays() calls at the same catalog version return the SAME
    cached object (no per-run shard concat); any mutation invalidates."""
    cat = Catalog(n_shards=3)
    for i in range(1, 21):
        cat.upsert(_entry(i))
    a = cat.arrays()
    b = cat.arrays()
    assert a is b
    # lazy string materialization does not invalidate the cache
    _ = a["_paths"]
    assert cat.arrays() is a
    cat.update_fields(3, size=123)
    c = cat.arrays()
    assert c is not a
    assert c["size"][np.nonzero(c["fid"] == 3)[0][0]] == 123
    assert cat.arrays() is c
    cat.remove(5)
    assert cat.arrays() is not c
