"""Changelog-consumption pipeline: sync + async dirty-tag modes (C4/C11)."""
import time

from repro.core import (Catalog, ChangelogCounters, EventPipeline,
                        PipelineConfig, Scanner)
from repro.fs import LustreSim


def _fs_with_files(n=30):
    fs = LustreSim(n_mdts=1)
    d = fs.mkdir(fs.root_fid(), "dir")
    fids = []
    for i in range(n):
        f = fs.create(d, f"f{i}", owner="u", uid="u")
        fs.write(f, 100 * (i + 1))
        fids.append(f)
    return fs, d, fids


def test_sync_pipeline_mirrors_fs():
    fs, d, fids = _fs_with_files()
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    n = pipe.process_once(100000)
    assert n > 0
    assert len(cat) == fs.count() - 1      # root not in changelog
    assert cat.get(fids[3]).size == 400
    # acks happened: nothing pending
    assert fs.changelog.stream(0).pending() == 0


def test_incremental_updates_no_rescan():
    fs, d, fids = _fs_with_files(10)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    pipe.process_once(100000)
    fs.write(fids[0], 5000, uid="u")
    fs.unlink(fids[1])
    new = fs.create(d, "fresh", owner="u")
    fs.write(new, 7)
    pipe.process_once()
    assert cat.get(fids[0]).size == 100 + 5000
    assert cat.get(fids[1]) is None
    assert cat.get(new).size == 7


def test_async_dirty_tag_dedups():
    """Paper SIII-A2 future work: repeated changes fold into one refresh."""
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    cfg = PipelineConfig(async_updates=True)
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), cfg)
    pipe.process_once(100000)
    for _ in range(20):                    # 20 writes to the same file
        fs.write(fids[2], 10, uid="u")
    n = pipe.process_once()
    assert n == 20
    assert pipe.dedup_hits >= 18           # tagged once, folded repeatedly
    assert cat.get(fids[2]).size == 300 + 200


def test_threaded_pipeline_drains():
    fs, d, fids = _fs_with_files(40)
    cat = Catalog()
    counters = ChangelogCounters()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(n_workers=3), counters)
    pipe.start()
    try:
        assert pipe.drain(timeout=20)
        for i in range(10):
            fs.write(fids[i], 1, uid="live")
        assert pipe.drain(timeout=20)
    finally:
        pipe.stop()
    assert cat.get(fids[0]).size == 101
    assert counters.snapshot()["per_user"]["live"]


def test_same_batch_create_unlink_never_materializes():
    """An UNLNK after a CREAT of the same fid in one batch folds to nothing:
    no error, no catalog entry, no dirty tag (sync and async modes)."""
    for async_updates in (False, True):
        fs = LustreSim(n_mdts=1)
        d = fs.mkdir(fs.root_fid(), "dir")
        keep = fs.create(d, "keep", owner="u")
        fs.write(keep, 50)
        ephemeral = fs.create(d, "tmp", owner="u")
        fs.write(ephemeral, 999)
        fs.unlink(ephemeral)               # same pending batch as its CREAT
        cat = Catalog()
        pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                             PipelineConfig(async_updates=async_updates,
                                            batch_size=1024))
        pipe.process_once(100000)
        assert cat.get(ephemeral) is None
        assert ephemeral not in pipe._dirty
        assert cat.get(keep).size == 50
        assert fs.changelog.stream(0).pending() == 0   # all acked cleanly


def test_delta_fanout_notifies_after_commit():
    fs, d, fids = _fs_with_files(8)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    events = []
    pipe.add_delta_listener(
        lambda changed, removed: events.append((sorted(changed),
                                                sorted(removed))))
    pipe.process_once(100000)
    changed = sorted(f for ch, _ in events for f in ch)
    assert changed == sorted([d] + fids)
    events.clear()

    fs.write(fids[0], 7, uid="u")
    fs.write(fids[0], 7, uid="u")          # folded: one refresh per batch
    fs.unlink(fids[1])
    pipe.process_once(100000)
    changed = [f for ch, _ in events for f in ch]
    removed = [f for _, rm in events for f in rm]
    assert changed == [fids[0]] and removed == [fids[1]]


def test_delta_fanout_async_mode_notifies_refresh():
    fs, d, fids = _fs_with_files(5)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0),
                         PipelineConfig(async_updates=True))
    pipe.process_once(100000)
    events = []
    pipe.add_delta_listener(
        lambda changed, removed: events.append((list(changed),
                                                list(removed))))
    for _ in range(10):
        fs.write(fids[2], 10, uid="u")
    fs.unlink(fids[3])
    pipe.process_once(100000)
    changed = [f for ch, _ in events for f in ch]
    removed = [f for _, rm in events for f in rm]
    assert removed == [fids[3]]
    assert changed == [fids[2]]            # deduped to one refresh
    assert cat.get(fids[2]).size == 300 + 100


def test_scan_and_changelog_agree():
    """DB built by scan == DB built by changelog replay."""
    fs, d, fids = _fs_with_files(25)
    by_scan = Catalog()
    Scanner(fs, by_scan).scan()
    by_log = Catalog()
    EventPipeline(fs, by_log, fs.changelog.stream(0),
                  PipelineConfig()).process_once(100000)
    for fid in fids:
        a, b = by_scan.get(fid), by_log.get(fid)
        assert a.size == b.size and a.owner == b.owner and a.path == b.path
