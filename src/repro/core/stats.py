"""Pre-aggregated, on-the-fly statistics (C6) — the O(1) ``rbh-report`` path.

The paper: *"Commonly used statistics are pre-generated in the database.
They are computed on-the-fly as entries are updated, so the following
information is always available: statistics per object type, per user, per
group, per migration status and file size profile."*

:class:`StatsAggregator` subscribes to catalog delta hooks — every
insert/update/remove adjusts counters incrementally, so report queries never
scan entries. Also implements the paper's SIII-C *future* counters as
beyond-paper features: per-user and per-jobid changelog counters and
per-directory-level usage counters (instant ``du``).

Counter updates can run **synchronously** (paper default; measurably slows
ingest) or be drained **asynchronously** by a background thread from a
bounded delta queue (the paper's proposed fix; stats lag slightly but ingest
is faster) — both modes are benchmarked in ``benchmarks/bench_changelog.py``.
"""
from __future__ import annotations

import queue
import threading
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .types import (ChangelogRecord, FsType, HsmState, SIZE_PROFILE_LABELS,
                    size_profile_bucket)


class _Acc:
    """count / volume (logical bytes) / spc_used (allocated) accumulator."""

    __slots__ = ("count", "volume", "spc_used")

    def __init__(self) -> None:
        self.count = 0
        self.volume = 0
        self.spc_used = 0

    def add(self, sign: int, size: int, blocks: int) -> None:
        self.count += sign
        self.volume += sign * size
        self.spc_used += sign * blocks

    def as_dict(self) -> dict:
        avg = self.volume / self.count if self.count else 0.0
        return {"count": self.count, "volume": self.volume,
                "spc_used": self.spc_used, "avg_size": avg}


class StatsAggregator:
    """O(1) pre-aggregated stats, keyed per user/group/type/hsm-state/size-bin."""

    def __init__(self, strings, async_mode: bool = False,
                 queue_size: int = 1 << 16) -> None:
        self.strings = strings
        self._lock = threading.Lock()
        # (owner_code, type) -> _Acc ; (group_code, type) -> _Acc ; type -> _Acc
        self.per_user: Dict[Tuple[int, int], _Acc] = defaultdict(_Acc)
        self.per_group: Dict[Tuple[int, int], _Acc] = defaultdict(_Acc)
        self.per_type: Dict[int, _Acc] = defaultdict(_Acc)
        self.per_hsm: Dict[int, _Acc] = defaultdict(_Acc)
        # (owner_code, size_bucket) -> count : per-user file size profile
        self.size_profile: Dict[Tuple[int, int], int] = defaultdict(int)
        self.total = _Acc()
        self.async_mode = async_mode
        self._q: Optional[queue.Queue] = None
        self._drainer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if async_mode:
            self._q = queue.Queue(maxsize=queue_size)
            self._drainer = threading.Thread(target=self._drain, daemon=True)
            self._drainer.start()

    # -- delta hook (wired into Catalog.add_delta_hook) -----------------------
    def on_delta(self, old, new) -> None:
        if self.async_mode:
            self._q.put((old, new))
        else:
            self._apply(old, new)

    def _drain(self) -> None:
        while not self._stop.is_set() or (self._q is not None and not self._q.empty()):
            try:
                old, new = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            self._apply(old, new)
            self._q.task_done()

    def flush(self) -> None:
        """Wait until asynchronously queued deltas are folded in."""
        if self._q is not None:
            self._q.join()

    def close(self) -> None:
        self._stop.set()
        if self._drainer is not None:
            self._drainer.join(timeout=5)

    def _apply(self, old, new) -> None:
        with self._lock:
            if old is not None:
                self._fold(-1, *old)
            if new is not None:
                self._fold(+1, *new)

    def _fold(self, sign: int, owner: int, group: int, type_: int,
              size: int, blocks: int, hsm: int) -> None:
        self.per_user[(owner, type_)].add(sign, size, blocks)
        self.per_group[(group, type_)].add(sign, size, blocks)
        self.per_type[type_].add(sign, size, blocks)
        self.per_hsm[hsm].add(sign, size, blocks)
        self.total.add(sign, size, blocks)
        if type_ == int(FsType.FILE):
            self.size_profile[(owner, size_profile_bucket(size))] += sign

    # -- O(1) report queries -----------------------------------------------------
    def report_user(self, user: str) -> List[dict]:
        """`rbh-report -u user`: per-type count/volume/avg — O(#types)."""
        code = self.strings.code_of(user)
        if code is None:
            return []
        out = []
        with self._lock:
            for t in sorted(FsType, key=int):
                acc = self.per_user.get((code, int(t)))
                if acc and acc.count:
                    d = acc.as_dict()
                    d.update(user=user, type=t.name.lower())
                    out.append(d)
        return out

    def report_group(self, grp: str) -> List[dict]:
        code = self.strings.code_of(grp)
        if code is None:
            return []
        out = []
        with self._lock:
            for t in sorted(FsType, key=int):
                acc = self.per_group.get((code, int(t)))
                if acc and acc.count:
                    d = acc.as_dict()
                    d.update(group=grp, type=t.name.lower())
                    out.append(d)
        return out

    def report_types(self) -> Dict[str, dict]:
        with self._lock:
            return {FsType(t).name.lower(): a.as_dict()
                    for t, a in self.per_type.items() if a.count}

    def report_hsm(self) -> Dict[str, dict]:
        with self._lock:
            return {HsmState(h).name.lower(): a.as_dict()
                    for h, a in self.per_hsm.items() if a.count}

    def user_size_profile(self, user: str) -> Dict[str, int]:
        code = self.strings.code_of(user)
        out = {lbl: 0 for lbl in SIZE_PROFILE_LABELS}
        if code is None:
            return out
        with self._lock:
            for (ucode, bucket), n in self.size_profile.items():
                if ucode == code and n:
                    out[SIZE_PROFILE_LABELS[bucket]] += n
        return out

    def top_users(self, by: str = "volume", k: int = 10,
                  type_: FsType = FsType.FILE) -> List[dict]:
        """Rank users without scanning entries (aggregates only)."""
        with self._lock:
            rows = []
            for (ucode, t), acc in self.per_user.items():
                if t != int(type_) or not acc.count:
                    continue
                d = acc.as_dict()
                d["user"] = self.strings.lookup(ucode)
                rows.append(d)
        rows.sort(key=lambda d: d.get(by, 0), reverse=True)
        return rows[:k]


class ChangelogCounters:
    """Per-type / per-user / per-jobid changelog counters (SIII-C)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.per_type: Dict[int, int] = defaultdict(int)
        self.per_user: Dict[str, Dict[int, int]] = defaultdict(lambda: defaultdict(int))
        self.per_job: Dict[str, Dict[int, int]] = defaultdict(lambda: defaultdict(int))
        self.total = 0

    def on_record(self, rec: ChangelogRecord) -> None:
        with self._lock:
            self.total += 1
            self.per_type[int(rec.type)] += 1
            if rec.uid:
                self.per_user[rec.uid][int(rec.type)] += 1
            if rec.jobid:
                self.per_job[rec.jobid][int(rec.type)] += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "total": self.total,
                "per_type": dict(self.per_type),
                "per_user": {u: dict(c) for u, c in self.per_user.items()},
                "per_job": {j: dict(c) for j, c in self.per_job.items()},
            }


class DirUsage:
    """Per-directory recursive usage counters up to ``max_depth`` (SIII-C).

    Makes ``du`` at shallow namespace levels O(1): each file delta is
    propagated to its ancestor directories (bounded by ``max_depth``).
    Ancestors are resolved from entry paths, so no catalog walk is needed.
    """

    def __init__(self, max_depth: int = 3) -> None:
        self.max_depth = max_depth
        self._lock = threading.Lock()
        self.usage: Dict[str, _Acc] = defaultdict(_Acc)

    @staticmethod
    def _ancestors(path: str, max_depth: int) -> List[str]:
        parts = [p for p in path.split("/") if p]
        out = ["/"]
        for i in range(min(len(parts) - 1, max_depth)):
            out.append("/" + "/".join(parts[: i + 1]))
        return out

    def on_file(self, sign: int, path: str, size: int, blocks: int) -> None:
        with self._lock:
            for d in self._ancestors(path, self.max_depth):
                self.usage[d].add(sign, size, blocks)

    def du(self, path: str) -> dict:
        path = "/" + "/".join(p for p in path.split("/") if p) if path != "/" else "/"
        with self._lock:
            return self.usage[path].as_dict() if path in self.usage else \
                {"count": 0, "volume": 0, "spc_used": 0, "avg_size": 0.0}
