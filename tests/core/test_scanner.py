import random

import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, never hard-error collection
from hypothesis import given, settings, strategies as st

from repro.core import Catalog, Scanner, multi_client_scan, prune_missing
from repro.fs import LustreSim


def build_tree(fs, seed: int, n_dirs: int, files_per_dir: int) -> int:
    rng = random.Random(seed)
    dirs = [fs.root_fid()]
    total = 1
    for i in range(n_dirs):
        parent = rng.choice(dirs)
        d = fs.mkdir(parent, f"d{i}")
        dirs.append(d)
        total += 1
        for j in range(rng.randint(0, files_per_dir)):
            f = fs.create(d, f"f{j}", owner=rng.choice(["a", "b"]))
            fs.write(f, rng.randint(0, 10000))
            total += 1
    return total


@pytest.mark.parametrize("threads", [1, 4])
def test_scan_finds_everything(threads):
    fs = LustreSim()
    total = build_tree(fs, seed=1, n_dirs=20, files_per_dir=5)
    cat = Catalog()
    st_ = Scanner(fs, cat, n_threads=threads).scan()
    assert len(cat) == total == fs.count()
    assert st_.errors == 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), threads=st.integers(1, 6))
def test_scan_thread_count_invariant(seed, threads):
    """Property: scan result is independent of parallelism (Fig. 3)."""
    fs = LustreSim()
    build_tree(fs, seed=seed, n_dirs=10, files_per_dir=3)
    cat1 = Catalog()
    Scanner(fs, cat1, n_threads=1).scan()
    cat2 = Catalog()
    Scanner(fs, cat2, n_threads=threads).scan()
    fids1 = sorted(f for s in cat1.shards for f in s.fids())
    fids2 = sorted(f for s in cat2.shards for f in s.fids())
    assert fids1 == fids2


def test_multi_client_scan_equivalent():
    fs = LustreSim()
    total = build_tree(fs, seed=7, n_dirs=30, files_per_dir=4)
    cat = Catalog()
    multi_client_scan(fs, cat, n_clients=3, threads_per_client=2)
    assert len(cat) == total


def test_prune_missing_after_deletes():
    fs = LustreSim()
    build_tree(fs, seed=3, n_dirs=5, files_per_dir=4)
    cat = Catalog()
    Scanner(fs, cat).scan()
    # delete some files behind the catalog's back
    victims = [e.fid for e in cat.entries() if e.type == 0][:3]
    for fid in victims:
        fs.unlink(fid)
    removed = prune_missing(fs, cat)
    assert removed == len(victims)
    assert len(cat) == fs.count()
