"""Aggregate experiments/dryrun/*.json into the roofline table (markdown +
CSV rows for run.py)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(pattern: str = "*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO flops | peak GiB/dev | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | "
                         f"{r.get('mesh')} | | | | | | | FAIL: "
                         f"{r.get('error', '?')[:60]} |")
            continue
        mem = r["memory"]["peak_estimate_bytes"] / 2 ** 30
        if "bottleneck" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                f"| {r['collective_s']:.4f} | {r['bottleneck']} "
                f"| {r.get('model_vs_hlo_flops', 0):.3f} | {mem:.2f} | ok |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | | | | "
                f"(multi-pod: fit/sharding only) | | {mem:.2f} | ok |")
    return "\n".join(lines)


def run() -> list:
    recs = [r for r in load_records() if "__16x16" in
            f"{r.get('arch')}__{r.get('shape')}__{r.get('mesh')}"
            or r.get("mesh") == "16x16"]
    rows = []
    for r in recs:
        if r.get("status") != "ok" or "bottleneck" not in r:
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((f"roofline_{r['arch']}_{r['shape']}",
                     dom * 1e6,
                     f"bottleneck_{r['bottleneck']}"
                     f"_computefrac_{r['compute_s']/dom:.2f}"))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_records()))
