"""Public profile-cube op: packs columns, pads, dispatches kernel/oracle.

``profile_cube`` turns four aligned columns (dense group id, size, blocks,
age-in-seconds) into the (3, B, S, A) count/volume/spc_used cube in one
launch. Rows are padded to the tile with an all-invalid pad; the group
axis is padded to the sublane multiple and sliced back.

``mesh_profile_cube`` is the mesh-resident analogue: it consumes the
device store's sharded ``(D, n_cols, Rp)`` global column array under
``shard_map``, builds one partial cube per device from that device's
resident block (Pallas kernel or jnp oracle — no column ever moves), and
``psum``-combines the partials into the replicated merged cube. Both the
sharded partials (which stay resident for warm scatter-add maintenance)
and the combined cube come back; ``mesh_cube_combine`` re-runs just the
psum over already-resident partials after in-place updates.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import LANE, profile_cube_pallas
from .ref import A_BUCKETS, N_MEASURES, S_BUCKETS, profile_cube_ref

# The (B, tile) gid one-hot must stay within a sane VMEM budget; catalogs
# with more distinct (owner, group, type, hsm) combinations take the host
# groupby path (see core.profiles).
MAX_GROUPS = 4096


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_groups", "use_kernel", "tile",
                                   "prebucketed"))
def _profile_cube_jit(cols: jax.Array, n_groups: int, use_kernel: bool,
                      tile: int, prebucketed: bool) -> jax.Array:
    """cols: (5|7, N) f32 rows [gid, size, blocks, age, (sb, ab,) valid]."""
    n = cols.shape[1]
    valid_col = 6 if prebucketed else 4
    sb_col, ab_col = (4, 5) if prebucketed else (-1, -1)
    pad_n = (-n) % tile
    if pad_n:
        cols = jnp.pad(cols, ((0, 0), (0, pad_n)))    # pad rows read valid=0
    pad_b = (-n_groups) % 8                           # f32 sublane multiple
    bp = n_groups + pad_b
    if use_kernel:
        cube = profile_cube_pallas(cols, n_groups=bp, valid_col=valid_col,
                                   sb_col=sb_col, ab_col=ab_col,
                                   tile=tile, interpret=not _on_tpu())
        cube = cube.reshape(N_MEASURES, bp, S_BUCKETS, A_BUCKETS)
    else:
        cube = profile_cube_ref(cols, bp, valid_col=valid_col,
                                sb_col=sb_col, ab_col=ab_col)
    return cube[:, :n_groups]


def profile_cube(gid, size, blocks, age, n_groups: int, valid=None,
                 sb=None, ab=None, use_kernel: Optional[bool] = None,
                 tile: int = 8 * LANE) -> np.ndarray:
    """Fused bucketize + segment-reduce over aligned entry columns.

    Returns the (N_MEASURES, n_groups, S_BUCKETS, A_BUCKETS) f32 cube:
    measure 0 counts, 1 sums ``size``, 2 sums ``blocks``; rows land in
    ``[gid, size_profile_bucket(size), age_profile_bucket(age)]``.

    ``sb``/``ab`` (optional) are precomputed bucket-index columns: pass
    them when raw sizes/ages exceed the f32 integer range (~2**24), where
    the on-device cast could round a value across a bucket edge —
    ``core.profiles`` always does, so bucket assignment matches its int64
    tables exactly. ``use_kernel=None`` selects the Pallas kernel on TPU
    and the jitted scatter-add oracle elsewhere (the kernel stays
    exercised off-TPU via interpret mode in tests). Sums are f32 — exact
    for integer measures up to 2**24 per cell; the incremental host path
    in ``core.profiles`` keeps int64 precision end-to-end.
    """
    if n_groups > MAX_GROUPS:
        raise ValueError(f"n_groups={n_groups} exceeds the on-device cap "
                         f"{MAX_GROUPS}; use the host groupby path")
    n = len(np.asarray(gid))
    if n_groups <= 0 or n == 0:
        return np.zeros((N_MEASURES, max(n_groups, 0), S_BUCKETS, A_BUCKETS),
                        np.float32)
    if valid is None:
        valid = np.ones(n, np.float32)
    prebucketed = sb is not None and ab is not None
    parts = (gid, size, blocks, age, sb, ab, valid) if prebucketed \
        else (gid, size, blocks, age, valid)
    cols = jnp.stack([jnp.asarray(np.asarray(c), jnp.float32)
                      for c in parts], axis=0)
    if use_kernel is None:
        use_kernel = _on_tpu()
    return np.asarray(_profile_cube_jit(cols, n_groups, use_kernel, tile,
                                        prebucketed))


# -- mesh-resident partial cubes (device-store analytics plane) --------------

@partial(jax.jit, static_argnames=("mesh", "n_groups", "gid_col", "size_col",
                                   "blocks_col", "sb_col", "ab_col",
                                   "valid_col", "use_kernel", "tile"))
def mesh_profile_cube(global_cols: jax.Array, *, mesh, n_groups: int,
                      gid_col: int, size_col: int, blocks_col: int,
                      sb_col: int, ab_col: int, valid_col: int,
                      use_kernel: bool = False, tile: int = 8 * LANE
                      ) -> tuple:
    """Per-device partial cubes + psum-combined merge, all under shard_map.

    ``global_cols`` is the store's assembled ``(D, n_cols, Rp)`` f32 array
    sharded along ``"shards"`` — each device builds the cube of its own
    resident rows (gid/sb/ab ride as extra analytics rows of the block,
    bucketized exactly on the host at scatter time), then the partials
    combine via ``psum``. Returns ``(partials, combined)``:

    * ``partials``: (D, N_MEASURES, n_groups * S * A) f32, sharded along
      ``"shards"`` — one flat partial cube resident per device, kept by
      the store for O(dirty) signed scatter-add maintenance;
    * ``combined``: (N_MEASURES, n_groups, S, A) f32, replicated — the
      merged cube (callers round to int64; exactness holds while per-cell
      sums stay inside the f32 integer envelope, like the single-device
      kernel path).

    ``n_groups`` must be a multiple of 8 (the f32 sublane — the store
    allocates the group axis padded) and ``Rp`` a multiple of ``tile``.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def _device(cols):
        c = cols[0]                              # (n_cols, Rp) local block
        if use_kernel:
            cube = profile_cube_pallas(
                c, n_groups=n_groups, gid_col=gid_col, size_col=size_col,
                blocks_col=blocks_col, age_col=size_col, valid_col=valid_col,
                sb_col=sb_col, ab_col=ab_col, tile=tile,
                interpret=not _on_tpu())
            cube = cube.reshape(N_MEASURES, n_groups, S_BUCKETS, A_BUCKETS)
        else:
            cube = profile_cube_ref(
                c, n_groups, gid_col=gid_col, size_col=size_col,
                blocks_col=blocks_col, age_col=size_col, valid_col=valid_col,
                sb_col=sb_col, ab_col=ab_col)
        combined = jax.lax.psum(cube, "shards")
        return cube.reshape(N_MEASURES, -1)[None], combined

    return shard_map(_device, mesh=mesh, in_specs=(P("shards"),),
                     out_specs=(P("shards"), P()),
                     check_rep=False)(global_cols)


@partial(jax.jit, static_argnames=("mesh", "n_groups", "gid_col", "size_col",
                                   "blocks_col", "sb_col", "ab_col",
                                   "valid_col"))
def mesh_scoped_cube(global_cols: jax.Array, perm: jax.Array,
                     subject: jax.Array, *, mesh, n_groups: int,
                     gid_col: int, size_col: int, blocks_col: int,
                     sb_col: int, ab_col: int, valid_col: int) -> jax.Array:
    """Subject-scoped profile cube in one fused launch over resident rows.

    Unlike :func:`mesh_profile_cube` there are no resident scoped
    partials — scoping is per-query: each device unpacks the subject's
    row from its ``(1, Sp, W)`` packed ``uint32`` permission buffer
    (``perm``, sharded along ``"shards"``; ``subject`` a traced i32 id),
    ANDs it into the validity row, and bins only visible rows; partial
    cubes psum into the replicated (N_MEASURES, n_groups, S, A) f32 cube.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def _device(cols, pm, sid):
        c = cols[0]                              # (n_cols, Rp) local block
        words = jax.lax.dynamic_index_in_dim(pm[0], sid, axis=0,
                                             keepdims=False)
        bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]) \
            & jnp.uint32(1)
        vis = (bits != 0).reshape(-1)
        masked = jnp.where(vis, c[valid_col], 0.0)
        c2 = jnp.concatenate([c, masked[None]], axis=0)
        cube = profile_cube_ref(
            c2, n_groups, gid_col=gid_col, size_col=size_col,
            blocks_col=blocks_col, age_col=size_col, valid_col=c.shape[0],
            sb_col=sb_col, ab_col=ab_col)
        return jax.lax.psum(cube, "shards")

    return shard_map(_device, mesh=mesh,
                     in_specs=(P("shards"), P("shards"), P()),
                     out_specs=P(), check_rep=False)(
                         global_cols, perm, jnp.asarray(subject, jnp.int32))


@partial(jax.jit, static_argnames=("mesh",))
def mesh_cube_combine(partials: jax.Array, *, mesh) -> jax.Array:
    """psum the resident (D, N_MEASURES, B*S*A) sharded partial cubes into
    the replicated merged cube — the only data that moves is the cube
    itself (columns stay put), so a warm query after scatter-add updates
    costs one small collective."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def _device(p):
        return jax.lax.psum(p[0], "shards")

    return shard_map(_device, mesh=mesh, in_specs=(P("shards"),),
                     out_specs=P(), check_rep=False)(partials)
