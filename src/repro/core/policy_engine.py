"""Generic policy engine (C5, C7, C10) — robinhood v3 plugin architecture.

A *policy* is: a **scope** (criteria restricting which entries it may ever
touch), ordered **rules** (criteria -> parameters), an **action** (plugin
callable), **triggers** (periodic / usage-watermark / manual), and run
options (sort order, rate limits, target volume/count).

This is the paper's v3 "generic policies": archive/purge/rmdir are just
shipped plugin configurations; users register custom actions the same way
(see ``plugins.py``). Watermark triggers reproduce the per-OST purge (C7):
when an OST exceeds ``high_wm``, the engine runs the policy restricted to
entries striped on that OST until usage is projected below ``low_wm``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog
from .policy import ALWAYS, Expr, parse_expr
from .types import Entry, FsType

Action = Callable[[Entry, dict], bool]   # returns True on success


@dataclasses.dataclass
class Rule:
    name: str
    condition: Expr
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PolicyDefinition:
    name: str
    action: Action
    scope: Expr = dataclasses.field(default_factory=lambda: ALWAYS)
    rules: List[Rule] = dataclasses.field(default_factory=list)
    # run behaviour
    sort_by: str = "atime"          # LRU by default, like robinhood purge
    sort_desc: bool = False
    max_actions_per_run: int = 0    # 0 = unlimited
    max_volume_per_run: int = 0     # 0 = unlimited (bytes)
    n_threads: int = 1
    dry_run: bool = False

    @classmethod
    def from_config(cls, name: str, action: Action, scope: str = "true",
                    rules: Optional[Sequence[Tuple[str, str, dict]]] = None,
                    **kw) -> "PolicyDefinition":
        """Build from string criteria — 'a few lines of configuration'."""
        pd = cls(name=name, action=action, scope=parse_expr(scope), **kw)
        for rname, cond, params in rules or []:
            pd.rules.append(Rule(rname, parse_expr(cond), params))
        return pd


@dataclasses.dataclass
class RunReport:
    policy: str
    matched: int = 0
    succeeded: int = 0
    failed: int = 0
    volume: int = 0          # bytes touched (e.g. freed / archived)
    elapsed: float = 0.0
    trigger: str = "manual"


class UsageWatermarkTrigger:
    """Per-resource usage trigger (OST / pool / HBM page pool).

    ``usage_fn()`` returns a list of (resource_key, used, capacity); when
    ``used/capacity`` exceeds ``high_pct``, the policy runs with a target of
    freeing down to ``low_pct``, restricted by ``restrict_fn(resource_key)``.
    """

    def __init__(self, usage_fn: Callable[[], List[Tuple[object, int, int]]],
                 high_pct: float, low_pct: float,
                 restrict_fn: Callable[[object], Expr]) -> None:
        self.usage_fn = usage_fn
        self.high_pct = high_pct
        self.low_pct = low_pct
        self.restrict_fn = restrict_fn

    def check(self) -> List[Tuple[object, Expr, int]]:
        """Returns (resource, extra_criteria, bytes_to_free) per firing."""
        out = []
        for key, used, cap in self.usage_fn():
            if cap <= 0:
                continue
            if 100.0 * used / cap >= self.high_pct:
                target = used - int(cap * self.low_pct / 100.0)
                out.append((key, self.restrict_fn(key), target))
        return out


class PolicyEngine:
    """Evaluates policies over the catalog and applies actions."""

    def __init__(self, catalog: Catalog, clock: Callable[[], float] = time.time
                 ) -> None:
        self.catalog = catalog
        self.clock = clock
        self.policies: Dict[str, PolicyDefinition] = {}
        self.triggers: List[Tuple[str, UsageWatermarkTrigger]] = []
        self.history: List[RunReport] = []
        self._lock = threading.Lock()

    def register(self, policy: PolicyDefinition) -> None:
        self.policies[policy.name] = policy

    def add_watermark_trigger(self, policy_name: str,
                              trigger: UsageWatermarkTrigger) -> None:
        self.triggers.append((policy_name, trigger))

    # -- matching -----------------------------------------------------------------
    def _match(self, policy: PolicyDefinition, extra: Optional[Expr],
               now: float) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        cols = self.catalog.arrays()
        mask = policy.scope.mask(cols, self.catalog.strings, now)
        if policy.rules:
            rule_mask = np.zeros_like(mask)
            for rule in policy.rules:
                rule_mask |= rule.condition.mask(cols, self.catalog.strings, now)
            mask &= rule_mask
        if extra is not None:
            mask &= extra.mask(cols, self.catalog.strings, now)
        return mask, cols

    def _rule_params(self, policy: PolicyDefinition, e: Entry, now: float) -> dict:
        for rule in policy.rules:
            if rule.condition.evaluate(e, now):
                return rule.params
        return {}

    # -- execution -----------------------------------------------------------------
    def run(self, policy_name: str, extra_criteria: Optional[Expr] = None,
            target_volume: int = 0, trigger: str = "manual") -> RunReport:
        """One policy run: match -> sort -> apply until targets met."""
        policy = self.policies[policy_name]
        now = self.clock()
        t0 = time.perf_counter()
        mask, cols = self._match(policy, extra_criteria, now)
        fids = cols["fid"][mask]
        report = RunReport(policy=policy_name, matched=int(fids.size),
                           trigger=trigger)

        if fids.size:
            sort_col = cols[policy.sort_by][mask]
            order = np.argsort(sort_col)
            if policy.sort_desc:
                order = order[::-1]
            fids = fids[order]

        budget_volume = target_volume or policy.max_volume_per_run
        budget_count = policy.max_actions_per_run

        work = list(fids.tolist())
        work_lock = threading.Lock()
        stop = threading.Event()

        def runner() -> None:
            while not stop.is_set():
                with work_lock:
                    if not work:
                        return
                    fid = work.pop(0)
                e = self.catalog.get(fid)
                if e is None:
                    continue
                params = self._rule_params(policy, e, now)
                size = e.size
                if policy.dry_run:
                    ok = True
                else:
                    try:
                        ok = policy.action(e, params)
                    except Exception:
                        ok = False
                with self._lock:
                    if ok:
                        report.succeeded += 1
                        report.volume += size
                    else:
                        report.failed += 1
                    if budget_volume and report.volume >= budget_volume:
                        stop.set()
                    if budget_count and report.succeeded >= budget_count:
                        stop.set()

        threads = [threading.Thread(target=runner, daemon=True)
                   for _ in range(max(1, policy.n_threads))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        report.elapsed = time.perf_counter() - t0
        self.history.append(report)
        return report

    def check_triggers(self) -> List[RunReport]:
        """Fire any watermark triggers whose threshold is exceeded (C7)."""
        reports = []
        for policy_name, trig in self.triggers:
            for key, extra, target in trig.check():
                reports.append(self.run(policy_name, extra_criteria=extra,
                                        target_volume=target,
                                        trigger=f"watermark:{key}"))
        return reports

    def run_all_periodic(self) -> List[RunReport]:
        return [self.run(name) for name in self.policies]
