"""Alerts (C5 §II-B2): detect 'abnormal or toxic' entries at ingest time.

Alert rules are policy criteria checked against every entry as it flows into
the catalog (entry hook) — no scan. Matching entries trigger a configurable
action: append to an alert log file, collect in memory, or call back.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

from .policy import Expr, parse_expr
from .telemetry import MetricRegistry
from .types import Entry


class AlertRule:
    def __init__(self, name: str, criteria: str,
                 action: Optional[Callable[[str, Entry], None]] = None,
                 cooldown: float = 0.0) -> None:
        self.name = name
        self.expr: Expr = parse_expr(criteria)
        self.action = action
        self.cooldown = cooldown          # per-fid re-alert suppression
        self._last_fired = {}

    def check(self, e: Entry, now: float) -> bool:
        if not self.expr.evaluate(e, now):
            return False
        last = self._last_fired.get(e.fid, 0.0)
        if self.cooldown and now - last < self.cooldown:
            return False
        self._last_fired[e.fid] = now
        return True


class AlertManager:
    """Ingest-time alert fan-out.

    The alert log is held open across fired alerts (lazy first-open,
    flushed per record so a tail sees alerts immediately) instead of
    reopened per alert — an ingest storm tripping a rule no longer pays
    an open/close syscall pair per record. Use :meth:`close` (or the
    context-manager form) to release the handle; firing after close
    reopens it. ``telemetry=`` (or :meth:`bind_telemetry`) additionally
    counts fired alerts per rule as ``alerts_fired{rule=...}``.
    """

    def __init__(self, log_path: Optional[str] = None,
                 clock: Callable[[], float] = time.time,
                 telemetry: Optional[MetricRegistry] = None) -> None:
        self.rules: List[AlertRule] = []
        self.fired: List[dict] = []
        self.log_path = log_path
        self.clock = clock
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._fh = None

    def bind_telemetry(self, registry: MetricRegistry) -> "AlertManager":
        self.telemetry = registry
        return self

    def add_rule(self, rule: AlertRule) -> None:
        self.rules.append(rule)

    def _log_handle(self):
        # lock held; lazy so a manager that never fires (or logs only in
        # memory) never touches the filesystem
        if self._fh is None and self.log_path:
            self._fh = open(self.log_path, "a", encoding="utf-8")
        return self._fh

    def close(self) -> None:
        """Release the alert-log handle (idempotent; fires reopen it)."""
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "AlertManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def on_entry(self, e: Entry) -> None:
        """Wire as ``catalog.add_entry_hook(mgr.on_entry)``."""
        now = self.clock()
        for rule in self.rules:
            if rule.check(e, now):
                rec = {"alert": rule.name, "fid": e.fid, "path": e.path,
                       "owner": e.owner, "size": e.size, "time": now}
                with self._lock:
                    self.fired.append(rec)
                    fh = self._log_handle()
                    if fh is not None:
                        fh.write(f"{now:.3f} ALERT {rule.name} "
                                 f"path={e.path} owner={e.owner} "
                                 f"size={e.size}\n")
                        fh.flush()        # a tail -f sees the alert now
                if self.telemetry is not None:
                    self.telemetry.counter(
                        "alerts_fired", help="ingest alerts fired per rule",
                        rule=rule.name).inc()
                if rule.action is not None:
                    rule.action(rule.name, e)
