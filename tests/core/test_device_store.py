"""Device-resident sharded column store: differential + refresh contracts.

In-process tests run on whatever devices exist (a 1-device ``("shards",)``
mesh on bare CPU — the mesh path must be correct there too); the
multi-device differential runs in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see conftest).
"""
import threading

import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState,
                        PolicyDefinition, PolicyEngine, parse_expr)

NOW = float(2 ** 20)          # f32-exact "now"

CONDITIONS = [
    "size > 16M",
    "size <= 4M",
    "owner == 'user1'",
    "last_access > 1000s",
    "hsm_state == archived",
    "size > 8M or owner == 'user0'",
    "not (size <= 1M or last_access <= 500s)",
]


def _shards_mesh():
    from repro.launch.mesh import make_shards_mesh
    return make_shards_mesh()


def _random_catalog(rng, n, n_shards=8):
    cat = Catalog(n_shards=n_shards)
    cat.upsert_batch([Entry(
        fid=i + 1, name=f"f{i + 1}", path=f"/p/d{i % 5}/f{i + 1}",
        type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
        size=int(rng.integers(0, 2 ** 15)) * 1024,           # f32-exact
        blocks=int(rng.integers(0, 2 ** 10)),
        owner=f"user{int(rng.integers(0, 4))}",
        group=f"grp{int(rng.integers(0, 3))}",
        hsm_state=HsmState(int(rng.integers(0, 5))),
        atime=NOW - float(rng.integers(0, 10_000)),          # f32-exact
        mtime=NOW - float(rng.integers(0, 10_000)),
    ) for i in range(n)])
    return cat


def _random_policy(rng, action):
    n_rules = int(rng.integers(1, 4))
    conds = rng.choice(len(CONDITIONS), size=n_rules, replace=False)
    return PolicyDefinition.from_config(
        name="p", action=action,
        scope=["true", "type == file"][int(rng.integers(0, 2))],
        rules=[(f"r{i}", CONDITIONS[int(c)], {"tag": f"r{i}"})
               for i, c in enumerate(conds)],
        sort_by=["atime", "size", "mtime"][int(rng.integers(0, 3))],
        sort_desc=bool(rng.integers(0, 2)),
        n_threads=1, batch_size=64, mutates=False)


class BatchRecorder:
    def __init__(self):
        self.lock = threading.Lock()
        self.calls = []

        def action_batch(batch, params):
            with self.lock:
                self.calls.extend(batch.fids.tolist())
            return [True] * len(batch)

        self.action_batch = action_batch

    def __call__(self, e, params):
        with self.lock:
            self.calls.append(e.fid)
        return True


def _engine_with_store(cat, policy, clock_t=NOW):
    eng = PolicyEngine(cat, clock=lambda: clock_t)
    eng.register(policy)
    eng.attach_device_store(DeviceColumnStore(cat, _shards_mesh()))
    return eng


# -- differential: mesh == single-launch == numpy -----------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mesh_matches_numpy_and_single_launch(seed):
    rng = np.random.default_rng(seed)
    cat = _random_catalog(rng, 500)
    results = {}
    for evaluator in ("numpy", "policy_scan", "policy_scan_mesh"):
        rec = BatchRecorder()
        policy = _random_policy(np.random.default_rng(seed + 100), rec)
        eng = _engine_with_store(cat, policy)
        r = eng.run("p", evaluator=evaluator)
        assert r.evaluator == evaluator, r.fallback_reason
        assert r.fallback_reason == ""
        results[evaluator] = (r.matched, r.succeeded, r.volume,
                              list(rec.calls))
    assert results["policy_scan_mesh"] == results["numpy"]
    assert results["policy_scan"] == results["numpy"]


@pytest.mark.parametrize("seed", [0, 1])
def test_mesh_differential_across_churn_rounds(seed):
    """Warm store (delta-scatter refreshed) keeps actioning the exact
    sequence a cold numpy scan of the same catalog state produces."""
    rng = np.random.default_rng(seed + 50)
    cat = _random_catalog(rng, 600)
    rec = BatchRecorder()
    policy = _random_policy(np.random.default_rng(seed + 150), rec)
    eng = _engine_with_store(cat, policy)
    eng.run("p", evaluator="policy_scan_mesh")       # cold upload
    store = eng.device_store
    live = np.arange(1, 601)
    for round_i in range(3):
        upd = rng.choice(live, size=40, replace=False)
        cat.update_fields_batch(
            upd.tolist(), size=int(rng.integers(0, 2 ** 15)) * 1024,
            atime=NOW - float(rng.integers(0, 10_000)))
        before = store.delta_refreshes
        rec.calls.clear()
        r_mesh = eng.run("p", evaluator="policy_scan_mesh")
        mesh_calls = list(rec.calls)
        assert store.delta_refreshes > before     # warm path: scatter, not restack
        rec.calls.clear()
        r_np = eng.run("p", evaluator="numpy")
        assert r_mesh.matched == r_np.matched
        assert mesh_calls == list(rec.calls), f"round {round_i}"


# -- refresh modes ------------------------------------------------------------

def test_scatter_refresh_equals_cold_upload_after_churn():
    rng = np.random.default_rng(7)
    cat = _random_catalog(rng, 400)
    expr = parse_expr("size > 8M and last_access > 2000s")
    warm = DeviceColumnStore(cat, _shards_mesh())
    warm.refresh()                                   # cold upload now
    upd = rng.choice(np.arange(1, 401), size=30, replace=False)
    cat.update_fields_batch(upd.tolist(), size=100 << 20, atime=NOW - 5000.0)
    fids_warm, agg_warm = warm.scan(expr, NOW)
    assert warm.delta_refreshes > 0 and warm.rows_scattered >= 30
    cold = DeviceColumnStore(cat, _shards_mesh())    # fresh: full upload
    fids_cold, agg_cold = cold.scan(expr, NOW)
    assert cold.delta_refreshes == 0 and cold.full_uploads > 0
    assert sorted(fids_warm.tolist()) == sorted(fids_cold.tolist())
    assert agg_warm["count"] == agg_cold["count"]
    assert agg_warm["volume"] == agg_cold["volume"]


def test_add_remove_rows_forces_full_reupload():
    rng = np.random.default_rng(9)
    cat = _random_catalog(rng, 300)
    expr = parse_expr("size > 1M")
    store = DeviceColumnStore(cat, _shards_mesh())
    store.scan(expr, NOW)
    uploads0 = store.full_uploads
    cat.remove(11)
    cat.upsert(Entry(fid=5001, name="n", path="/p/n", type=FsType.FILE,
                     size=64 << 20, atime=NOW - 100.0))
    fids, _ = store.scan(expr, NOW)
    assert store.full_uploads > uploads0             # structural fallback
    ref = cat.arrays()
    ref_fids = ref["fid"][expr.mask(ref, cat.strings, NOW)]
    assert sorted(fids.tolist()) == sorted(ref_fids.tolist())
    assert 11 not in fids.tolist() and 5001 in fids.tolist()


def test_churn_threshold_falls_back_to_full_upload():
    rng = np.random.default_rng(11)
    cat = _random_catalog(rng, 200)
    store = DeviceColumnStore(cat, _shards_mesh(), refresh_frac=0.05)
    store.refresh()
    # churn far above 5% of every group's rows
    cat.update_fields_batch(list(range(1, 150)), size=99 << 20)
    stats = store.refresh()
    assert stats["delta"] == 0 and stats["full"] > 0
    fids, _ = store.scan(parse_expr("size > 90M"), NOW)
    assert sorted(fids.tolist()) == list(range(1, 150))


def test_growth_repads_and_stays_correct():
    rng = np.random.default_rng(13)
    cat = _random_catalog(rng, 100)
    store = DeviceColumnStore(cat, _shards_mesh(), tile=128)
    store.refresh()
    rp0 = store._rp
    cat.upsert_batch([Entry(fid=10_000 + i, name=f"g{i}", path=f"/p/g{i}",
                            type=FsType.FILE, size=2 << 20,
                            atime=NOW - 10.0) for i in range(3000)])
    fids, _ = store.scan(parse_expr("size > 1M"), NOW)
    assert store._rp > rp0
    ref = cat.arrays()
    ref_fids = ref["fid"][parse_expr("size > 1M").mask(ref, cat.strings, NOW)]
    assert sorted(fids.tolist()) == sorted(ref_fids.tolist())


def test_fresh_store_skips_upload_when_quiet():
    cat = _random_catalog(np.random.default_rng(15), 150)
    store = DeviceColumnStore(cat, _shards_mesh())
    store.refresh()
    stats = store.refresh()                          # no churn in between
    assert stats == {"full": 0, "delta": 0,
                     "fresh": store.n_devices, "padded": 0}


# -- ops-layer routing --------------------------------------------------------

def test_scan_catalog_routes_through_store():
    from repro.kernels.policy_scan.ops import scan_catalog
    cat = _random_catalog(np.random.default_rng(17), 250)
    expr = parse_expr("size > 4M and last_access > 1000s")
    store = DeviceColumnStore(cat, _shards_mesh())
    fids_store, agg_store = scan_catalog(cat, expr, NOW, store=store)
    fids_up, agg_up = scan_catalog(cat, expr, NOW, use_kernel=False)
    assert sorted(fids_store.tolist()) == sorted(fids_up.tolist())
    assert agg_store["count"] == agg_up["count"]
    assert agg_store["volume"] == agg_up["volume"]
    assert agg_store["size_profile"] == agg_up["size_profile"]


def test_match_programs_mesh_agrees_with_match_programs():
    from repro.core.policy import all_of, any_of
    from repro.kernels.policy_scan.ops import (match_programs,
                                               match_programs_mesh)
    rng = np.random.default_rng(19)
    cat = _random_catalog(rng, 350)
    policy = _random_policy(np.random.default_rng(20), None)
    rule_exprs = [r.condition for r in policy.rules]
    exprs = [all_of([policy.scope, any_of(rule_exprs)])] + rule_exprs
    store = DeviceColumnStore(cat, _shards_mesh())
    mesh = match_programs_mesh(store, exprs, NOW)
    masks, agg, rule_idx = match_programs(cat.arrays(), exprs, cat.strings,
                                          NOW, use_kernel=False)
    fids, sizes, _sort, ridx = mesh.plan(policy.sort_by)
    arrays = cat.arrays()
    ref_fids = arrays["fid"][masks[0]]
    order = np.argsort(fids)
    ref_order = np.argsort(ref_fids)
    np.testing.assert_array_equal(fids[order], ref_fids[ref_order])
    np.testing.assert_array_equal(sizes[order],
                                  arrays["size"][masks[0]][ref_order])
    np.testing.assert_array_equal(ridx[order],
                                  rule_idx[masks[0]][ref_order])
    assert mesh.agg["count"] == agg["count"]
    assert mesh.agg["rule_count"] == agg["rule_count"]


def test_store_rejects_foreign_catalog_and_missing_axis():
    from repro.core.policy import PolicyError
    cat = _random_catalog(np.random.default_rng(23), 50)
    other = _random_catalog(np.random.default_rng(24), 50)
    eng = PolicyEngine(cat)
    store = DeviceColumnStore(other, _shards_mesh())
    with pytest.raises(PolicyError):
        eng.attach_device_store(store)
    from repro.launch.mesh import make_mesh
    with pytest.raises(PolicyError):
        DeviceColumnStore(cat, make_mesh((1,), ("data",)))


# -- multi-device (subprocess: 8 fake XLA devices) ----------------------------

@pytest.mark.slow
def test_mesh_differential_on_eight_devices():
    out = run_subprocess("""
import numpy as np
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType,
                        PolicyDefinition, PolicyEngine)
from repro.launch.mesh import make_shards_mesh

NOW = float(2 ** 20)
rng = np.random.default_rng(0)
cat = Catalog(n_shards=16)
cat.upsert_batch([Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                        type=FsType.FILE,
                        size=int(rng.integers(0, 2 ** 15)) * 1024,
                        owner=f"user{i % 4}",
                        atime=NOW - float(rng.integers(0, 10_000)))
                  for i in range(3000)])
acted = []
def act(e, p): return True
act.action_batch = lambda b, p: (acted.extend(b.fids.tolist()),
                                 [True] * len(b))[1]
eng = PolicyEngine(cat, clock=lambda: NOW)
eng.register(PolicyDefinition.from_config(
    name="p", action=act, scope="type == file",
    rules=[("big", "size > 16M", {}), ("cold", "last_access > 5000s", {})],
    sort_by="atime", mutates=False))
mesh = make_shards_mesh(8)
assert mesh.devices.size == 8
store = DeviceColumnStore(cat, mesh)
eng.attach_device_store(store)
r = eng.run("p", evaluator="policy_scan_mesh")
assert r.evaluator == "policy_scan_mesh" and not r.fallback_reason
mesh_calls = list(acted); acted.clear()
rn = eng.run("p", evaluator="numpy")
assert r.matched == rn.matched and mesh_calls == acted
# warm delta refresh on every device's group
cat.update_fields_batch(list(range(1, 3000, 37)), size=200 << 20)
acted.clear()
r2 = eng.run("p", evaluator="policy_scan_mesh")
assert store.delta_refreshes == 8        # every group scattered, none restacked
mesh_calls = list(acted); acted.clear()
eng.run("p", evaluator="numpy")
assert mesh_calls == acted
# kernel (interpret) under shard_map agrees too
fids_k, _ = store.scan(__import__("repro.core",
                                  fromlist=["parse_expr"]).parse_expr(
    "size > 16M"), NOW, use_kernel=True)
fids_r, _ = store.scan(__import__("repro.core",
                                  fromlist=["parse_expr"]).parse_expr(
    "size > 16M"), NOW, use_kernel=False)
assert sorted(fids_k.tolist()) == sorted(fids_r.tolist())
print("OK", r.matched)
""")
    assert "OK" in out


# -- review regressions -------------------------------------------------------

def test_sort_by_fid_plans_and_parent_fid_falls_back():
    """fid is a valid mirror sort key; parent_fid (not mirrored) must
    degrade to the host path with a recorded reason, not crash."""
    cat = _random_catalog(np.random.default_rng(31), 200)
    rec = BatchRecorder()
    policy = PolicyDefinition.from_config(
        name="p", action=rec, scope="type == file",
        rules=[("any", "size >= 0", {})], sort_by="fid", mutates=False)
    eng = _engine_with_store(cat, policy)
    r = eng.run("p", evaluator="policy_scan_mesh")
    assert r.evaluator == "policy_scan_mesh" and not r.fallback_reason
    mesh_calls = list(rec.calls)
    rec.calls.clear()
    eng.run("p", evaluator="numpy")
    assert mesh_calls == rec.calls
    policy2 = PolicyDefinition.from_config(
        name="q", action=rec, scope="type == file",
        rules=[("any", "size >= 0", {})], sort_by="parent_fid",
        mutates=False)
    eng.register(policy2)
    r2 = eng.run("q", evaluator="policy_scan_mesh")
    assert r2.evaluator in ("policy_scan", "numpy")
    assert "policy_scan_mesh->" in r2.fallback_reason
    assert "sort_by" in r2.fallback_reason


def test_stale_mesh_match_plan_raises():
    from repro.core.policy import PolicyError
    cat = _random_catalog(np.random.default_rng(33), 150)
    store = DeviceColumnStore(cat, _shards_mesh())
    match = store.match([parse_expr("size >= 0")], NOW)
    cat.update_fields_batch([1, 2, 3], size=77 << 20)
    store.refresh()                      # mirrors mutated since the match
    with pytest.raises(PolicyError, match="stale"):
        match.plan("size")
    # a fresh match plans fine again
    store.match([parse_expr("size >= 0")], NOW).plan("size")


def test_scan_catalog_rejects_mismatched_store():
    from repro.core.policy import PolicyError
    from repro.kernels.policy_scan.ops import scan_catalog
    cat = _random_catalog(np.random.default_rng(35), 60)
    other = _random_catalog(np.random.default_rng(36), 60)
    store = DeviceColumnStore(other, _shards_mesh())
    with pytest.raises(PolicyError, match="different catalog"):
        scan_catalog(cat, parse_expr("size >= 0"), NOW, store=store)


def test_incremental_run_records_requested_evaluator_override():
    cat = _random_catalog(np.random.default_rng(37), 120)
    rec = BatchRecorder()
    policy = PolicyDefinition.from_config(
        name="p", action=rec, scope="type == file",
        rules=[("any", "size >= 0", {})], sort_by="atime", mutates=False)
    eng = _engine_with_store(cat, policy)
    eng.enable_incremental()
    eng.run("p")                                   # prime the cache
    eng.mark_dirty([1])
    r = eng.run("p", evaluator="policy_scan_mesh", matching="incremental")
    assert r.mode == "incremental" and r.evaluator == "numpy"
    assert "policy_scan_mesh->incremental" in r.fallback_reason


def test_trajectory_creates_missing_dir(tmp_path):
    import sys
    sys.path.insert(0, "/root/repo")
    from benchmarks.run import _append_trajectory
    out = tmp_path / "nested" / "traj"
    path = _append_trajectory(str(out), "bench_policy",
                              [("row", 1.0, "d")], True, 0.5)
    import json
    data = json.load(open(path))
    assert data["suite"] == "benchmarks.bench_policy"
    assert len(data["entries"]) == 1
    # appending accumulates
    _append_trajectory(str(out), "bench_policy", [("row", 2.0, "d")],
                       False, 0.5)
    assert len(json.load(open(path))["entries"]) == 2


def test_detach_unregisters_hook_and_store_stays_correct():
    cat = _random_catalog(np.random.default_rng(41), 100)
    store = DeviceColumnStore(cat, _shards_mesh())
    store.refresh()
    assert store._on_delta in cat._hooks
    store.detach()
    assert store._on_delta not in cat._hooks
    cat.update_fields(1, size=99 << 20)       # no dirty intake anymore
    assert all(not g.dirty for g in store._groups)
    # matching still works: hook-less mutations force cold full uploads
    fids, _ = store.scan(parse_expr("size > 90M"), NOW)
    assert fids.tolist() == [1]
    store.detach()                             # idempotent


def test_refresh_repads_when_group_outgrows_capacity_mid_refresh():
    """A snapshot that exceeds the padded capacity (concurrent insert
    race) must re-pad and retry, not crash the stack staging."""
    from repro.core.device_store import _RepadNeeded
    cat = _random_catalog(np.random.default_rng(43), 100)
    store = DeviceColumnStore(cat, _shards_mesh(), tile=128)
    store.refresh()
    # simulate the race: capacity says _rp, but the snapshot will see more
    # rows than refresh()'s initial need-check observed
    store._rp = store.tile                 # force an undersized capacity
    for g in store._groups:
        g.uploaded = False                 # every group must re-upload
    cat.upsert_batch([Entry(fid=20_000 + i, name=f"r{i}", path=f"/p/r{i}",
                            type=FsType.FILE, size=5 << 20,
                            atime=NOW - 1.0) for i in range(2000)])
    stats = store.refresh()                # would raise before the retry fix
    assert stats["full"] == store.n_devices
    fids, _ = store.scan(parse_expr("size > 4M"), NOW)
    ref = cat.arrays()
    ref_fids = ref["fid"][parse_expr("size > 4M").mask(ref, cat.strings, NOW)]
    assert sorted(fids.tolist()) == sorted(ref_fids.tolist())
