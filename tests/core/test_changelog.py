import threading

from repro.core import ChangelogHub, ChangelogStream, ChangelogType


def test_ack_purges_and_pending():
    s = ChangelogStream()
    for fid in range(1, 6):
        s.emit(ChangelogType.CREAT, fid)
    recs = s.read(max_records=3)
    assert [r.seq for r in recs] == [1, 2, 3]
    assert s.pending() == 5         # nothing acked yet
    s.ack(3)
    assert s.pending() == 2
    recs = s.read()
    assert [r.seq for r in recs] == [4, 5]


def test_crash_redelivery_no_loss(tmp_path):
    """Paper SII-C2: unacked records survive a consumer crash."""
    d = str(tmp_path)
    s = ChangelogStream(mdt=0, persist_dir=d)
    for fid in range(1, 11):
        s.emit(ChangelogType.CREAT, fid)
    s.read(max_records=7)
    s.ack(4)                        # only 4 committed before the "crash"
    s.close()
    # restart: a fresh stream on the same dir re-delivers 5..10
    s2 = ChangelogStream(mdt=0, persist_dir=d)
    recs = s2.read(max_records=100)
    assert [r.seq for r in recs] == list(range(5, 11))
    # and new records continue the sequence
    r = s2.emit(ChangelogType.UNLNK, 99)
    assert r.seq == 11


def test_reset_cursor_redelivers():
    s = ChangelogStream()
    for fid in range(3):
        s.emit(ChangelogType.MKDIR, fid)
    s.read()
    s.ack(1)
    s.reset_cursor()
    assert [r.seq for r in s.read()] == [2, 3]


def test_named_subscribers_have_independent_cursors():
    s = ChangelogStream()
    for fid in range(1, 4):
        s.emit(ChangelogType.CREAT, fid)
    s.subscribe("engine")                  # starts at the head: future only
    s.emit(ChangelogType.CREAT, 4)
    assert [r.seq for r in s.read(subscriber="engine")] == [4]
    assert [r.seq for r in s.read()] == [1, 2, 3, 4]   # default unaffected
    s.ack(4)                               # default acks everything...
    assert s.pending() == 0
    # ...but records 4+ survive until "engine" acks too
    s.reset_cursor(subscriber="engine")
    assert [r.seq for r in s.read(subscriber="engine")] == [4]
    s.ack(4, subscriber="engine")
    assert s.pending(subscriber="engine") == 0


def test_subscribe_from_start_sees_retained_records():
    s = ChangelogStream()
    for fid in range(1, 4):
        s.emit(ChangelogType.CREAT, fid)
    s.subscribe("auditor", from_start=True)
    assert [r.seq for r in s.read(subscriber="auditor")] == [1, 2, 3]


def test_laggard_subscriber_holds_back_purge_until_unsubscribed():
    s = ChangelogStream()
    s.subscribe("slow")
    for fid in range(1, 6):
        s.emit(ChangelogType.CREAT, fid)
    s.read(max_records=100)
    s.ack(5)                               # default fully acked
    assert len(s._records) == 5            # retained for "slow"
    s.unsubscribe("slow")
    assert len(s._records) == 0            # released


def test_subscriber_acks_survive_crash(tmp_path):
    d = str(tmp_path)
    s = ChangelogStream(mdt=0, persist_dir=d)
    s.subscribe("engine", from_start=True)
    for fid in range(1, 8):
        s.emit(ChangelogType.CREAT, fid)
    s.read(max_records=100)
    s.ack(7)
    s.read(max_records=3, subscriber="engine")
    s.ack(3, subscriber="engine")
    s.close()
    # restart: both cursors recover; 4..7 redelivered to "engine" only
    s2 = ChangelogStream(mdt=0, persist_dir=d)
    assert s2.read(max_records=100) == []
    s2.subscribe("engine")
    assert [r.seq for r in s2.read(max_records=100, subscriber="engine")] \
        == [4, 5, 6, 7]
    # an unregistered crashed subscriber still holds back purge
    s2.ack(7)
    assert len(s2._records) == 4


def test_unsubscribe_after_recovery_releases_retention(tmp_path):
    d = str(tmp_path)
    s = ChangelogStream(mdt=0, persist_dir=d)
    s.subscribe("engine", from_start=True)
    for fid in range(1, 4):
        s.emit(ChangelogType.CREAT, fid)
    s.read(max_records=2, subscriber="engine")
    s.ack(2, subscriber="engine")
    s.close()
    s2 = ChangelogStream(mdt=0, persist_dir=d)
    s2.subscribe("engine")
    s2.unsubscribe("engine")           # decommissioned for good
    for fid in range(4, 10):
        s2.emit(ChangelogType.CREAT, fid)
    s2.read(max_records=100)
    s2.ack(9)
    assert len(s2._records) == 0       # stale recovered ack must not pin
    s2.close()
    s3 = ChangelogStream(mdt=0, persist_dir=d)
    assert s3.pending() == 0           # ...nor resurrect in the ack file


def test_ack_beyond_head_is_clamped():
    s = ChangelogStream()
    for fid in range(1, 4):
        s.emit(ChangelogType.CREAT, fid)
    s.ack(100)                             # overshoot: clamped to seq 3
    assert s.acked == 3
    r = s.emit(ChangelogType.CREAT, 9)     # later records are NOT swallowed
    assert r.seq == 4
    assert [x.seq for x in s.read()] == [4]


def test_hub_and_stream_close_are_idempotent(tmp_path):
    hub = ChangelogHub(n_mdts=2, persist_dir=str(tmp_path))
    hub.stream(0).emit(ChangelogType.CREAT, 1)
    hub.close()
    hub.close()                            # second close: no error
    hub.stream(1).close()                  # per-stream re-close: no error


def test_concurrent_producers_unique_seqs():
    s = ChangelogStream()

    def produce():
        for i in range(100):
            s.emit(ChangelogType.CREAT, i)

    threads = [threading.Thread(target=produce) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = s.read(max_records=1000)
    seqs = [r.seq for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == 400


def test_round_robin_bounded_lag_skew_under_single_mdt_burst():
    """Fairness: a burst on one MDT must not starve the others — every
    round-robin sweep serves each MDT up to one quantum, so a trickle
    stream's backlog stays bounded by (quantum + its per-sweep arrivals)
    for the whole time the burst is draining."""
    hub = ChangelogHub(n_mdts=4)
    q = 64
    for i in range(40 * q):                       # 40-quantum burst, MDT 0
        hub.stream(0).emit(ChangelogType.CREAT, i + 1)
    for m in (1, 2, 3):
        for i in range(8):
            hub.stream(m).emit(ChangelogType.CLOSE, i + 1)

    sweeps = 0
    while hub.total_pending():
        batches = hub.read_round_robin(quantum=q)
        assert batches, "pending records but an empty sweep"
        served = {cb.mdt for cb in batches}
        for cb in batches:
            hub.stream(cb.mdt).ack(int(cb.seq[-1]))
        sweeps += 1
        if sweeps <= 3:
            # while the burst is hot, every trickle MDT with pending
            # records was served in the same sweep (no starvation)
            assert served == {0, 1, 2, 3}
        for m in (1, 2, 3):
            # bounded lag skew: the trickle streams never accumulate
            # more than one quantum of backlog behind the burst
            assert hub.stream(m).pending() <= q, \
                f"mdt{m} starved behind the mdt0 burst"
        if sweeps <= 10:                          # live trickle continues
            for m in (1, 2, 3):
                hub.stream(m).emit(ChangelogType.CLOSE, 100 + sweeps)
        assert sweeps < 200
    assert sweeps >= 40                           # burst took many sweeps


def test_round_robin_rotates_start_mdt():
    """The sweep's starting MDT rotates so no stream is permanently
    first in line for the quantum."""
    hub = ChangelogHub(n_mdts=3)
    for m in range(3):
        for i in range(6):
            hub.stream(m).emit(ChangelogType.CREAT, i + 1)
    firsts = []
    for _ in range(3):
        batches = hub.read_round_robin(quantum=2)
        firsts.append(batches[0].mdt)
        for cb in batches:
            hub.stream(cb.mdt).ack(int(cb.seq[-1]))
    assert len(set(firsts)) == 3


def test_read_columnar_matches_read():
    s = ChangelogStream()
    for fid in range(1, 9):
        s.emit(ChangelogType.CREAT if fid % 2 else ChangelogType.UNLNK, fid)
    cb = s.read_columnar(max_records=5)
    assert cb is not None and len(cb) == 5
    assert cb.seq.tolist() == [1, 2, 3, 4, 5]
    assert cb.fid.tolist() == [1, 2, 3, 4, 5]
    assert cb.type.tolist() == [int(ChangelogType.CREAT),
                                int(ChangelogType.UNLNK),
                                int(ChangelogType.CREAT),
                                int(ChangelogType.UNLNK),
                                int(ChangelogType.CREAT)]
    assert [r.seq for r in cb.records] == [1, 2, 3, 4, 5]
    assert s.read_columnar(max_records=5).seq.tolist() == [6, 7, 8]
    assert s.read_columnar(max_records=5, timeout=0.0) is None
