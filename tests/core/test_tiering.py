"""Tiered residency: placement under an HBM row budget, warm-segment
streaming, and byte-identity with the all-resident store.

The contract under test: a `DeviceColumnStore` with ``hbm_budget_rows``
set answers **every** query (match/scan, find_paths, top_files, du,
analytics_cube — scoped and unscoped) byte-identically to an unbudgeted
store over the same catalog, while holding only the placement-chosen
groups resident and streaming the demoted groups' packed segments
through the double-buffered device window.

In-process tests run on the 1-device mesh (which exercises the
zero-resident streaming branch — everything demoted); the mixed
residency differential runs in a subprocess with 8 fake XLA devices.
"""
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState,
                        PolicyDefinition, PolicyEngine, parse_expr)
from repro.core.grants import GrantTable
from repro.core.profiles import GroupIndex

NOW = float(2 ** 20)          # f32-exact "now"


def _shards_mesh():
    from repro.launch.mesh import make_shards_mesh
    return make_shards_mesh()


def _random_catalog(rng, n, n_shards=8):
    cat = Catalog(n_shards=n_shards)
    cat.upsert_batch([Entry(
        fid=i + 1, name=f"f{i + 1}", path=f"/p/d{i % 5}/f{i + 1}",
        type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
        size=int(rng.integers(0, 2 ** 12)) * 1024,           # f32-exact
        blocks=int(rng.integers(0, 2 ** 10)),
        owner=f"user{int(rng.integers(0, 4))}",
        group=f"grp{int(rng.integers(0, 3))}",
        hsm_state=HsmState(int(rng.integers(0, 5))),
        atime=NOW - float(rng.integers(0, 10_000)),          # f32-exact
        mtime=NOW - float(rng.integers(0, 10_000)),
    ) for i in range(n)])
    return cat


def _full_setup(store, gi):
    store.enable_reports_plane()
    store.enable_cube_plane(gi, clock=lambda: NOW)
    grants = GrantTable()
    grants.add_subject("user1")
    grants.add_subject("aud", owners=(), subtrees=("/p/d2",))
    store.enable_permissions_plane(grants)


# -- zero-resident streaming (1-device in-process mesh) -----------------------

def _pair(rng_seed=0, n=600, **tier_kw):
    """(reference store, tiered store) over one catalog + shared planes."""
    cat = _random_catalog(np.random.default_rng(rng_seed), n)
    gi = GroupIndex()
    ref = DeviceColumnStore(cat, _shards_mesh(), tile=128)
    _full_setup(ref, gi)
    tier = DeviceColumnStore(cat, _shards_mesh(), tile=128, **tier_kw)
    _full_setup(tier, gi)
    return cat, ref, tier


def test_streaming_matches_resident_store_all_queries():
    # budget below one padded block: every group demotes, all queries
    # stream (the window reserve is carved out of the budget, so this
    # also covers "budget smaller than the reserve")
    cat, ref, tier = _pair(hbm_budget_rows=256, window_rows=128)
    expr = parse_expr("size > 1M and last_access > 1000s")
    f_ref, a_ref = ref.scan(expr, NOW)
    f_t, a_t = tier.scan(expr, NOW)
    assert tier.demotions >= 1
    assert tier.tiering_counters()["resident_groups"] == 0
    assert sorted(f_ref.tolist()) == sorted(f_t.tolist())
    assert a_ref == a_t
    for subj in (None, "user1", "aud"):
        assert (ref.find_paths(expr, NOW, subject=subj)
                == tier.find_paths(expr, NOW, subject=subj))
        assert np.array_equal(ref.analytics_cube(NOW, subject=subj),
                              tier.analytics_cube(NOW, subject=subj))
    for by in ("size", "atime"):
        assert (ref.top_files(by=by, k=7, now=NOW)
                == tier.top_files(by=by, k=7, now=NOW))
    for pref in ("/p", "/p/d2"):
        for subj in (None, "aud"):
            assert ref.du(pref, subject=subj) == tier.du(pref, subject=subj)
    tc = tier.tiering_counters()
    assert tc["segments_streamed"] > 0 and tc["windows_streamed"] > 0


def test_streamed_match_survives_churn_and_repack():
    cat, ref, tier = _pair(rng_seed=3, hbm_budget_rows=256, window_rows=128)
    expr = parse_expr("size > 2M")
    rng = np.random.default_rng(99)
    for _ in range(3):
        upd = rng.choice(np.arange(1, 601), size=50, replace=False)
        cat.update_fields_batch(upd.tolist(),
                                size=int(rng.integers(1, 2 ** 12)) * 1024,
                                atime=NOW - 321.0)
        f_ref, a_ref = ref.scan(expr, NOW)
        f_t, a_t = tier.scan(expr, NOW)
        assert sorted(f_ref.tolist()) == sorted(f_t.tolist())
        assert a_ref == a_t
        assert np.array_equal(ref.analytics_cube(NOW),
                              tier.analytics_cube(NOW))
    assert tier.segment_repacks >= 1      # churned segments re-encoded


def test_unlimited_budget_never_demotes():
    cat, ref, tier = _pair(rng_seed=5, hbm_budget_rows=None)
    f_ref, _ = ref.scan(parse_expr("size > 4M"), NOW)
    f_t, _ = tier.scan(parse_expr("size > 4M"), NOW)
    assert sorted(f_ref.tolist()) == sorted(f_t.tolist())
    assert tier.demotions == 0 and tier.segments_streamed == 0
    assert tier.tiering_counters()["demoted_groups"] == 0


def test_async_demote_commits_and_stays_correct():
    cat, ref, tier = _pair(rng_seed=7, hbm_budget_rows=256,
                           window_rows=128, demote_async=True)
    expr = parse_expr("size > 1M")
    f0, a0 = tier.scan(expr, NOW)         # launches the async pack
    tier.drain_demotions()
    f1, a1 = tier.scan(expr, NOW)         # served from the segment now
    f_ref, a_ref = ref.scan(expr, NOW)
    assert sorted(f1.tolist()) == sorted(f_ref.tolist()) \
        == sorted(f0.tolist())
    assert a1 == a_ref == a0
    assert tier.demotions >= 1 and tier.segments_streamed > 0


def test_segment_persists_beside_sqlite_mirror(tmp_path):
    db = str(tmp_path / "cat.db")
    cat = Catalog(n_shards=8, db_path=db)
    cat.upsert_batch([Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                            type=FsType.FILE, size=(i % 7) << 20,
                            atime=NOW - 50.0) for i in range(300)])
    store = DeviceColumnStore(cat, _shards_mesh(), tile=128,
                              hbm_budget_rows=128, window_rows=128)
    fids, _ = store.scan(parse_expr("size > 3M"), NOW)
    import os
    segs = [f for f in os.listdir(tmp_path) if ".seg" in f]
    assert store.demotions >= 1 and segs, segs
    ref = cat.arrays()
    want = ref["fid"][parse_expr("size > 3M").mask(ref, cat.strings, NOW)]
    assert sorted(fids.tolist()) == sorted(want.tolist())


def test_run_report_surfaces_tiering_counters():
    cat = _random_catalog(np.random.default_rng(21), 400)
    calls = []

    def act(e, p):
        return True
    act.action_batch = lambda b, p: (calls.extend(b.fids.tolist()),
                                     [True] * len(b))[1]
    eng = PolicyEngine(cat, clock=lambda: NOW)
    eng.register(PolicyDefinition.from_config(
        name="p", action=act, scope="type == file",
        rules=[("big", "size > 2M", {})], sort_by="atime", mutates=False))
    eng.attach_device_store(DeviceColumnStore(
        cat, _shards_mesh(), tile=128, hbm_budget_rows=256,
        window_rows=128))
    r = eng.run("p", evaluator="policy_scan_mesh")
    assert r.evaluator == "policy_scan_mesh" and not r.fallback_reason
    assert r.tiering["demotions"] >= 1
    assert r.tiering["segments_streamed"] > 0
    assert r.tiering["resident_groups"] == 0
    calls_mesh = list(calls)
    calls.clear()
    rn = eng.run("p", evaluator="numpy")
    assert rn.tiering == {}               # host path: no store involved
    assert r.matched == rn.matched and calls_mesh == calls


def test_reports_facade_exposes_tiering_counters():
    from repro.core.reports import Reports
    cat = _random_catalog(np.random.default_rng(23), 300)
    rep = Reports(cat, clock=lambda: NOW)
    assert rep.tiering_counters() == {}
    rep.attach_device_store(DeviceColumnStore(
        cat, _shards_mesh(), tile=128, hbm_budget_rows=256,
        window_rows=128))
    paths = rep.find("size > 4M")
    assert rep.store_served >= 1 and rep.last_fallback_reason is None
    tc = rep.tiering_counters()
    assert tc["demotions"] >= 1 and tc["segments_streamed"] > 0
    ref = cat.arrays()
    mask = parse_expr("size > 4M").mask(ref, cat.strings, NOW)
    assert len(paths) == int(mask.sum())


# -- grants: unknown-subject diagnostics (satellite) --------------------------

def test_unknown_subject_error_names_known_subjects():
    g = GrantTable()
    with pytest.raises(KeyError, match="<none registered>"):
        g.subject_id("ghost")
    g.add_subject("alice")
    g.add_subject("bob")
    with pytest.raises(KeyError, match="alice, bob") as ei:
        g.subject("ghost")
    assert "unknown subject 'ghost'" in str(ei.value)


# -- mixed residency + placement (subprocess: 8 fake XLA devices) -------------

@pytest.mark.slow
def test_mixed_residency_differential_on_eight_devices():
    out = run_subprocess("""
import numpy as np
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType,
                        parse_expr)
from repro.core.grants import GrantTable
from repro.core.profiles import GroupIndex
from repro.launch.mesh import make_shards_mesh

NOW = float(2 ** 20)
rng = np.random.default_rng(0)
cat = Catalog(n_shards=8)
cat.upsert_batch([Entry(
    fid=i + 1, name=f"f{i}", path=f"/p/d{i % 5}/f{i}", type=FsType.FILE,
    size=int(rng.integers(0, 2 ** 12)) * 1024,
    blocks=int(rng.integers(0, 2 ** 10)),
    owner=f"user{i % 4}", group=f"grp{i % 3}",
    atime=NOW - float(rng.integers(0, 10_000)))
    for i in range(2000)])
gi = GroupIndex()
def setup(store):
    store.enable_reports_plane()
    store.enable_cube_plane(gi, clock=lambda: NOW)
    grants = GrantTable(); grants.add_subject("user1")
    grants.add_subject("aud", owners=(), subtrees=("/p/d2",))
    store.enable_permissions_plane(grants)
ref = DeviceColumnStore(cat, make_shards_mesh(8), tile=128)
setup(ref)
# 2000 rows / 8 groups -> rp 384; budget 3000 holds 2 resident blocks
# plus the 2*8*128 window reserve -> mixed residency
tier = DeviceColumnStore(cat, make_shards_mesh(8), tile=128,
                         hbm_budget_rows=3000, window_rows=128)
setup(tier)
expr = parse_expr("size > 1M and last_access > 1000s")
f_ref, a_ref = ref.scan(expr, NOW)
f_t, a_t = tier.scan(expr, NOW)
tc = tier.tiering_counters()
assert 0 < tc["resident_groups"] < 8, tc     # genuinely mixed
assert sorted(f_ref.tolist()) == sorted(f_t.tolist())
assert a_ref == a_t
for subj in (None, "user1", "aud"):
    assert (ref.find_paths(expr, NOW, subject=subj)
            == tier.find_paths(expr, NOW, subject=subj)), subj
    assert np.array_equal(ref.analytics_cube(NOW, subject=subj),
                          tier.analytics_cube(NOW, subject=subj)), subj
for by in ("size", "atime"):
    for subj in (None, "user1"):
        assert (ref.top_files(by=by, k=9, now=NOW, subject=subj)
                == tier.top_files(by=by, k=9, now=NOW, subject=subj))
for pref in ("/p", "/p/d2"):
    for subj in (None, "aud"):
        assert ref.du(pref, subject=subj) == tier.du(pref, subject=subj)
# heat-driven promotion: churn one demoted group hard, watch it return
# (shard = fid % n_shards; 8 shards over 8 devices puts shard g in
# group g, so the demoted group's rows are the fids congruent to it)
demoted = [g.gid for g in tier._groups if not g.resident]
target_shard = tier._groups[demoted[0]].shard_ids[0]
victim_fids = [f for f in range(1, 2001) if f % 8 == target_shard][:200]
for _ in range(3):
    cat.update_fields_batch(victim_fids,
                            size=int(rng.integers(1, 2 ** 12)) * 1024)
    tier.scan(expr, NOW)
assert tier.promotions >= 1, tier.tiering_counters()
f_ref2, a_ref2 = ref.scan(expr, NOW)
f_t2, a_t2 = tier.scan(expr, NOW)
assert sorted(f_ref2.tolist()) == sorted(f_t2.tolist()) and a_ref2 == a_t2
assert np.array_equal(ref.analytics_cube(NOW), tier.analytics_cube(NOW))
print("OK", len(f_t2), tier.tiering_counters())
""")
    assert "OK" in out


@pytest.mark.slow
def test_growth_repads_only_grown_group_on_eight_devices():
    """Satellite regression: growing ONE shard group must not re-upload
    the untouched groups — their device blocks are widened in place
    (same buffer donated through _pad_block, no host->device copy of the
    column data) and keep serving byte-identical results."""
    out = run_subprocess("""
import numpy as np
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType,
                        parse_expr)
from repro.launch.mesh import make_shards_mesh

NOW = float(2 ** 20)
rng = np.random.default_rng(1)
cat = Catalog(n_shards=8)
cat.upsert_batch([Entry(fid=i + 1, name=f"f{i}", path=f"/p/f{i}",
                        type=FsType.FILE,
                        size=int(rng.integers(0, 2 ** 12)) * 1024,
                        atime=NOW - float(rng.integers(0, 10_000)))
                  for i in range(800)])
store = DeviceColumnStore(cat, make_shards_mesh(8), tile=128)
store.refresh()
rp0 = store._rp
full0 = store.full_uploads
# shard = fid % 8 and shard s lives in group s: fids congruent to 0
# grow ONLY group 0, far past the padded capacity
grown_gid = 0
cat.upsert_batch([Entry(fid=100_000 + 8 * i, name=f"g{i}",
                        path=f"/p/g{i}", type=FsType.FILE,
                        size=2 << 20, atime=NOW - 10.0)
                  for i in range(2000)])
before = {g.gid: store._bufs[g.gid] for g in store._groups}
stats = store.refresh()
assert store._rp > rp0
# exactly one group re-uploaded; the others were padded on-device
assert store.full_uploads == full0 + 1, stats
assert store.device_pads >= 7 and stats["padded"] >= 7, stats
untouched = [g.gid for g in store._groups if g.gid != grown_gid]
# identity: a padded block is the SAME donated buffer widened, never a
# fresh host upload (jnp.pad donates, so identity does change, but the
# mirror columns must not have been re-staged: full == 1 proves that);
# cheap extra guard: no other group went stale
assert all(store._groups[g].uploaded for g in untouched)
fids, _ = store.scan(parse_expr("size > 1M"), NOW)
ref = cat.arrays()
want = ref["fid"][parse_expr("size > 1M").mask(ref, cat.strings, NOW)]
assert sorted(fids.tolist()) == sorted(want.tolist())
print("OK", stats)
""")
    assert "OK" in out
