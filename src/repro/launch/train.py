"""Production training launcher.

Wires mesh -> sharding rules -> model -> data pipeline -> train step ->
Robinhood-managed checkpoints -> restart driver. Works from 1 CPU device
(mesh 1x1) up to the 512-chip production mesh (same code path the dry-run
compiles).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b \
        --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ALIASES, get_config
from repro.data import DataPipeline
from repro.models import Model
from repro.optim import AdamW, cosine_warmup
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import run_with_restarts
from repro.runtime.sharding import ShardingRules, profile_for
from repro.train import init_train_state, make_train_step


def make_mesh(shape_str: str) -> Mesh:
    dims = tuple(int(x) for x in shape_str.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) <= 3 else None
    devs = np.array(jax.devices()[: int(np.prod(dims))]).reshape(dims)
    return Mesh(devs, axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1x1",
                    help='mesh shape, e.g. "16x16" or "2x16x16"')
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-interval", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg, kv_chunk=min(1024, args.seq))
    opt = AdamW(lr=cosine_warmup(args.lr, args.steps // 10 + 1, args.steps),
                weight_decay=0.01)
    mesh = make_mesh(args.mesh)
    rules = ShardingRules(cfg, mesh, profile_for(cfg))
    pipe = DataPipeline(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch, seed=args.seed)
    cm = CheckpointManager(args.ckpt_dir, keep_last=3, archive_every=0)

    step_fn = jax.jit(make_train_step(model, opt))
    t_start = time.time()
    tokens_per_step = args.batch * args.seq
    history = []

    def init_state():
        return init_train_state(model, opt, jax.random.PRNGKey(args.seed))

    def one_step(state, step):
        b = pipe.batch_for(step)
        toks = jnp.asarray(b["tokens"]).reshape(
            args.accum, args.batch // args.accum, args.seq)
        labels = jnp.asarray(b["labels"]).reshape(
            args.accum, args.batch // args.accum, args.seq)
        state, metrics = step_fn(state, {"tokens": toks, "labels": labels})
        loss = float(metrics["loss"])
        history.append(loss)
        if step % args.log_interval == 0:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"({(step + 1) * tokens_per_step / dt:.0f} tok/s)",
                  flush=True)
        return state

    with mesh:
        final, restarts, replayed = run_with_restarts(
            train_steps=args.steps, step_fn=one_step,
            init_state=init_state, ckpt=cm,
            ckpt_interval=args.ckpt_interval)
    print(f"done: {args.steps} steps, restarts={restarts}, "
          f"first-10 loss {np.mean(history[:10]):.4f} -> "
          f"last-10 loss {np.mean(history[-10:]):.4f}")
    print(f"checkpoints: {cm.steps()} (+cold {cm.steps(True)})")
    print(f"artifact catalog: {cm.store.usage()}")


if __name__ == "__main__":
    main()
