"""Launch layer: production meshes, dry-run driver, roofline analysis."""
