"""rbh-report / rbh-find / rbh-du clones + alerts + plugins (C5/C9/C10)."""
import time

import pytest

from repro.core import (AlertManager, AlertRule, Catalog, DirUsage, Entry,
                        FsType, PolicyDefinition, PolicyEngine, Reports,
                        Scanner, StatsAggregator, PLUGIN_REGISTRY)
from repro.fs import LustreSim


def _fs():
    fs = LustreSim(n_osts=4)
    fs.define_pool("ssd", (0, 1))
    fs.define_pool("hdd", (2, 3))
    proj = fs.mkdir(fs.root_fid(), "proj")
    logs = fs.mkdir(proj, "logs")
    for i in range(10):
        f = fs.create(proj, f"data{i}.tar", owner="foo", pool="ssd")
        fs.write(f, (i + 1) * 1000)
    for i in range(5):
        f = fs.create(logs, f"log{i}.txt", owner="bar", pool="hdd")
        fs.write(f, 10)
    return fs, proj, logs


def test_find_and_du():
    fs, proj, logs = _fs()
    cat = Catalog()
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    Scanner(fs, cat).scan()
    rep = Reports(cat, stats)
    assert len(rep.find("path == '/proj/*.tar' and size > 5000")) == 5
    assert len(rep.find("owner == 'bar'")) == 5
    du = rep.du("/proj/logs")
    assert du["files"] == 5 and du["volume"] == 50
    du_all = rep.du("/proj")
    assert du_all["files"] == 15
    top = rep.top_files(k=3)
    assert top[0]["size"] == 10000.0
    assert rep.top_dirs_by_count(1)[0]["children"] >= 10


def test_du_index_tracks_catalog_churn():
    """The sorted-prefix-range index rebuilds on catalog mutations."""
    fs, proj, logs = _fs()
    cat = Catalog()
    Scanner(fs, cat).scan()
    rep = Reports(cat)
    before = rep.du("/proj/logs")
    assert before["files"] == 5 and before["volume"] == 50
    # mutate through every invalidation-relevant path
    log0 = [e for e in cat.entries() if e.path == "/proj/logs/log0.txt"][0]
    cat.update_fields(log0.fid, size=1000)
    assert rep.du("/proj/logs")["volume"] == 50 - 10 + 1000
    cat.remove(log0.fid)
    after = rep.du("/proj/logs")
    assert after["files"] == 4 and after["volume"] == 40
    # du_many answers several subtrees from one index build
    many = rep.du_many(["/proj", "/proj/logs", "/nope"])
    assert many[0] == rep.du("/proj")
    assert many[1] == after
    assert many[2] == {"count": 0, "files": 0, "volume": 0, "spc_used": 0}
    # prefix is a path-component match, not a string prefix match
    assert rep.du("/proj/lo")["count"] == 0


def test_path_index_rebuilds_only_churned_shards():
    """Per-shard du-index maintenance: churn in one shard leaves the other
    shards' sorted-prefix-range indexes warm."""
    cat = Catalog(n_shards=4)
    for i in range(1, 41):
        cat.upsert(Entry(fid=i, name=f"f{i}", path=f"/a/f{i}",
                         type=FsType.FILE, size=100, blocks=100))
    rep = Reports(cat)
    assert rep.du("/a")["files"] == 40
    assert rep.index_rebuilds == 4          # cold build: one per shard
    # repeat query: all warm
    rep.du("/a")
    assert rep.index_rebuilds == 4
    # mutate one fid -> exactly one shard version ticks -> one rebuild
    cat.update_fields(8, size=999)
    assert rep.du("/a")["volume"] == 39 * 100 + 999
    assert rep.index_rebuilds == 5
    cat.remove(9)
    out = rep.du_many(["/a", "/nope"])
    assert out[0]["files"] == 39 and out[1]["files"] == 0
    assert rep.index_rebuilds == 6


def test_dir_usage_deep_queries_route_to_path_index():
    """DirUsage.max_depth contract: deeper queries answer from Reports.du
    instead of a silently-truncated zero."""
    cat = Catalog(n_shards=2)
    du = DirUsage(max_depth=2)
    paths = ["/a/b/c/d/f1", "/a/b/c/f2", "/a/f3"]
    for i, p in enumerate(paths):
        cat.upsert(Entry(fid=i + 1, name=p.rsplit("/", 1)[1], path=p,
                         type=FsType.FILE, size=100, blocks=50))
        du.on_file(+1, p, 100, 50)
    rep = Reports(cat)
    rep.bind_dir_usage(du)
    # shallow answers stay O(1) from the counters
    assert du.du("/a")["count"] == 3
    # deeper than max_depth: routed to the sorted-prefix-range index and
    # consistent with Reports.du (files == count, volumes agree)
    deep = du.du("/a/b/c")
    assert deep["count"] == 2 and deep["volume"] == 200
    assert deep["volume"] == rep.du("/a/b/c")["volume"]
    assert du.du("/a/b/c/d")["count"] == 1
    # unbound DirUsage refuses instead of silently reporting zero
    with pytest.raises(ValueError):
        DirUsage(max_depth=2).du("/a/b/c")


def test_rmdir_empty_batch_matches_scalar():
    """The batched rmdir_empty derives emptiness from the parent_fid
    groupby column — identical outcomes to the per-entry readdir path,
    including nested empty directories inside one chunk (plan order
    decides whether a parent emptied mid-chunk is removable, for both
    plan directions)."""
    for sort_desc in (False, True):
        results = {}
        for execution in ("scalar", "columnar"):
            fs = LustreSim(n_osts=2)
            proj = fs.mkdir(fs.root_fid(), "proj")
            keep = fs.mkdir(proj, "full")       # has a child file
            f = fs.create(keep, "data.bin", owner="foo")
            fs.write(f, 100)
            for i in range(6):
                fs.mkdir(proj, f"empty{i}")     # removable
            # nested chain: /proj/nest -> /proj/nest/inner (both empty-able)
            nest = fs.mkdir(proj, "nest")
            fs.mkdir(nest, "inner")
            cat = Catalog()
            Scanner(fs, cat).scan()
            eng = PolicyEngine(cat)
            eng.register(PolicyDefinition.from_config(
                name="rmdir", action=PLUGIN_REGISTRY["rmdir_empty"](fs, cat),
                scope="type == dir and (name == 'empty*' or name == 'full'"
                      " or name == 'nest' or name == 'inner')",
                sort_by="fid", sort_desc=sort_desc))
            r = eng.run("rmdir", execution=execution)
            dirs = sorted(e.path for e in cat.entries()
                          if e.type == FsType.DIR)
            results[execution] = (r.succeeded, r.failed, dirs)
        assert results["scalar"] == results["columnar"], sort_desc
        succeeded, failed, dirs = results["columnar"]
        # ascending fid visits parent before child: nest survives this
        # run; descending empties inner first so nest goes too — either
        # way identical to scalar
        assert (succeeded, failed) == ((8, 1) if sort_desc else (7, 2))
        assert "/proj/full" in dirs             # never empty
        assert not any("empty" in d for d in dirs)
        assert ("/proj/nest" in dirs) == (not sort_desc)


def test_rmdir_empty_batch_on_parentless_catalog():
    """A catalog where nothing records a parent (parent_fid=-1 all over)
    must treat every directory as empty, not crash on the empty groupby."""
    fs = LustreSim(n_osts=2)
    d1 = fs.mkdir(fs.root_fid(), "d1")
    d2 = fs.mkdir(fs.root_fid(), "d2")
    cat = Catalog()
    for i, fid in enumerate((d1, d2)):
        cat.upsert(Entry(fid=fid, name=f"d{i+1}", path=f"/d{i+1}",
                         type=FsType.DIR))     # default parent_fid=-1
    action = PLUGIN_REGISTRY["rmdir_empty"](fs, cat)
    oks = action.action_batch(cat.column_batch([d1, d2]), {})
    assert oks == [True, True]
    assert len(cat) == 0


def test_checksum_plugin_batch_matches_scalar():
    results = {}
    for execution in ("scalar", "columnar"):
        fs, proj, logs = _fs()
        cat = Catalog()
        Scanner(fs, cat).scan()
        # desync one file so a corrupt verdict exists
        tar0 = [e for e in cat.entries() if e.path == "/proj/data0.tar"][0]
        cat.update_fields(tar0.fid, size=tar0.size + 1)
        eng = PolicyEngine(cat)
        eng.register(PolicyDefinition.from_config(
            name="fsck", action=PLUGIN_REGISTRY["checksum"](fs, cat),
            scope="type == file"))
        r = eng.run("fsck", execution=execution)
        statuses = sorted((e.path, e.status) for e in cat.entries()
                          if e.type == 0)
        results[execution] = (r.succeeded, r.failed, statuses)
    assert results["scalar"] == results["columnar"]
    assert results["columnar"][1] == 1          # the desynced file failed
    assert ("/proj/data0.tar", "corrupt") in results["columnar"][2]


def test_report_user_o1_matches_scan():
    fs, proj, logs = _fs()
    cat = Catalog()
    stats = StatsAggregator(cat.strings)
    cat.add_delta_hook(stats.on_delta)
    Scanner(fs, cat).scan()
    rep = Reports(cat, stats)
    rows = rep.report_user("foo")
    files = [r for r in rows if r["type"] == "file"][0]
    assert files["count"] == 10
    assert files["volume"] == sum((i + 1) * 1000 for i in range(10))
    txt = rep.format_user_report("foo")
    assert "foo" in txt and "file" in txt


def test_alerts_fire_on_ingest():
    fs, proj, logs = _fs()
    cat = Catalog()
    am = AlertManager()
    am.add_rule(AlertRule("big_tar", "size > 8000 and name == '*.tar'"))
    cat.add_entry_hook(am.on_entry)
    Scanner(fs, cat).scan()
    assert {a["path"] for a in am.fired} == {"/proj/data8.tar",
                                             "/proj/data9.tar"}


def test_generic_policy_plugins():
    fs, proj, logs = _fs()
    cat = Catalog()
    Scanner(fs, cat).scan()
    eng = PolicyEngine(cat)
    # v3 generic policy from the plugin registry: tag then purge logs
    eng.register(PolicyDefinition.from_config(
        name="tag_logs", action=PLUGIN_REGISTRY["tag_status"](fs, cat),
        scope="type == file",
        rules=[("logs", "path == '/proj/logs/*'", {"status": "expired"})]))
    r = eng.run("tag_logs")
    assert r.succeeded == 5
    eng.register(PolicyDefinition.from_config(
        name="purge_expired", action=PLUGIN_REGISTRY["purge"](fs, cat),
        scope="status == 'expired'"))
    r2 = eng.run("purge_expired")
    assert r2.succeeded == 5
    assert fs.count() == 3 + 10   # root, proj, logs + tars


def test_pool_migration_plugin():
    fs, proj, logs = _fs()
    cat = Catalog()
    Scanner(fs, cat).scan()
    eng = PolicyEngine(cat)
    eng.register(PolicyDefinition.from_config(
        name="ssd_to_hdd", action=PLUGIN_REGISTRY["migrate_pool"](fs, cat),
        scope="pool == 'ssd' and size > 7000",
        rules=[("all", "true", {"pool": "hdd"})]))
    r = eng.run("ssd_to_hdd")
    assert r.succeeded == 3       # files of 8000, 9000, 10000 bytes
    moved = [e for e in cat.entries() if e.pool == "hdd" and e.size > 7000]
    assert len(moved) == 3
    for e in moved:
        assert all(o in (2, 3) for o in e.stripe_osts)
