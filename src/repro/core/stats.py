"""Pre-aggregated, on-the-fly statistics (C6) — the O(1) ``rbh-report`` path.

The paper: *"Commonly used statistics are pre-generated in the database.
They are computed on-the-fly as entries are updated, so the following
information is always available: statistics per object type, per user, per
group, per migration status and file size profile."*

:class:`StatsAggregator` subscribes to catalog delta hooks — every
insert/update/remove adjusts counters incrementally, so report queries never
scan entries. Also implements the paper's SIII-C *future* counters as
beyond-paper features: per-user and per-jobid changelog counters and
per-directory-level usage counters (instant ``du``).

Counter updates can run **synchronously** (paper default; measurably slows
ingest) or be drained **asynchronously** by a background thread from a
bounded delta queue (the paper's proposed fix; stats lag slightly but ingest
is faster) — both modes are benchmarked in ``benchmarks/bench_changelog.py``.

The scalar per-record dict fold here is the **differential oracle** for the
on-device analytics subsystem (:class:`~repro.core.profiles.ProfileCube`):
pass ``cube=`` to serve every report from the incrementally-maintained
profile cube instead (deltas forward to it, reports reduce over it), while
this scalar path stays available for byte-identical cross-checks.

**Shared delta fan-out contract.** Each consumer of catalog deltas claims
exactly one feed. A cube-backed aggregator forwards its own hook into the
cube (claiming the cube's feed); when the cube is instead served by the
:class:`~repro.core.device_store.DeviceColumnStore` cube plane
(``ProfileCube.attach_device_store``), the *store's* hook is the single
consumer and fans one dirty batch out to resident columns, partial cubes
and plane mirrors in the same scatter pass — so one pipeline delta batch
is applied exactly once everywhere (see :mod:`repro.core.profiles`).
"""
from __future__ import annotations

import queue
import threading
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .types import (ChangelogRecord, FsType, HsmState, SIZE_PROFILE_LABELS,
                    size_profile_bucket)


class _Acc:
    """count / volume (logical bytes) / spc_used (allocated) accumulator."""

    __slots__ = ("count", "volume", "spc_used")

    def __init__(self) -> None:
        self.count = 0
        self.volume = 0
        self.spc_used = 0

    def add(self, sign: int, size: int, blocks: int) -> None:
        self.count += sign
        self.volume += sign * size
        self.spc_used += sign * blocks

    def as_dict(self) -> dict:
        avg = self.volume / self.count if self.count else 0.0
        return {"count": self.count, "volume": self.volume,
                "spc_used": self.spc_used, "avg_size": avg}


class StatsAggregator:
    """O(1) pre-aggregated stats, keyed per user/group/type/hsm-state/size-bin."""

    def __init__(self, strings, async_mode: bool = False,
                 queue_size: int = 1 << 16, cube=None) -> None:
        self.strings = strings
        self._lock = threading.Lock()
        # cube-backed mode: deltas forward to the ProfileCube, reports
        # reduce over it — the scalar dicts below then stay empty. The
        # forwarding hook becomes the cube's one delta feed (wiring
        # attach() as well would double-count; claiming it here raises).
        self._cube = cube
        if cube is not None:
            cube.claim_delta_feed("StatsAggregator(cube=...)")
        # (owner_code, type) -> _Acc ; (group_code, type) -> _Acc ; type -> _Acc
        self.per_user: Dict[Tuple[int, int], _Acc] = defaultdict(_Acc)
        self.per_group: Dict[Tuple[int, int], _Acc] = defaultdict(_Acc)
        self.per_type: Dict[int, _Acc] = defaultdict(_Acc)
        self.per_hsm: Dict[int, _Acc] = defaultdict(_Acc)
        # (owner_code, size_bucket) -> count : per-user file size profile
        self.size_profile: Dict[Tuple[int, int], int] = defaultdict(int)
        self._total = _Acc()
        self.async_mode = async_mode
        self._q: Optional[queue.Queue] = None
        self._drainer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if async_mode:
            self._q = queue.Queue(maxsize=queue_size)
            self._drainer = threading.Thread(target=self._drain, daemon=True)
            self._drainer.start()

    # -- delta hook (wired into Catalog.add_delta_hook) -----------------------
    def on_delta(self, old, new) -> None:
        if self.async_mode:
            self._q.put((old, new))
        else:
            self._apply(old, new)

    def on_delta_batch(self, pairs) -> None:
        """Batch variant of :meth:`on_delta` (register it as the ``batch=``
        arm of ``Catalog.add_delta_hook``): one committed delta batch folds
        under ONE lock acquisition instead of one per mutation."""
        if self.async_mode:
            for p in pairs:
                self._q.put(p)
            return
        if self._cube is not None:
            self._cube.on_delta_batch(pairs)
            return
        with self._lock:
            fold = self._fold
            for old, new in pairs:
                if old is not None:
                    fold(-1, *old)
                if new is not None:
                    fold(+1, *new)

    def _drain(self) -> None:
        while not self._stop.is_set() or (self._q is not None and not self._q.empty()):
            try:
                old, new = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            self._apply(old, new)
            self._q.task_done()

    def flush(self) -> None:
        """Wait until asynchronously queued deltas are folded in."""
        if self._q is not None:
            self._q.join()

    def close(self) -> None:
        self._stop.set()
        if self._drainer is not None:
            self._drainer.join(timeout=5)

    def _apply(self, old, new) -> None:
        if self._cube is not None:
            self._cube.on_delta(old, new)
            return
        with self._lock:
            if old is not None:
                self._fold(-1, *old)
            if new is not None:
                self._fold(+1, *new)

    def _fold(self, sign: int, fid: int, owner: int, group: int, type_: int,
              size: int, blocks: int, hsm: int, atime: float) -> None:
        # fid/atime ride the Delta for the profile cube (shard routing +
        # age buckets); the flat scalar counters ignore them
        self.per_user[(owner, type_)].add(sign, size, blocks)
        self.per_group[(group, type_)].add(sign, size, blocks)
        self.per_type[type_].add(sign, size, blocks)
        self.per_hsm[hsm].add(sign, size, blocks)
        self._total.add(sign, size, blocks)
        if type_ == int(FsType.FILE):
            self.size_profile[(owner, size_profile_bucket(size))] += sign

    @property
    def total(self) -> _Acc:
        if self._cube is not None:
            acc = _Acc()
            count, volume, spc = self._cube.totals()
            acc.count, acc.volume, acc.spc_used = count, volume, spc
            return acc
        return self._total

    # -- O(1) report queries -----------------------------------------------------
    def report_user(self, user: str) -> List[dict]:
        """`rbh-report -u user`: per-type count/volume/avg — O(#types)."""
        if self._cube is not None:
            return self._cube.report_user(user)
        code = self.strings.code_of(user)
        if code is None:
            return []
        out = []
        with self._lock:
            for t in sorted(FsType, key=int):
                acc = self.per_user.get((code, int(t)))
                if acc and acc.count:
                    d = acc.as_dict()
                    d.update(user=user, type=t.name.lower())
                    out.append(d)
        return out

    def report_group(self, grp: str) -> List[dict]:
        if self._cube is not None:
            return self._cube.report_group(grp)
        code = self.strings.code_of(grp)
        if code is None:
            return []
        out = []
        with self._lock:
            for t in sorted(FsType, key=int):
                acc = self.per_group.get((code, int(t)))
                if acc and acc.count:
                    d = acc.as_dict()
                    d.update(group=grp, type=t.name.lower())
                    out.append(d)
        return out

    def report_types(self) -> Dict[str, dict]:
        if self._cube is not None:
            return self._cube.report_types()
        with self._lock:
            return {FsType(t).name.lower(): a.as_dict()
                    for t, a in self.per_type.items() if a.count}

    def report_hsm(self) -> Dict[str, dict]:
        if self._cube is not None:
            return self._cube.report_hsm()
        with self._lock:
            return {HsmState(h).name.lower(): a.as_dict()
                    for h, a in self.per_hsm.items() if a.count}

    def user_size_profile(self, user: str) -> Dict[str, int]:
        if self._cube is not None:
            return self._cube.user_size_profile(user)
        code = self.strings.code_of(user)
        out = {lbl: 0 for lbl in SIZE_PROFILE_LABELS}
        if code is None:
            return out
        with self._lock:
            for (ucode, bucket), n in self.size_profile.items():
                if ucode == code and n:
                    out[SIZE_PROFILE_LABELS[bucket]] += n
        return out

    def top_users(self, by: str = "volume", k: int = 10,
                  type_: FsType = FsType.FILE) -> List[dict]:
        """Rank users without scanning entries (aggregates only)."""
        if self._cube is not None:
            return self._cube.top_users(by=by, k=k, type_=type_)
        with self._lock:
            rows = []
            for (ucode, t), acc in self.per_user.items():
                if t != int(type_) or not acc.count:
                    continue
                d = acc.as_dict()
                d["user"] = self.strings.lookup(ucode)
                rows.append(d)
        rows.sort(key=lambda d: d.get(by, 0), reverse=True)
        return rows[:k]


class ChangelogCounters:
    """Per-type / per-user / per-jobid changelog counters (SIII-C)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.per_type: Dict[int, int] = defaultdict(int)
        self.per_user: Dict[str, Dict[int, int]] = defaultdict(lambda: defaultdict(int))
        self.per_job: Dict[str, Dict[int, int]] = defaultdict(lambda: defaultdict(int))
        self.total = 0

    def on_record(self, rec: ChangelogRecord) -> None:
        with self._lock:
            self.total += 1
            self.per_type[int(rec.type)] += 1
            if rec.uid:
                self.per_user[rec.uid][int(rec.type)] += 1
            if rec.jobid:
                self.per_job[rec.jobid][int(rec.type)] += 1

    def on_records(self, recs) -> None:
        """Count a whole read batch under one lock (columnar ingest)."""
        with self._lock:
            per_type, per_user, per_job = \
                self.per_type, self.per_user, self.per_job
            self.total += len(recs)
            for rec in recs:
                t = int(rec.type)
                per_type[t] += 1
                if rec.uid:
                    per_user[rec.uid][t] += 1
                if rec.jobid:
                    per_job[rec.jobid][t] += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "total": self.total,
                "per_type": dict(self.per_type),
                "per_user": {u: dict(c) for u, c in self.per_user.items()},
                "per_job": {j: dict(c) for j, c in self.per_job.items()},
            }


class DirUsage:
    """Per-directory recursive usage counters up to ``max_depth`` (SIII-C).

    Makes ``du`` at shallow namespace levels O(1): each file delta is
    propagated to its ancestor directories (bounded by ``max_depth``).
    Ancestors are resolved from entry paths, so no catalog walk is needed.

    **Depth contract**: attribution stops at ``max_depth`` path components
    — a directory deeper than that accumulates nothing, so a naive lookup
    there would silently report zero usage and disagree with the
    index-backed ``Reports.du``. :meth:`du` therefore routes queries
    deeper than ``max_depth`` to ``deep_du`` (wire it to ``Reports.du``
    via :meth:`Reports.bind_dir_usage`) and raises if no deep path is
    bound, rather than returning a silently-truncated answer.
    """

    def __init__(self, max_depth: int = 3, deep_du=None) -> None:
        self.max_depth = max_depth
        # fallback for paths deeper than max_depth: callable(path) -> dict
        # in Reports.du shape ({count, files, volume, spc_used})
        self.deep_du = deep_du
        self._lock = threading.Lock()
        self.usage: Dict[str, _Acc] = defaultdict(_Acc)

    @staticmethod
    def _ancestors(path: str, max_depth: int) -> List[str]:
        parts = [p for p in path.split("/") if p]
        out = ["/"]
        for i in range(min(len(parts) - 1, max_depth)):
            out.append("/" + "/".join(parts[: i + 1]))
        return out

    def on_file(self, sign: int, path: str, size: int, blocks: int) -> None:
        with self._lock:
            for d in self._ancestors(path, self.max_depth):
                self.usage[d].add(sign, size, blocks)

    def du(self, path: str) -> dict:
        parts = [p for p in path.split("/") if p]
        path = "/" + "/".join(parts) if parts else "/"
        if len(parts) > self.max_depth:
            # counters were never attributed this deep — answer from the
            # sorted-prefix-range index instead of a silent zero
            if self.deep_du is None:
                raise ValueError(
                    f"path {path!r} is deeper than max_depth="
                    f"{self.max_depth} and no deep_du fallback is bound "
                    "(see Reports.bind_dir_usage)")
            deep = self.deep_du(path)
            files = deep.get("files", deep.get("count", 0))
            return {"count": files, "volume": deep["volume"],
                    "spc_used": deep["spc_used"],
                    "avg_size": deep["volume"] / files if files else 0.0}
        with self._lock:
            return self.usage[path].as_dict() if path in self.usage else \
                {"count": 0, "volume": 0, "spc_used": 0, "avg_size": 0.0}
