"""HSM-style KV-cache tiering driven by the Robinhood policy engine.

Mapping (DESIGN.md SS2): the hot :class:`PagePool` is an OST (bounded HBM);
host DRAM is the HSM backend; each page is a catalog entry whose atime is
its last attention access; purge-on-watermark reproduces the paper's
per-OST release policy — when the hot pool crosses ``high_wm`` the engine
archives+releases least-recently-used pages until ``low_wm``; touching a
released page restores it transparently (like Lustre reads on released
files). O(1) residency stats come from the same StatsAggregator.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.catalog import Catalog
from ..core.policy import parse_expr
from ..core.policy_engine import (PolicyDefinition, PolicyEngine,
                                  UsageWatermarkTrigger)
from ..core.stats import StatsAggregator
from ..core.types import Entry, FsType, HsmState
from .paged import PagePool, SequencePages


class TieredKvCache:
    """Two-tier paged KV cache with policy-driven migration."""

    def __init__(self, pool: PagePool, high_wm: float = 80.0,
                 low_wm: float = 50.0,
                 clock: Callable[[], float] = time.time) -> None:
        self.pool = pool
        self.clock = clock
        self.cold: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}  # fid->k,v
        self.catalog = Catalog(n_shards=2)
        self.stats = StatsAggregator(self.catalog.strings)
        self.catalog.add_delta_hook(self.stats.on_delta)
        self.engine = PolicyEngine(self.catalog, clock=clock)
        self.sequences: Dict[int, SequencePages] = {}
        self._page_fid: Dict[Tuple[int, int], int] = {}   # (seq, idx)->fid
        self._fid_info: Dict[int, dict] = {}              # fid -> info
        self._next_fid = 1
        self._pinned: set = set()       # fids immune to eviction (in use)
        self.restores = 0
        self.page_bytes = (pool.page_size * pool.n_kv * pool.head_dim
                           * 2 * pool.k.itemsize)

        def do_release(e: Entry, params: dict) -> bool:
            return self._release_page(e.fid)

        self.engine.register(PolicyDefinition.from_config(
            name="kv_release", action=do_release,
            scope="type == file",
            rules=[("resident_pages", "status == 'hot'", {})],
            sort_by="atime",            # LRU, like the paper's purge
        ))
        self.engine.add_watermark_trigger(
            "kv_release",
            UsageWatermarkTrigger(
                usage_fn=lambda: [("hot_pool", self.pool.used * self.page_bytes,
                                   self.pool.n_pages * self.page_bytes)],
                high_pct=high_wm, low_pct=low_wm,
                restrict_fn=lambda key: parse_expr("status == 'hot'")))

    # -- catalog plumbing --------------------------------------------------------
    def _register_page(self, seq_id: int, idx: int, page_id: int) -> int:
        fid = self._next_fid
        self._next_fid += 1
        self._page_fid[(seq_id, idx)] = fid
        self._fid_info[fid] = {"seq": seq_id, "idx": idx, "page": page_id}
        now = self.clock()
        self.catalog.upsert(Entry(
            fid=fid, name=f"seq{seq_id}/page{idx}", path=f"/kv/{seq_id}/{idx}",
            type=FsType.FILE, size=self.page_bytes, blocks=self.page_bytes,
            owner=f"seq{seq_id}", status="hot", atime=now, mtime=now,
            ctime=now))
        return fid

    # -- serving-side API ---------------------------------------------------------
    def admit(self, seq_id: int) -> SequencePages:
        sp = SequencePages(seq_id)
        self.sequences[seq_id] = sp
        return sp

    def append_token(self, seq_id: int, k: np.ndarray, v: np.ndarray) -> None:
        """Write one token's K/V; allocates (possibly evicting) as needed."""
        sp = self.sequences[seq_id]
        slot = sp.length % self.pool.page_size
        if slot == 0:
            page = self._alloc_with_pressure()
            idx = len(sp.page_ids)
            sp.page_ids.append(page)
            self._register_page(seq_id, idx, page)
        idx = sp.length // self.pool.page_size
        self._ensure_resident(seq_id, idx)
        page = sp.page_ids[idx]
        self.pool.write_token(page, slot, k, v)
        sp.length += 1
        self._touch(seq_id, idx)

    def page_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Resident page table for attention; restores released pages.

        Pages of ``seq_id`` are pinned while the table is live so restoring
        page N cannot evict freshly-restored page M of the same sequence.
        """
        sp = self.sequences[seq_id]
        self._pinned = {self._page_fid[(seq_id, i)]
                        for i in range(len(sp.page_ids))}
        for idx in range(len(sp.page_ids)):
            self._ensure_resident(seq_id, idx)
            self._touch(seq_id, idx)
        return sp.table(max_pages)

    def unpin(self) -> None:
        self._pinned = set()

    def finish(self, seq_id: int) -> None:
        """Request completed: free everything it held."""
        sp = self.sequences.pop(seq_id, None)
        if sp is None:
            return
        for idx, page in enumerate(sp.page_ids):
            fid = self._page_fid.pop((seq_id, idx), None)
            if fid is None:
                continue
            info = self._fid_info.pop(fid)
            if fid in self.cold:
                del self.cold[fid]
            e = self.catalog.get(fid)
            if e is not None and e.status == "hot":
                self.pool.free(info["page"])
            self.catalog.remove(fid)

    # -- tier movement ------------------------------------------------------------
    def _touch(self, seq_id: int, idx: int) -> None:
        fid = self._page_fid[(seq_id, idx)]
        self.catalog.update_fields(fid, atime=self.clock())

    def _alloc_with_pressure(self) -> int:
        page = self.pool.alloc()
        if page is None:
            self.engine.check_triggers()
            page = self.pool.alloc()
        if page is None:
            # hard fallback: force-release the LRU hot page
            self.engine.run("kv_release",
                            target_volume=self.page_bytes)
            page = self.pool.alloc()
        if page is None:
            raise MemoryError("hot KV pool exhausted")
        return page

    def _release_page(self, fid: int) -> bool:
        """hot -> cold: archive payload to host then free the hot slot."""
        if fid in self._pinned:
            return False                 # in use by a live page table
        info = self._fid_info.get(fid)
        if info is None:
            return False
        e = self.catalog.get(fid)
        if e is None or e.status != "hot":
            return False
        k, v = self.pool.read_page(info["page"])
        self.cold[fid] = (k, v)
        self.pool.free(info["page"])
        self.catalog.update_fields(fid, status="cold",
                                   hsm_state=HsmState.RELEASED, blocks=0)
        return True

    def _ensure_resident(self, seq_id: int, idx: int) -> None:
        """cold -> hot restore on access (transparent, like Lustre-HSM)."""
        fid = self._page_fid.get((seq_id, idx))
        if fid is None:
            return
        e = self.catalog.get(fid)
        if e is None or e.status == "hot":
            return
        page = self._alloc_with_pressure()
        k, v = self.cold.pop(fid)
        self.pool.write_page(page, k, v)
        self._fid_info[fid]["page"] = page
        self.sequences[seq_id].page_ids[idx] = page
        self.catalog.update_fields(fid, status="hot",
                                   hsm_state=HsmState.ARCHIVED,
                                   blocks=self.page_bytes)
        self.restores += 1

    def maybe_run_policies(self) -> None:
        """Periodic trigger check (call between decode steps)."""
        self.engine.check_triggers()

    # -- O(1) stats (rbh-report for the cache) --------------------------------------
    def residency_report(self, seq_id: int) -> List[dict]:
        return self.stats.report_user(f"seq{seq_id}")

    def tier_report(self) -> Dict[str, dict]:
        cols = self.catalog.arrays()
        hot_code = self.catalog.strings.code_of("hot")
        cold_code = self.catalog.strings.code_of("cold")
        hot = int((cols["status"] == hot_code).sum()) if hot_code is not None else 0
        cold = int((cols["status"] == cold_code).sum()) if cold_code is not None else 0
        return {"hot_pages": hot, "cold_pages": cold,
                "hot_usage_pct": self.pool.usage_pct,
                "restores": self.restores}
