"""Paper SII-B4: rbh-find / rbh-du clones vs POSIX walking, on a REAL
directory tree (PosixFs backend) — plus a large synthetic catalog showing
the vectorized / sorted-prefix-range ``du`` against the old per-path
Python-generator prefix match."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import Catalog, Entry, FsType, Reports, Scanner, StatsAggregator
from repro.fs import PosixFs


def _du_generator(cat, path_prefix):
    """The pre-refactor Reports.du: a Python generator over every path."""
    cols = cat.arrays()
    prefix = path_prefix.rstrip("/")
    paths = cols["_paths"]
    mask = np.fromiter(
        (p == prefix or p.startswith(prefix + "/") for p in paths),
        dtype=bool, count=len(paths))
    file_mask = mask & (cols["type"] == int(FsType.FILE))
    return {
        "count": int(mask.sum()),
        "files": int(file_mask.sum()),
        "volume": int(cols["size"][file_mask].sum()),
        "spc_used": int(cols["blocks"][file_mask].sum()),
    }


def _bench_du_scaling(n: int) -> list:
    """Sorted-prefix-range du (cold build / warm queries) vs the generator.

    The realistic rbh-du workload is many subtree queries against a
    slowly-churning catalog: the index is built once per catalog version
    and every query after that is two binary searches.
    """
    rng = np.random.default_rng(3)
    cat = Catalog(n_shards=4)
    n_dirs = 64
    for lo in range(0, n, 100_000):
        hi = min(lo + 100_000, n)
        entries = [Entry(fid=i + 1, name=f"f{i}",
                         path=f"/fs/d{i % n_dirs}/f{i}", type=FsType.FILE,
                         size=int(rng.integers(0, 1 << 20)), blocks=8)
                   for i in range(lo, hi)]
        cat.upsert_batch(entries)
    rep = Reports(cat)
    prefixes = [f"/fs/d{d}" for d in range(n_dirs)]

    t0 = time.perf_counter()
    ref = _du_generator(cat, prefixes[0])
    dt_gen = time.perf_counter() - t0

    t0 = time.perf_counter()
    got = rep.du(prefixes[0])                 # cold: builds the path index
    dt_cold = time.perf_counter() - t0
    assert got == ref

    t0 = time.perf_counter()
    many = rep.du_many(prefixes)              # warm: binary searches only
    dt_warm = (time.perf_counter() - t0) / len(prefixes)
    assert many[0] == ref
    for d in (1, n_dirs // 2, n_dirs - 1):
        assert many[d] == _du_generator(cat, prefixes[d])

    return [
        ("du_python_generator", 1e6 * dt_gen, f"{n}_paths"),
        ("du_sorted_range_cold", 1e6 * dt_cold,
         f"index_build_speedup_{dt_gen/max(dt_cold,1e-9):.1f}x"),
        ("du_sorted_range_warm", 1e6 * dt_warm,
         f"{len(prefixes)}_queries_amortized"
         f"_speedup_{dt_gen/max(dt_warm,1e-9):.1f}x"),
    ]


def _make_tree(root, n_dirs=40, files_per_dir=25):
    rng = __import__("random").Random(0)
    dirs = [root]
    for i in range(n_dirs):
        d = os.path.join(rng.choice(dirs[-10:]), f"d{i}")
        os.makedirs(d, exist_ok=True)
        dirs.append(d)
        for j in range(files_per_dir):
            with open(os.path.join(d, f"f{j}.dat"), "wb") as f:
                f.write(b"x" * rng.randint(0, 4096))


def run(smoke: bool = False) -> list:
    rows = _bench_du_scaling(100_000 if smoke else 1_000_000)
    tmp = tempfile.mkdtemp(prefix="rbh_bench_")
    try:
        _make_tree(tmp)
        fs = PosixFs(tmp)
        cat = Catalog()
        stats = StatsAggregator(cat.strings)
        cat.add_delta_hook(stats.on_delta)
        t0 = time.perf_counter()
        st = Scanner(fs, cat, n_threads=4).scan()
        scan_dt = time.perf_counter() - t0
        rows.append(("posix_initial_scan", 1e6 * scan_dt / st.entries,
                     f"{st.entries}_entries"))
        rep = Reports(cat, stats)

        # find: files > 2KB
        t0 = time.perf_counter()
        hits_posix = []
        for dirpath, _d, files in os.walk(tmp):
            for f in files:
                p = os.path.join(dirpath, f)
                if os.path.getsize(p) > 2048:
                    hits_posix.append(p)
        dt_posix = time.perf_counter() - t0
        t0 = time.perf_counter()
        hits_db = rep.find("type == file and size > 2k")
        dt_db = time.perf_counter() - t0
        assert len(hits_db) == len(hits_posix)
        rows.append(("find_posix_walk", 1e6 * dt_posix,
                     f"{len(hits_posix)}_hits"))
        rows.append(("find_rbh_db", 1e6 * dt_db,
                     f"speedup_{dt_posix/max(dt_db,1e-9):.1f}x"))

        # du -s
        t0 = time.perf_counter()
        total = 0
        for dirpath, _d, files in os.walk(tmp):
            for f in files:
                total += os.path.getsize(os.path.join(dirpath, f))
        dt_posix_du = time.perf_counter() - t0
        t0 = time.perf_counter()
        du = rep.du(tmp)
        dt_db_du = time.perf_counter() - t0
        assert du["volume"] == total
        rows.append(("du_posix_walk", 1e6 * dt_posix_du, f"{total}_bytes"))
        rows.append(("du_rbh_db", 1e6 * dt_db_du,
                     f"speedup_{dt_posix_du/max(dt_db_du,1e-9):.1f}x"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows
