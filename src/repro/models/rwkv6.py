"""RWKV-6 "Finch" time-mix and channel-mix (data-dependent decay).

Recurrence per head (key-dim i, value-dim j):

    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

Sequence processing uses the chunked linear-attention form: within a chunk
of length C the intra-chunk part is an O(C^2 hd) masked product, the
inter-chunk part applies the carried state; every decay exponent that
appears is a difference lw_a - lw_b with a >= b along time, hence <= 0 and
safe to exponentiate (we additionally clamp at 0). The pure O(S) step
recurrence lives in ``wkv_step`` (decode) and doubles as the test oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv_step(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """One token. r,k,v,w: (B,H,hd); u: (H,hd); state: (B,H,hd,hd).

    Returns (y (B,H,hd), new_state). All f32.
    """
    kv = k[..., :, None] * v[..., None, :]                 # (B,H,hd,hd)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return y, new_state


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, lw: jax.Array,
                u: jax.Array, state: Optional[jax.Array] = None,
                chunk: int = 64, unroll: int = 1
                ) -> Tuple[jax.Array, jax.Array]:
    """Sequence form. r,k,v: (B,S,H,hd) f32; lw: (B,S,H,hd) log-decay (<=0);
    u: (H,hd). Returns (y (B,S,H,hd), final_state (B,H,hd,hd)).
    """
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), dtype=jnp.float32)
    assert S % chunk == 0, f"S={S} must divide chunk={chunk}"
    n = S // chunk

    def reshape(x):
        return x.reshape(B, n, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    rs, ks, vs, lws = map(reshape, (r, k, v, lw))

    tri_lt = jnp.tril(jnp.ones((chunk, chunk), dtype=bool), k=-1)  # s < t

    def body(S_prev, inp):
        rc, kc, vc, lwc = inp                              # (B,C,H,hd)
        cum = jnp.cumsum(lwc, axis=1)                      # lw_1..t inclusive
        cum_prev = cum - lwc                               # lw up to t-1
        # inter-chunk: y_t += (r_t * exp(cum_prev_t)) @ S_prev
        r_dec = rc * jnp.exp(cum_prev)
        y_inter = jnp.einsum("bthi,bhij->bthj", r_dec, S_prev)
        # intra-chunk: A[t,s] = sum_i r[t,i] k[s,i] exp(cum_prev[t]-cum[s]), s<t
        expo = cum_prev[:, :, None] - cum[:, None, :, :, :]   # (B,t,s,H,hd)
        expo = jnp.minimum(expo, 0.0)
        a = jnp.einsum("bthi,bshi,btshi->btsh", rc, kc, jnp.exp(expo))
        a = jnp.where(tri_lt[None, :, :, None], a, 0.0)
        # current-token bonus term: A[t,t] = sum_i r[t,i] u[i] k[t,i]
        diag = jnp.einsum("bthi,hi,bthi->bth", rc, u, kc)
        y_intra = jnp.einsum("btsh,bshj->bthj", a, vc) + \
            diag[..., None] * vc
        # state update: S = diag(exp(cum_C)) S_prev + sum_s (k_s exp(cum_C-cum_s)) v_s
        cum_end = cum[:, -1:, :, :]                        # (B,1,H,hd)
        k_dec = kc * jnp.exp(jnp.minimum(cum_end - cum, 0.0))
        S_new = jnp.exp(cum_end[:, 0])[..., None] * S_prev + \
            jnp.einsum("bshi,bshj->bhij", k_dec, vc)
        return S_new, y_inter + y_intra

    final_state, ys = jax.lax.scan(body, state, (rs, ks, vs, lws),
                                   unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y, final_state


def wkv_ref(r, k, v, lw, u, state=None):
    """O(S) serial oracle (python loop — tests on tiny shapes only)."""
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), dtype=jnp.float32)
    ys = []
    for t in range(S):
        y, state = wkv_step(r[:, t], k[:, t], v[:, t],
                            jnp.exp(lw[:, t]), u, state)
        ys.append(y)
    return jnp.stack(ys, axis=1), state


def token_shift(x: jax.Array, last: Optional[jax.Array] = None) -> jax.Array:
    """Previous-token features: shift right by one along S. x: (B,S,D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :]
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def ddlerp(x: jax.Array, xprev: jax.Array, mu: jax.Array,
           a: jax.Array, b: jax.Array) -> jax.Array:
    """RWKV6 data-dependent lerp for one channel group.

    x, xprev: (B,S,D); mu: (D,); a: (D,L); b: (L,D).
    mix = x + (mu + tanh((xprev-x) @ a) @ b) * (xprev - x)
    """
    dx = xprev - x
    dyn = jnp.tanh(dx @ a) @ b
    return x + (mu + dyn) * dx
