"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory ships three files:

* ``kernel.py`` — the ``pl.pallas_call`` with explicit BlockSpec VMEM tiling
  (TPU is the target; validated on CPU with ``interpret=True``);
* ``ops.py``    — the jit'd public wrapper (dispatches kernel on TPU,
  interpret-mode kernel or the oracle elsewhere);
* ``ref.py``    — the pure-jnp oracle the kernel is tested against.

Kernels:

* ``policy_scan``     — columnar predicate-program evaluation + aggregation
  (the TPU-native analogue of the paper's DB table scan, C1+C6);
* ``profile_cube``    — fused bucketize + one-hot-matmul segment reduction
  producing the ownership/age/size profile cube (the paper's C6 report
  tables) in a single launch;
* ``paged_attention`` — decode attention over non-contiguous KV pages (the
  hot tier of the HSM-style KV cache);
* ``rglru_scan``      — RG-LRU sequential recurrence (recurrentgemma);
* ``rwkv6_step``      — RWKV6 decode state update.
"""
