"""Distribution: sharding rules, elastic restore, grad compression, and a
mini dry-run on small fake-device meshes (subprocess; 16 devices)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
from conftest import run_subprocess


def test_sharding_rules_cover_all_params():
    """Every leaf of every arch gets a spec with matching rank."""
    import jax
    from repro.configs import ARCH_IDS, get_config
    from repro.models import Model
    from repro.runtime.sharding import ShardingRules

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        m = Model(cfg)
        specs = m.param_specs()
        rules = ShardingRules(cfg, FakeMesh(), "tp")
        pspecs = rules.param_pspecs(specs)

        def check(path, leaf, spec):
            assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), specs, pspecs)


@pytest.mark.slow
def test_mini_dryrun_16_devices():
    """Lower+compile train & serve steps on a 4x4 mesh with a smoke arch."""
    out = run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models import Model
from repro.optim import AdamW
from repro.runtime.sharding import ShardingRules
from repro.train import init_train_state, make_train_step
from repro.serve import make_serve_step

mesh = Mesh(np.array(jax.devices()).reshape(4, 4), ("data", "model"))
cfg = get_config("gemma2_9b", smoke=True)
model = Model(cfg, kv_chunk=16)
rules = ShardingRules(cfg, mesh, "tp")
opt = AdamW()
state_specs = jax.eval_shape(lambda: init_train_state(model, opt, jax.random.PRNGKey(0)))
pspecs = {"params": rules.param_pspecs(state_specs["params"]),
          "opt": {"m": rules.opt_state_pspecs(state_specs["params"]),
                  "v": rules.opt_state_pspecs(state_specs["params"]), "count": P()},
          "step": P()}
state_sh = rules.to_shardings(pspecs)
batch = {"tokens": jax.ShapeDtypeStruct((2, 8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((2, 8, 32), jnp.int32)}
batch_sh = rules.to_shardings(rules.batch_pspecs(batch))
step = make_train_step(model, opt, grad_pspecs=rules.opt_state_pspecs(state_specs["params"]))
with mesh:
    c = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(state_specs, batch).compile()
ca = c.cost_analysis()
ca = ca[0] if isinstance(ca, list) else ca   # list-of-dicts in older jax
print("train ok", ca.get("flops", 0) > 0)

# serve step
params = model.param_specs()
cache = model.init_cache(8, 64, abstract=True)
with mesh:
    c2 = jax.jit(make_serve_step(model),
                 in_shardings=(rules.to_shardings(rules.param_pspecs(params)),
                               rules.to_shardings(rules.cache_pspecs(cache)),
                               NamedSharding(mesh, P("data", None)), NamedSharding(mesh, P())),
                 ).lower(params, cache,
                         jax.ShapeDtypeStruct((8, 1), jnp.int32),
                         jax.ShapeDtypeStruct((), jnp.int32)).compile()
ca2 = c2.cost_analysis()
ca2 = ca2[0] if isinstance(ca2, list) else ca2
print("serve ok", ca2.get("flops", 0) > 0)
""", devices=16, timeout=280)
    assert "train ok True" in out and "serve ok True" in out


@pytest.mark.slow
def test_elastic_restore_different_mesh(tmp_path):
    """Save on a 2x2 mesh, restore onto 4x1 and 1-device meshes."""
    out = run_subprocess(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.configs import get_config
from repro.models import Model
from repro.optim import AdamW
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import reshard_state, state_shardings
from repro.train import init_train_state

cfg = get_config("chatglm3_6b", smoke=True)
model = Model(cfg)
opt = AdamW()
state = init_train_state(model, opt, jax.random.PRNGKey(1))
mesh_a = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
sh_a = state_shardings(cfg, mesh_a, state)
state_a = reshard_state(state, sh_a)
cm = CheckpointManager({str(tmp_path / 'ck')!r})
cm.save(state_a, 1)

mesh_b = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("data", "model"))
sh_b = state_shardings(cfg, mesh_b, state)
restored, step = cm.restore(like=state, shardings=sh_b)
w0 = jax.tree.leaves(state)[0]
w1 = jax.tree.leaves(restored)[0]
print("elastic ok", bool(jnp.allclose(w0.astype(jnp.float32), w1.astype(jnp.float32))), step)
""", devices=8, timeout=280)
    assert "elastic ok True 1" in out


@pytest.mark.slow
def test_grad_compression_shard_map():
    """int8 error-feedback all-reduce over a 4-way dp axis == exact mean
    after error feedback accumulates (convergence over steps)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from jax.experimental.shard_map import shard_map
from repro.optim.grad_compression import make_compressed_allreduce, init_error_state

mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
reduce_tree = make_compressed_allreduce(mesh, "data")
rng = np.random.default_rng(0)
g_local = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)  # per-shard grads
err0 = jnp.zeros((4, 64), jnp.float32)

@partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
         out_specs=(P("data"), P("data")))
def reduce_once(g, e):
    out, e2 = reduce_tree({"g": g}, {"g": e})
    return out["g"], e2["g"]

exact = jnp.mean(g_local, axis=0)
total_err = None
g_hat, err = reduce_once(g_local, err0)
err1_norm = float(jnp.abs(g_hat[0] - exact).max())
# error feedback: feeding the SAME gradient again corrects quant error
acc = g_hat[0]
for _ in range(10):
    g_hat, err = reduce_once(g_local, err)
    acc = acc + g_hat[0]
drift = float(jnp.abs(acc / 11 - exact).max())
print("compress ok", err1_norm < 0.05, drift < err1_norm, round(err1_norm,5), round(drift,6))
""", devices=4, timeout=280)
    assert "compress ok True True" in out


def test_data_pipeline_determinism_and_resume():
    from repro.data import DataPipeline
    p1 = DataPipeline(vocab=100, seq_len=16, global_batch=8, n_shards=2,
                      seed=7)
    batches = [p1.next_batch(shard=0) for _ in range(5)]
    snap = p1.checkpoint()
    after = [p1.next_batch(shard=0) for _ in range(3)]
    # resume elsewhere
    p2 = DataPipeline(vocab=100, seq_len=16, global_batch=8, n_shards=2,
                      seed=7)
    p2.restore(snap)
    replay = [p2.next_batch(shard=0) for _ in range(3)]
    for a, b in zip(after, replay):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards differ, steps differ
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])
    assert not np.array_equal(p1.batch_for(0, 0)["tokens"],
                              p1.batch_for(0, 1)["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])
