"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def sched(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(1, warmup_steps)
        prog = jnp.clip((c - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup_steps, warm, cos)
    return sched
