"""In-process Lustre model: namespace + OSTs + pools + DNE changelogs + HSM.

This is the "filesystem under management" for tests, benchmarks and examples.
It models exactly what the paper's engine consumes/controls:

* a namespace of entries with POSIX attributes;
* **OSTs** with capacities; files stripe over OSTs (``stripe_count``), data
  usage is accounted per OST so watermark-triggered purge (C7) is observable;
* **pools** — administratively-defined OST groups, usable in policies;
* **DNE**: directories are hash-distributed over ``n_mdts`` metadata shards,
  each emitting its own transactional changelog stream (C3);
* **HSM hooks**: archive copies file payload to an :class:`HsmBackend`,
  release punches OST data (keeping a stub), restore brings it back —
  emitting HSM changelog events throughout (C8).

Operations update atime/mtime/ctime like a real FS so age-based policies are
meaningful; a ``clock`` callable is injectable so tests can fake time.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.changelog import ChangelogHub
from ..core.types import ChangelogType, Entry, FsType, HsmState
from .hsm_backend import HsmBackend


class Ost:
    """One object storage target: capacity + used-bytes accounting."""

    def __init__(self, index: int, capacity: int) -> None:
        self.index = index
        self.capacity = capacity
        self.used = 0
        self._lock = threading.Lock()

    def alloc(self, nbytes: int) -> None:
        with self._lock:
            self.used += nbytes

    def free(self, nbytes: int) -> None:
        with self._lock:
            self.used = max(0, self.used - nbytes)

    @property
    def usage_pct(self) -> float:
        return 100.0 * self.used / self.capacity if self.capacity else 0.0


class _Node:
    __slots__ = ("entry", "children", "data_len", "archived_len")

    def __init__(self, entry: Entry) -> None:
        self.entry = entry
        self.children: Dict[str, int] = {}   # name -> fid (dirs only)
        self.data_len = 0                     # bytes resident on OSTs
        self.archived_len = 0                 # bytes archived in HSM


class LustreSim:
    """Simulated Lustre filesystem with changelog + OST + HSM semantics."""

    def __init__(self, n_osts: int = 4, ost_capacity: int = 1 << 30,
                 n_mdts: int = 1, stripe_count: int = 1,
                 changelog_dir: Optional[str] = None,
                 hsm: Optional[HsmBackend] = None,
                 clock: Callable[[], float] = time.time) -> None:
        self.osts = [Ost(i, ost_capacity) for i in range(n_osts)]
        self.pools: Dict[str, Tuple[int, ...]] = {}
        self.stripe_count = stripe_count
        self.changelog = ChangelogHub(n_mdts=n_mdts, persist_dir=changelog_dir)
        self.n_mdts = n_mdts
        self.hsm = hsm
        self.clock = clock
        self._lock = threading.RLock()
        self._nodes: Dict[int, _Node] = {}
        self._next_fid = 2
        self._rr = 0   # round-robin stripe cursor
        now = self.clock()
        root = Entry(fid=1, parent_fid=0, name="/", path="/", type=FsType.DIR,
                     mode=0o755, atime=now, mtime=now, ctime=now)
        self._nodes[1] = _Node(root)

    # -- helpers -------------------------------------------------------------
    def define_pool(self, name: str, ost_indices: Sequence[int]) -> None:
        self.pools[name] = tuple(ost_indices)

    def _mdt_of(self, parent_fid: int) -> int:
        return parent_fid % self.n_mdts

    def _emit(self, parent_fid: int, type_: ChangelogType, fid: int, **kw) -> None:
        kw.setdefault("time", self.clock())
        self.changelog.stream(self._mdt_of(parent_fid)).emit(
            type_, fid, parent_fid=parent_fid, **kw)

    def _pick_osts(self, pool: str) -> Tuple[int, ...]:
        cands = self.pools.get(pool) or tuple(range(len(self.osts)))
        n = min(self.stripe_count, len(cands))
        out = tuple(cands[(self._rr + i) % len(cands)] for i in range(n))
        self._rr += 1
        return out

    def _node(self, fid: int) -> _Node:
        node = self._nodes.get(fid)
        if node is None:
            raise FileNotFoundError(fid)
        return node

    def _alloc_fid(self) -> int:
        fid = self._next_fid
        self._next_fid += 1
        return fid

    # -- namespace operations (each emits a changelog record) -------------------
    def mkdir(self, parent: int, name: str, owner: str = "root",
              group: str = "root", uid: str = "", jobid: str = "") -> int:
        with self._lock:
            pnode = self._node(parent)
            if name in pnode.children:
                raise FileExistsError(name)
            fid = self._alloc_fid()
            now = self.clock()
            path = (pnode.entry.path.rstrip("/") + "/" + name)
            e = Entry(fid=fid, parent_fid=parent, name=name, path=path,
                      type=FsType.DIR, mode=0o755, owner=owner, group=group,
                      atime=now, mtime=now, ctime=now)
            self._nodes[fid] = _Node(e)
            pnode.children[name] = fid
            pnode.entry.mtime = now
            self._emit(parent, ChangelogType.MKDIR, fid, name=name, uid=uid,
                       jobid=jobid)
            return fid

    def create(self, parent: int, name: str, owner: str = "root",
               group: str = "root", pool: str = "", uid: str = "",
               jobid: str = "") -> int:
        with self._lock:
            pnode = self._node(parent)
            if name in pnode.children:
                raise FileExistsError(name)
            fid = self._alloc_fid()
            now = self.clock()
            stripes = self._pick_osts(pool)
            path = (pnode.entry.path.rstrip("/") + "/" + name)
            e = Entry(fid=fid, parent_fid=parent, name=name, path=path,
                      type=FsType.FILE, owner=owner, group=group, pool=pool,
                      ost_idx=stripes[0] if stripes else -1,
                      stripe_osts=stripes, atime=now, mtime=now, ctime=now)
            self._nodes[fid] = _Node(e)
            pnode.children[name] = fid
            pnode.entry.mtime = now
            self._emit(parent, ChangelogType.CREAT, fid, name=name, uid=uid,
                       jobid=jobid)
            return fid

    def symlink(self, parent: int, name: str, target: str,
                owner: str = "root", uid: str = "") -> int:
        with self._lock:
            pnode = self._node(parent)
            fid = self._alloc_fid()
            now = self.clock()
            path = (pnode.entry.path.rstrip("/") + "/" + name)
            e = Entry(fid=fid, parent_fid=parent, name=name, path=path,
                      type=FsType.SYMLINK, owner=owner, size=len(target),
                      atime=now, mtime=now, ctime=now,
                      xattrs={"target": target})
            self._nodes[fid] = _Node(e)
            pnode.children[name] = fid
            self._emit(parent, ChangelogType.SLINK, fid, name=name, uid=uid)
            return fid

    def write(self, fid: int, nbytes: int, uid: str = "", jobid: str = "") -> None:
        """Append ``nbytes``; allocates across the file's stripe OSTs."""
        with self._lock:
            node = self._node(fid)
            e = node.entry
            if e.type != FsType.FILE:
                raise IsADirectoryError(fid)
            per = nbytes // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
            for idx in e.stripe_osts:
                self.osts[idx].alloc(per)
            node.data_len += nbytes
            now = self.clock()
            e.size += nbytes
            e.blocks = node.data_len
            e.mtime = e.atime = now
            if e.hsm_state in (HsmState.ARCHIVED,):
                e.hsm_state = HsmState.DIRTY
                self._emit(e.parent_fid, ChangelogType.HSM, fid,
                           attrs={"hsm_state": int(HsmState.DIRTY)}, uid=uid)
            self._emit(e.parent_fid, ChangelogType.CLOSE, fid, name=e.name,
                       uid=uid, jobid=jobid,
                       attrs={"size": e.size, "blocks": e.blocks,
                              "mtime": e.mtime})

    def read(self, fid: int, uid: str = "") -> int:
        """Touch atime; transparently restores released files (Lustre does)."""
        with self._lock:
            node = self._node(fid)
            node.entry.atime = self.clock()
            if node.entry.hsm_state == HsmState.RELEASED:
                self.hsm_restore(fid, uid=uid)
            return node.entry.size

    def setattr(self, fid: int, uid: str = "", **attrs) -> None:
        with self._lock:
            node = self._node(fid)
            e = node.entry
            for k, v in attrs.items():
                setattr(e, k, v)
            e.ctime = self.clock()
            self._emit(e.parent_fid, ChangelogType.SATTR, fid, name=e.name,
                       uid=uid, attrs=dict(attrs))

    def rename(self, fid: int, new_parent: int, new_name: str,
               uid: str = "") -> None:
        with self._lock:
            node = self._node(fid)
            e = node.entry
            old_parent = self._node(e.parent_fid)
            old_parent.children.pop(e.name, None)
            npnode = self._node(new_parent)
            npnode.children[new_name] = fid
            e.parent_fid, e.name = new_parent, new_name
            e.path = npnode.entry.path.rstrip("/") + "/" + new_name
            e.ctime = self.clock()
            self._fix_paths(fid)
            self._emit(new_parent, ChangelogType.RENME, fid, name=new_name,
                       uid=uid, attrs={"path": e.path})

    def _fix_paths(self, fid: int) -> None:
        node = self._nodes[fid]
        for name, cfid in node.children.items():
            ce = self._nodes[cfid].entry
            ce.path = node.entry.path.rstrip("/") + "/" + name
            if ce.type == FsType.DIR:
                self._fix_paths(cfid)

    def unlink(self, fid: int, uid: str = "", jobid: str = "") -> None:
        with self._lock:
            node = self._node(fid)
            e = node.entry
            if e.type == FsType.DIR:
                if node.children:
                    raise OSError("directory not empty")
                type_ = ChangelogType.RMDIR
            else:
                type_ = ChangelogType.UNLNK
                per = node.data_len // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
                for idx in e.stripe_osts:
                    self.osts[idx].free(per)
            parent = self._nodes.get(e.parent_fid)
            if parent:
                parent.children.pop(e.name, None)
            del self._nodes[fid]
            self._emit(e.parent_fid, type_, fid, name=e.name, uid=uid,
                       jobid=jobid)

    # -- HSM operations (C8) -----------------------------------------------------
    def hsm_archive(self, fid: int, archive_id: int = 1, uid: str = "") -> None:
        with self._lock:
            node = self._node(fid)
            e = node.entry
            if self.hsm is None:
                raise RuntimeError("no HSM backend attached")
            e.hsm_state = HsmState.ARCHIVING
            self.hsm.put(fid, e.size, archive_id)
            node.archived_len = e.size
            e.hsm_state = HsmState.ARCHIVED
            e.archive_id = archive_id
            self._emit(e.parent_fid, ChangelogType.HSM, fid, uid=uid,
                       attrs={"hsm_state": int(HsmState.ARCHIVED),
                              "archive_id": archive_id})

    def hsm_release(self, fid: int, uid: str = "") -> None:
        """Punch data from OSTs; entry stays visible (stub)."""
        with self._lock:
            node = self._node(fid)
            e = node.entry
            if e.hsm_state != HsmState.ARCHIVED:
                raise RuntimeError(f"cannot release fid {fid}: not archived")
            per = node.data_len // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
            for idx in e.stripe_osts:
                self.osts[idx].free(per)
            node.data_len = 0
            e.blocks = 0
            e.hsm_state = HsmState.RELEASED
            self._emit(e.parent_fid, ChangelogType.HSM, fid, uid=uid,
                       attrs={"hsm_state": int(HsmState.RELEASED), "blocks": 0})

    def hsm_restore(self, fid: int, uid: str = "") -> None:
        with self._lock:
            node = self._node(fid)
            e = node.entry
            if self.hsm is None or not self.hsm.has(fid):
                e.hsm_state = HsmState.LOST
                raise RuntimeError(f"HSM copy of fid {fid} lost")
            e.hsm_state = HsmState.RESTORING
            size = self.hsm.get(fid)
            per = size // max(1, len(e.stripe_osts)) if e.stripe_osts else 0
            for idx in e.stripe_osts:
                self.osts[idx].alloc(per)
            node.data_len = size
            e.blocks = size
            e.hsm_state = HsmState.ARCHIVED
            self._emit(e.parent_fid, ChangelogType.HSM, fid, uid=uid,
                       attrs={"hsm_state": int(HsmState.ARCHIVED),
                              "blocks": size})

    # -- FsBackend interface (for the scanner) ------------------------------------
    def root_fid(self) -> int:
        return 1

    def readdir(self, fid: int) -> List[Tuple[str, int]]:
        with self._lock:
            return list(self._node(fid).children.items())

    def stat(self, fid: int) -> Optional[Entry]:
        with self._lock:
            node = self._nodes.get(fid)
            if node is None:
                return None
            e = node.entry
            # return a copy so catalog mutations never alias FS state
            import dataclasses
            return dataclasses.replace(e, xattrs=dict(e.xattrs),
                                       stripe_osts=tuple(e.stripe_osts))

    def stat_batch(self, fids) -> List[Optional[Entry]]:
        """Stat many fids under ONE namespace lock acquisition.

        The per-entry copy bypasses ``dataclasses.replace`` (which
        re-runs ``__init__`` field by field) with a ``__dict__`` copy —
        the same bulk-construction idiom as ``CatalogShard.get_batch`` —
        so the columnar pipeline's GET_INFO stage costs a dict copy per
        surviving fid, not a dataclass construction per record.
        """
        out: List[Optional[Entry]] = []
        new = Entry.__new__
        with self._lock:
            nodes = self._nodes
            for fid in fids:
                node = nodes.get(fid)
                if node is None:
                    out.append(None)
                    continue
                e = node.entry
                c = new(Entry)
                d = dict(e.__dict__)
                d["xattrs"] = dict(e.xattrs)
                d["stripe_osts"] = tuple(e.stripe_osts)
                c.__dict__ = d
                out.append(c)
        return out

    def count(self) -> int:
        with self._lock:
            return len(self._nodes)
