"""`rbh-report` / `rbh-find` / `rbh-du` clones (C6, C9) — answer from the DB.

All queries here run against the catalog (vectorized column masks) or the
pre-aggregated stats — never against the filesystem, which is the paper's
point: *"all these metadata queries do not generate extra load on the
filesystem"*.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from .catalog import Catalog
from .policy import Expr, parse_expr
from .stats import StatsAggregator
from .types import FsType, format_size


class Reports:
    def __init__(self, catalog: Catalog, stats: Optional[StatsAggregator] = None,
                 clock=time.time) -> None:
        self.catalog = catalog
        self.stats = stats
        self.clock = clock

    # -- rbh-report ---------------------------------------------------------------
    def report_user(self, user: str) -> List[dict]:
        """O(1) per-user summary (pre-aggregated)."""
        if self.stats is None:
            raise RuntimeError("stats aggregator not attached")
        return self.stats.report_user(user)

    def format_user_report(self, user: str) -> str:
        rows = self.report_user(user)
        lines = ["user, type, count, spc_used, avg_size"]
        for r in rows:
            lines.append(f"{r['user']}, {r['type']}, {r['count']}, "
                         f"{format_size(r['spc_used'])}, "
                         f"{format_size(r['avg_size'])}")
        return "\n".join(lines)

    # -- rbh-find -----------------------------------------------------------------
    def find(self, criteria: str, limit: int = 0) -> List[str]:
        """DB-backed `find`: returns matching paths."""
        expr = parse_expr(criteria)
        cols = self.catalog.arrays()
        mask = expr.mask(cols, self.catalog.strings, self.clock())
        idx = np.nonzero(mask)[0]
        if limit:
            idx = idx[:limit]
        paths = cols["_paths"]
        return [paths[i] for i in idx]

    # -- rbh-du --------------------------------------------------------------------
    def du(self, path_prefix: str) -> dict:
        """DB-backed `du -s`: aggregate a subtree with one vector pass."""
        cols = self.catalog.arrays()
        prefix = path_prefix.rstrip("/")
        paths = cols["_paths"]
        mask = np.fromiter(
            (p == prefix or p.startswith(prefix + "/") for p in paths),
            dtype=bool, count=len(paths))
        file_mask = mask & (cols["type"] == int(FsType.FILE))
        return {
            "count": int(mask.sum()),
            "files": int(file_mask.sum()),
            "volume": int(cols["size"][file_mask].sum()),
            "spc_used": int(cols["blocks"][file_mask].sum()),
        }

    # -- top-N listings (paper SII-B3) ----------------------------------------------
    def top_files(self, by: str = "size", k: int = 10,
                  desc: bool = True) -> List[dict]:
        cols = self.catalog.arrays()
        fidx = np.nonzero(cols["type"] == int(FsType.FILE))[0]
        vals = cols[by][fidx]
        if vals.size == 0:
            return []
        k = min(k, vals.size)
        order = np.argsort(vals, kind="stable")
        order = order[::-1][:k] if desc else order[:k]
        paths = cols["_paths"]
        return [{"path": paths[fidx[o]], by: float(vals[o]),
                 "fid": int(cols["fid"][fidx[o]])} for o in order]

    def top_dirs_by_count(self, k: int = 10) -> List[dict]:
        """Top directories by direct child count (one vector groupby)."""
        cols = self.catalog.arrays()
        parents = cols["parent_fid"]
        uniq, counts = np.unique(parents[parents >= 0], return_counts=True)
        if uniq.size == 0:
            return []
        k = min(k, uniq.size)
        top = np.argsort(counts)[::-1][:k]
        out = []
        for i in top:
            e = self.catalog.get(int(uniq[i]))
            out.append({"path": e.path if e else f"fid:{int(uniq[i])}",
                        "children": int(counts[i])})
        return out

    def oldest_files(self, k: int = 10) -> List[dict]:
        return self.top_files(by="atime", k=k, desc=False)
