"""Property suite for the columnar fold: the vectorized last-write-wins
fold (``fold_columnar``) must equal the record-order scalar fold on every
interleaving of CREAT/UNLNK/SETATTR/... storms, and the end-to-end
columnar pipeline must land on the identical catalog state as the
record-at-a-time oracle across arbitrary batch boundaries.

The deterministic seeded sweeps always run; the hypothesis generators
ride on top when the package is available (same oracle, wider search).
"""
import numpy as np
import pytest

from repro.core import (Catalog, ChangelogType, EventPipeline,
                        PipelineConfig, fold_columnar)
from repro.fs import LustreSim

_RM = (int(ChangelogType.UNLNK), int(ChangelogType.RMDIR))
_BORN = (int(ChangelogType.CREAT), int(ChangelogType.MKDIR))
_ALL_TYPES = [int(t) for t in ChangelogType]


def scalar_fold(fids, types):
    """Record-order reference fold: dict insertion + last-write-wins."""
    first, last = {}, {}
    for f, t in zip(fids, types):
        if f not in first:
            first[f] = t
        last[f] = t
    survivors = sorted(f for f, t in last.items() if t not in _RM)
    removed = sorted(f for f, t in last.items() if t in _RM)
    annihilated = sorted(f for f in removed if first[f] in _BORN)
    dedup = len(fids) - len(last)
    return survivors, removed, annihilated, dedup


def _check_fold(fids, types):
    fr = fold_columnar(np.asarray(fids, dtype=np.int64),
                       np.asarray(types, dtype=np.int8))
    survivors, removed, annihilated, dedup = scalar_fold(fids, types)
    assert fr.survivors.tolist() == survivors
    assert fr.removed.tolist() == removed
    assert fr.annihilated.tolist() == annihilated
    assert fr.dedup == dedup
    # removal classification and survivor set partition the uniques
    assert len(survivors) + len(removed) == len(set(fids))


def test_fold_empty_and_singletons():
    _check_fold([], [])
    for t in _ALL_TYPES:
        _check_fold([7], [t])


def test_fold_create_unlink_annihilation():
    _check_fold([1, 1], [int(ChangelogType.CREAT), int(ChangelogType.UNLNK)])
    # pre-existing fid removed: removed but NOT annihilated
    _check_fold([1, 1], [int(ChangelogType.SATTR), int(ChangelogType.UNLNK)])
    # removal then more records never happens for real fids, but the fold
    # is still well-defined: last op wins
    _check_fold([1, 1], [int(ChangelogType.UNLNK), int(ChangelogType.SATTR)])


def test_fold_setattr_storm_dedups():
    fids = [5] * 100 + [9]
    types = [int(ChangelogType.SATTR)] * 100 + [int(ChangelogType.CREAT)]
    _check_fold(fids, types)
    fr = fold_columnar(np.asarray(fids, np.int64), np.asarray(types, np.int8))
    assert fr.dedup == 99 and fr.survivors.tolist() == [5, 9]


@pytest.mark.parametrize("seed", range(12))
def test_fold_random_interleavings(seed):
    """Seeded sweep: random fid reuse under every op type, sizes that
    straddle the no-duplicate fast path (uniq.size == n) both ways."""
    rng = np.random.default_rng(seed)
    for _ in range(40):
        n = int(rng.integers(1, 200))
        n_fids = int(rng.integers(1, max(2, n)))
        fids = rng.integers(1, n_fids + 1, size=n).tolist()
        types = rng.choice(_ALL_TYPES, size=n).tolist()
        _check_fold(fids, types)


# -- end-to-end batch-boundary invariance -------------------------------------

def _random_workload(rng, n_ops=250):
    """Random create/write/unlink/mkdir program against a 2-MDT sim."""
    fs = LustreSim(n_mdts=2)
    dirs = [fs.mkdir(fs.root_fid(), f"d{i}") for i in range(4)]
    live = []
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.35 or not live:
            f = fs.create(dirs[int(rng.integers(0, 4))],
                          f"f{int(rng.integers(0, 10 ** 9))}",
                          owner=f"u{int(rng.integers(0, 3))}",
                          uid=f"u{int(rng.integers(0, 3))}")
            live.append(f)
        elif op < 0.85:
            # hot-spot writes: 90% hit the first few files (dedup storm)
            if rng.random() < 0.9 and len(live) > 3:
                f = live[int(rng.integers(0, 3))]
            else:
                f = live[int(rng.integers(0, len(live)))]
            fs.write(f, int(rng.integers(1, 50)) * 10, uid="u0")
        else:
            f = live.pop(int(rng.integers(0, len(live))))
            fs.unlink(f)
    # a never-acking subscriber pins the records so the same stream can
    # be replayed by several mirrors (acks purge otherwise)
    fs.changelog.subscribe("retain", from_start=True)
    return fs


def _mirror(fs, columnar, batch_size):
    cat = Catalog(n_shards=2)
    pipe = EventPipeline(fs, cat, fs.changelog,
                         PipelineConfig(columnar=columnar,
                                        batch_size=batch_size))
    pipe.process_once(10 ** 7)
    for s in fs.changelog.streams.values():
        s.reset_cursor()
        # rewind so the next mirror replays the same records
        sub = s._sub(None)
        sub.read_cursor = 0
        sub.acked = 0
    return {e.fid: (e.name, e.path, int(e.type), e.size, e.owner, e.group)
            for e in cat.entries()}


@pytest.mark.parametrize("seed", range(6))
def test_columnar_equals_oracle_across_batch_boundaries(seed):
    """The folded catalog mirror is invariant under batch size and equals
    the record-at-a-time oracle on the same random interleaving."""
    rng = np.random.default_rng(100 + seed)
    fs = _random_workload(rng)
    ref = _mirror(fs, columnar=False, batch_size=512)
    for batch_size in (1, 3, 17, 128, 10 ** 6):
        assert _mirror(fs, columnar=True, batch_size=batch_size) == ref, \
            f"columnar mirror diverged at batch_size={batch_size}"
    assert _mirror(fs, columnar=False, batch_size=7) == ref


# -- hypothesis layer (skipped when the package is absent) --------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                   # seeded sweeps above still run
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(st.lists(st.tuples(st.integers(1, 12),
                              st.sampled_from(_ALL_TYPES)),
                    max_size=300))
    @settings(max_examples=200, deadline=None)
    def test_fold_matches_scalar_reference(ops):
        fids = [f for f, _ in ops]
        types = [t for _, t in ops]
        _check_fold(fids, types)

    @pytest.mark.slow
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 5, 33, 10 ** 6]))
    @settings(max_examples=20, deadline=None)
    def test_e2e_mirror_invariant_under_batching(seed, batch_size):
        rng = np.random.default_rng(seed)
        fs = _random_workload(rng, n_ops=120)
        ref = _mirror(fs, columnar=False, batch_size=512)
        assert _mirror(fs, columnar=True, batch_size=batch_size) == ref
