"""Fault tolerance: failure detection, checkpoint/restart, stragglers.

On a 1000+-node cluster the runtime must assume hosts fail mid-step. The
JAX SPMD model restarts the whole job from the last checkpoint when a host
is lost; what the framework owns is (a) detecting the loss fast
(heartbeats), (b) making restarts cheap (frequent, atomic checkpoints,
restored elastically onto the surviving mesh — runtime/elastic.py), and
(c) not letting one slow host starve the input pipeline (redundant data
shards).

Hosts are simulated in-process (threads + injected failures) so the full
detect -> restore -> replay path is exercised by tests on CPU.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Callable, Dict, List, Optional

from .checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Raised inside the train loop when a 'host' dies."""

    def __init__(self, host: int, step: int) -> None:
        super().__init__(f"host {host} failed at step {step}")
        self.host = host
        self.step = step


class HeartbeatMonitor:
    """Tracks per-host heartbeats; declares hosts dead after a timeout."""

    def __init__(self, n_hosts: int, timeout: float = 1.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.n_hosts = n_hosts
        self.timeout = timeout
        self.clock = clock
        self._lock = threading.Lock()
        now = clock()
        self._last: Dict[int, float] = {h: now for h in range(n_hosts)}
        self._dead: set = set()

    def beat(self, host: int) -> None:
        with self._lock:
            if host not in self._dead:
                self._last[host] = self.clock()

    def mark_dead(self, host: int) -> None:
        with self._lock:
            self._dead.add(host)

    def revive(self, host: int) -> None:
        with self._lock:
            self._dead.discard(host)
            self._last[host] = self.clock()

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        with self._lock:
            return sorted(self._dead | {
                h for h, t in self._last.items() if now - t > self.timeout})

    def healthy(self) -> bool:
        return not self.dead_hosts()


def run_with_restarts(train_steps: int,
                      step_fn: Callable[[object, int], object],
                      init_state: Callable[[], object],
                      ckpt: CheckpointManager,
                      ckpt_interval: int = 10,
                      max_restarts: int = 5,
                      on_restart: Optional[Callable[[int, int], None]] = None
                      ) -> tuple:
    """Drive a train loop to completion across simulated failures.

    ``step_fn(state, step)`` may raise :class:`SimulatedFailure`; the driver
    restores the last checkpoint and replays from there. Returns
    (final_state, restarts, steps_replayed).
    """
    state = init_state()
    step = 0
    restarts = 0
    replayed = 0
    while step < train_steps:
        try:
            state = step_fn(state, step)
            step += 1
            if step % ckpt_interval == 0:
                ckpt.save(state, step)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            try:
                state, restored_step = ckpt.restore(like=state)
            except FileNotFoundError:
                state, restored_step = init_state(), 0
            replayed += step - restored_step
            if on_restart is not None:
                on_restart(step, restored_step)
            step = restored_step
    return state, restarts, replayed


class RedundantShardRouter:
    """Straggler mitigation for the input pipeline.

    Every data shard is assigned to ``replication`` hosts; a global step
    consumes each shard from whichever replica responds first, so one slow
    host delays nothing as long as a replica is healthy. (This is the
    standard backup-request trick applied to data loading; compute-side
    stragglers are lockstep in SPMD and are handled by restart instead.)
    """

    def __init__(self, n_shards: int, n_hosts: int,
                 replication: int = 2) -> None:
        self.n_shards = n_shards
        self.n_hosts = n_hosts
        self.replication = min(replication, n_hosts)
        self.assignment: Dict[int, List[int]] = {
            s: [(s + r) % n_hosts for r in range(self.replication)]
            for s in range(n_shards)}

    def hosts_for(self, shard: int) -> List[int]:
        return self.assignment[shard]

    def pick(self, shard: int, latency: Callable[[int], float]) -> int:
        """The replica that answers first under the given latency model."""
        return min(self.hosts_for(shard), key=latency)

    def coverage_without(self, dead: List[int]) -> float:
        """Fraction of shards still readable if ``dead`` hosts are lost."""
        alive = 0
        for s in range(self.n_shards):
            if any(h not in dead for h in self.hosts_for(s)):
                alive += 1
        return alive / max(1, self.n_shards)
