"""Minimal backend interface the scanner and pipeline consume."""
from __future__ import annotations

from typing import Iterable, List, Optional, Protocol, Tuple

from ..core.types import Entry


class FsBackend(Protocol):
    """What Robinhood needs from a filesystem: readdir + stat, by fid."""

    def root_fid(self) -> int: ...

    def readdir(self, fid: int) -> List[Tuple[str, int]]:
        """(name, child_fid) pairs of a directory."""
        ...

    def stat(self, fid: int) -> Optional[Entry]: ...


def stat_batch(fs, fids: Iterable[int]) -> List[Optional[Entry]]:
    """Batched stat with a scalar fallback.

    The columnar ingest plane resolves every surviving fid of a folded
    batch in one call; backends that can serve it under a single lock
    (``LustreSim.stat_batch``) export their own, everything else gets the
    per-fid loop here.
    """
    batched = getattr(fs, "stat_batch", None)
    if batched is not None:
        return batched(fids)
    return [fs.stat(f) for f in fids]
