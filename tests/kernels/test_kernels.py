"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, never hard-error collection
from hypothesis import given, settings, strategies as st

from repro.core.catalog import StringTable
from repro.core.policy import KERNEL_COLUMNS, compile_program, parse_expr

# ---------------------------------------------------------------------------
# policy_scan
# ---------------------------------------------------------------------------


def _random_cols(rng, n):
    cols = np.zeros((len(KERNEL_COLUMNS), n), np.float32)
    cols[KERNEL_COLUMNS.index("size")] = rng.integers(0, 1 << 32, n)
    cols[KERNEL_COLUMNS.index("blocks")] = rng.integers(0, 1 << 32, n)
    cols[KERNEL_COLUMNS.index("owner")] = rng.integers(0, 4, n)
    cols[KERNEL_COLUMNS.index("type")] = rng.integers(0, 2, n)
    cols[KERNEL_COLUMNS.index("atime")] = 1e6 - rng.integers(0, 1e5, n)
    return cols


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), n=st.sampled_from([17, 100, 1024, 3000]))
def test_policy_scan_kernel_vs_ref(seed, n):
    from repro.kernels.policy_scan.ops import policy_scan
    from repro.kernels.policy_scan.ref import policy_scan_ref
    rng = np.random.default_rng(seed)
    st_ = StringTable()
    st_.intern("u0"), st_.intern("u1"), st_.intern("u2")
    cols = _random_cols(rng, n)
    expr = parse_expr("(size > 1GB or owner == 'u1') and type == file")
    ops, ci, opr = compile_program(expr, st_, now=1e6)
    args = (jnp.asarray(cols), jnp.asarray(ops), jnp.asarray(ci),
            jnp.asarray(opr))
    kw = dict(size_col=KERNEL_COLUMNS.index("size"),
              blocks_col=KERNEL_COLUMNS.index("blocks"))
    mask_k, agg_k = policy_scan(*args, **kw)
    mask_r, agg_r = policy_scan_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(mask_k), np.asarray(mask_r))
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_r),
                               rtol=1e-5, atol=1)


@pytest.mark.parametrize("seed,n", [(0, 17), (1, 100), (2, 1024), (3, 3000)])
def test_policy_scan_batch_kernel_vs_ref(seed, n):
    """Single-launch (R, P) batch kernel == batch oracle == per-program
    single kernel: masks, fused attribution, per-program aggregates."""
    from repro.core.policy import compile_programs
    from repro.kernels.policy_scan.ops import policy_scan, policy_scan_batch
    rng = np.random.default_rng(seed)
    st_ = StringTable()
    st_.intern("u0"), st_.intern("u1"), st_.intern("u2")
    cols = _random_cols(rng, n)
    exprs = [parse_expr("(size > 1GB or owner == 'u1') and type == file"),
             parse_expr("size > 1GB"),
             parse_expr("owner == 'u1'"),
             parse_expr("not (type == file and size <= 32M)")]
    ops, ci, opr = compile_programs(exprs, st_, now=1e6)
    kw = dict(size_col=KERNEL_COLUMNS.index("size"),
              blocks_col=KERNEL_COLUMNS.index("blocks"))
    jc = jnp.asarray(cols)
    masks_k, rule_k, agg_k = policy_scan_batch(
        jc, jnp.asarray(ops), jnp.asarray(ci), jnp.asarray(opr),
        use_kernel=True, **kw)
    masks_r, rule_r, agg_r = policy_scan_batch(
        jc, jnp.asarray(ops), jnp.asarray(ci), jnp.asarray(opr),
        use_kernel=False, **kw)
    np.testing.assert_allclose(np.asarray(masks_k), np.asarray(masks_r))
    np.testing.assert_array_equal(np.asarray(rule_k), np.asarray(rule_r))
    np.testing.assert_allclose(np.asarray(agg_k), np.asarray(agg_r),
                               rtol=1e-5, atol=1)
    # per-program single launches see the identical masks and aggregates
    for r in range(ops.shape[0]):
        m1, a1 = policy_scan(jc, jnp.asarray(ops[r]), jnp.asarray(ci[r]),
                             jnp.asarray(opr[r]), use_kernel=True, **kw)
        np.testing.assert_allclose(np.asarray(masks_k)[r], np.asarray(m1))
        np.testing.assert_allclose(np.asarray(agg_k)[r], np.asarray(a1),
                                   rtol=1e-5, atol=1)
    # attribution: first-match-wins over programs 1..R-1, -1 when none
    mk = np.asarray(masks_k) > 0.5
    expect = np.argmax(mk[1:], axis=0).astype(np.int32)
    expect[~mk[1:].any(axis=0)] = -1
    np.testing.assert_array_equal(np.asarray(rule_k), expect)


def test_policy_scan_end_to_end_catalog():
    from repro.core import Catalog, Entry, FsType
    from repro.kernels.policy_scan.ops import scan_catalog
    cat = Catalog()
    for i in range(1, 300):
        cat.upsert(Entry(fid=i, name=f"f{i}", path=f"/f{i}",
                         type=FsType.FILE, size=i * 1000, blocks=i * 1000,
                         owner="foo" if i % 3 else "bar"))
    expr = parse_expr("size > 100000 and owner == 'foo'")
    fids, agg = scan_catalog(cat, expr, now=time.time())
    truth = [e.fid for e in cat.entries()
             if e.size > 100000 and e.owner == "foo"]
    assert sorted(fids.tolist()) == sorted(truth)
    assert agg["count"] == len(truth)
    assert agg["volume"] == sum(e.size for e in cat.entries()
                                if e.fid in set(truth))


# ---------------------------------------------------------------------------
# profile_cube
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,b", [(0, 17, 3), (1, 100, 1), (2, 1024, 40),
                                      (3, 3000, 21)])
def test_profile_cube_kernel_vs_ref(seed, n, b):
    """Fused bucketize + segment-reduce kernel == scatter-add oracle ==
    scalar bucket functions (exact, f32-safe sizes)."""
    from repro.core.types import age_profile_bucket, size_profile_bucket
    from repro.kernels.profile_cube.ops import profile_cube
    rng = np.random.default_rng(seed)
    gid = rng.integers(0, b, n)
    size = rng.integers(0, 1 << 13, n)          # f32-exact sums per cell
    blocks = rng.integers(0, 1 << 13, n)
    age = rng.uniform(-100, 400 * 86400, n)
    kern = profile_cube(gid, size, blocks, age, n_groups=b, use_kernel=True)
    ref = profile_cube(gid, size, blocks, age, n_groups=b, use_kernel=False)
    np.testing.assert_array_equal(kern, ref)
    truth = np.zeros_like(kern, dtype=np.int64)
    for g, s, bl, a in zip(gid, size, blocks, age):
        sb, ab = size_profile_bucket(int(s)), age_profile_bucket(float(a))
        truth[0, g, sb, ab] += 1
        truth[1, g, sb, ab] += int(s)
        truth[2, g, sb, ab] += int(bl)
    np.testing.assert_array_equal(np.rint(kern).astype(np.int64), truth)


def test_profile_cube_valid_mask_and_edge_shapes():
    from repro.kernels.profile_cube.ops import MAX_GROUPS, profile_cube
    n = 50
    rng = np.random.default_rng(9)
    gid = rng.integers(0, 4, n)
    size = rng.integers(0, 1 << 10, n)
    valid = (np.arange(n) % 2 == 0).astype(np.float32)
    cube = profile_cube(gid, size, size, np.zeros(n), n_groups=4,
                        valid=valid, use_kernel=True)
    assert cube[0].sum() == valid.sum()
    # zero rows / zero groups
    empty = profile_cube(np.zeros(0), np.zeros(0), np.zeros(0), np.zeros(0),
                         n_groups=0)
    assert empty.shape[1] == 0
    with pytest.raises(ValueError):
        profile_cube(gid, size, size, np.zeros(n), n_groups=MAX_GROUPS + 1)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hkp", [(8, 4, 16), (4, 4, 8), (8, 1, 32)])
def test_paged_attention_sweep(dtype, hkp):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    H, K, P = hkp
    rng = np.random.default_rng(hash(hkp) % 2**31)
    B, hd, n_pages, max_pages = 2, 32, 16, 4
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((n_pages, P, K, hd)), dtype)
    vp = jnp.asarray(rng.standard_normal((n_pages, P, K, hd)), dtype)
    pt = np.full((B, max_pages), -1, np.int32)
    lens = np.zeros(B, np.int32)
    for b in range(B):
        n = rng.integers(1, max_pages + 1)
        pt[b, :n] = rng.choice(n_pages, n, replace=False)
        lens[b] = rng.integers((n - 1) * P + 1, n * P + 1)
    out_k = paged_attention(q, kp, vp, jnp.asarray(pt), jnp.asarray(lens))
    out_r = paged_attention_ref(q, kp, vp, jnp.asarray(pt),
                                jnp.asarray(lens))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol,
                               rtol=tol)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 16, 128), (2, 64, 256), (3, 128, 128)])
def test_rglru_kernel_sweep(shape):
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_ref
    B, S, R = shape
    rng = np.random.default_rng(S)
    la = jnp.asarray(-np.abs(rng.standard_normal((B, S, R))) * 0.2,
                     jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, R)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, R)), jnp.float32)
    np.testing.assert_allclose(np.asarray(rglru_scan(la, b, h0)),
                               np.asarray(rglru_ref(la, b, h0)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 2, 16), (2, 4, 64), (4, 8, 32)])
def test_rwkv6_step_sweep(shape):
    from repro.kernels.rwkv6_step.ops import rwkv6_step
    from repro.kernels.rwkv6_step.ref import rwkv6_step_ref
    B, H, hd = shape
    rng = np.random.default_rng(hd)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    r, k, v = mk(B, H, hd), mk(B, H, hd), mk(B, H, hd)
    w = jnp.asarray(rng.uniform(0.3, 1.0, (B, H, hd)), jnp.float32)
    u, s0 = mk(H, hd), mk(B, H, hd, hd)
    yk, sk = rwkv6_step(r, k, v, w, u, s0)
    yr, sr = rwkv6_step_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=1e-5)
