"""Columnar, sharded metadata catalog — the paper's "database" (C1).

The paper stores the filesystem-metadata mirror in MySQL and observes
(SIII-B) that a single DB host becomes the bottleneck once DNE spreads the
namespace over several MDSes; it names catalog *sharding* as the way out.
This implementation builds that future directly:

* entries live in N independent **shards** (hash of fid), each with its own
  lock, so concurrent changelog streams (one per MDT) never contend;
* each shard is **columnar** (struct-of-arrays, numpy): policy predicates and
  report aggregations run as vectorized column masks — the in-process
  analogue of a DB table scan, and the exact memory layout consumed by the
  ``policy_scan`` Pallas kernel on TPU;
* durability is sqlite WAL (optional): a batch of updates is committed to
  sqlite *before* the changelog reader acks, preserving the paper's
  transactional contract (SII-C2).

Strings (owner, group, pool, status) are interned to int32 codes in a shared
:class:`StringTable`, which is what makes vectorized/accelerator predicate
evaluation possible.
"""
from __future__ import annotations

import os
import sqlite3
import threading
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .telemetry import MetricRegistry, counter_attr
from .types import Entry, FsType, HsmState

# Stats/alert hooks receive these light tuples instead of full Entries.
# (fid, owner_code, group_code, type, size, blocks, hsm_state, atime) —
# everything the pre-aggregated stats and the profile cube need to apply a
# signed bucket update without re-reading the shard.
Delta = Tuple[int, int, int, int, int, int, int, float]

_NUMERIC_COLUMNS: Tuple[Tuple[str, np.dtype], ...] = (
    ("fid", np.int64),
    ("parent_fid", np.int64),
    ("type", np.int8),
    ("size", np.int64),
    ("blocks", np.int64),
    ("mode", np.int32),
    ("nlink", np.int32),
    ("atime", np.float64),
    ("mtime", np.float64),
    ("ctime", np.float64),
    ("ost_idx", np.int16),
    ("hsm_state", np.int8),
    ("archive_id", np.int32),
    ("owner", np.int32),     # interned code
    ("group", np.int32),     # interned code
    ("pool", np.int32),      # interned code
    ("status", np.int32),    # interned code (v3 generic-policy status)
    ("dirty", np.int8),
)
_STRING_FIELDS = ("owner", "group", "pool", "status")

# Enum instance caches: Enum.__call__ is surprisingly hot when a batch fetch
# rebuilds tens of thousands of entries.
_FSTYPE = {int(t): t for t in FsType}
_HSMSTATE = {int(s): s for s in HsmState}


class _StringSnapshot:
    """Frozen view of one shard's name/path lists + its valid row indices."""

    __slots__ = ("idx", "names", "paths")

    def __init__(self, idx: np.ndarray, names: List[str],
                 paths: List[str]) -> None:
        self.idx = idx
        self.names = names
        self.paths = paths

    def gather(self, attr: str) -> List[str]:
        src = self.paths if attr == "_paths" else self.names
        return [src[i] for i in self.idx]


class LazyColumns(dict):
    """Column dict whose expensive keys materialize on first access.

    ``Catalog.arrays()`` returns numeric columns eagerly (cheap vectorized
    copies) but defers the per-row ``_paths``/``_names`` python lists —
    only host-side glob predicates and path reports consume them, and
    building them dominates columnar matching cost on large catalogs.
    """

    def __init__(self, data: Dict[str, np.ndarray],
                 loaders: Dict[str, Callable[[], list]]) -> None:
        super().__init__(data)
        self._loaders = loaders

    def __missing__(self, key):
        fn = self._loaders.get(key)
        if fn is None:
            raise KeyError(key)
        val = fn()
        self[key] = val
        return val

    def __contains__(self, key) -> bool:
        return super().__contains__(key) or key in self._loaders


class ColumnBatch:
    """Entry-free columnar view of a set of catalog rows.

    The zero-materialization contract of the batched action path: a
    ``ColumnBatch`` carries every numeric column (fid/size/blocks/hsm_state/
    owner-code/... as numpy arrays aligned with the requested fid order)
    plus a ``present`` mask, WITHOUT constructing a single Python ``Entry``.
    Batch actions consume it directly; the few that genuinely need full
    ``Entry`` objects declare ``needs_entries = True`` (see
    ``core.plugins``) and the engine materializes for them alone.

    * numeric columns: attribute access (``batch.size``, ``batch.fid``) or
      ``batch.col(name)``;
    * interned string columns: ``batch.decode("owner")`` lazily decodes the
      int32 codes through the shared :class:`StringTable` (cached);
    * ``take(idx)`` slices a sub-batch (used for per-rule action groups);
    * ``entries()`` is the materializing escape hatch — one
      :meth:`Catalog.get_batch` call, cached; only ``needs_entries``
      plugins and the legacy benchmark path pay it.
    """

    __slots__ = ("cols", "present", "strings", "_catalog", "_decoded",
                 "_entries")

    def __init__(self, cols: Dict[str, np.ndarray], present: np.ndarray,
                 strings: "StringTable", catalog=None) -> None:
        self.cols = cols
        self.present = present
        self.strings = strings
        self._catalog = catalog
        self._decoded: Dict[str, list] = {}
        self._entries = None

    def __len__(self) -> int:
        return len(self.present)

    @property
    def fids(self) -> np.ndarray:
        return self.cols["fid"]

    def col(self, name: str) -> np.ndarray:
        return self.cols[name]

    def __getattr__(self, name: str):
        try:
            return self.cols[name]
        except KeyError:
            raise AttributeError(name) from None

    def decode(self, name: str) -> List[str]:
        """Lazily decode an interned string column (owner/group/pool/status)
        to a list of strings; absent rows decode to ''."""
        out = self._decoded.get(name)
        if out is None:
            lookup = self.strings.lookup
            out = [lookup(c) for c in self.cols[name].tolist()]
            self._decoded[name] = out
        return out

    def take(self, idx) -> "ColumnBatch":
        """Sub-batch at the given positions (int indices or bool mask)."""
        idx = np.asarray(idx)
        if idx.dtype == bool:
            idx = np.nonzero(idx)[0]
        idx = idx.astype(np.int64)
        pos = idx.tolist()
        cols = {k: (v[idx] if isinstance(v, np.ndarray)
                    else [v[i] for i in pos])           # _names/_paths lists
                for k, v in self.cols.items()}
        sub = ColumnBatch(cols, self.present[idx], self.strings,
                          self._catalog)
        if self._entries is not None:
            sub._entries = [self._entries[i] for i in idx.tolist()]
        return sub

    def entries(self) -> List[Optional[Entry]]:
        """Materialize full Entry objects (cached; the cost this view
        exists to avoid — only ``needs_entries`` actions trigger it)."""
        if self._entries is None:
            if self._catalog is None:
                raise RuntimeError("ColumnBatch has no catalog attached")
            self._entries = self._catalog.get_batch(self.fids.tolist())
        return self._entries

    @classmethod
    def from_entries(cls, entries: Sequence[Optional[Entry]],
                     strings: "StringTable", catalog=None) -> "ColumnBatch":
        """Build a batch from already-materialized entries (the legacy
        Entry-first execution path; pure overhead the columnar path skips).
        Absent entries (None) read 0 with ``present=False``."""
        n = len(entries)
        cols = {name: np.zeros(n, dtype=dt) for name, dt in _NUMERIC_COLUMNS}
        present = np.zeros(n, dtype=bool)
        for i, e in enumerate(entries):
            if e is None:
                continue
            present[i] = True
            cols["fid"][i] = e.fid
            cols["parent_fid"][i] = e.parent_fid
            cols["type"][i] = int(e.type)
            cols["size"][i] = e.size
            cols["blocks"][i] = e.blocks
            cols["mode"][i] = e.mode
            cols["nlink"][i] = e.nlink
            cols["atime"][i] = e.atime
            cols["mtime"][i] = e.mtime
            cols["ctime"][i] = e.ctime
            cols["ost_idx"][i] = e.ost_idx
            cols["hsm_state"][i] = int(e.hsm_state)
            cols["archive_id"][i] = e.archive_id
            cols["owner"][i] = strings.intern(e.owner)
            cols["group"][i] = strings.intern(e.group)
            cols["pool"][i] = strings.intern(e.pool)
            cols["status"][i] = strings.intern(e.status)
            cols["dirty"][i] = 1 if e.dirty else 0
        batch = cls(cols, present, strings, catalog)
        batch._entries = list(entries)
        return batch


class StringTable:
    """Bidirectional string<->int32 interning table (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._to_code: Dict[str, int] = {}
        self._to_str: List[str] = []
        self.intern("")  # code 0 is always the empty string

    def intern(self, s: str) -> int:
        with self._lock:
            code = self._to_code.get(s)
            if code is None:
                code = len(self._to_str)
                self._to_code[s] = code
                self._to_str.append(s)
            return code

    def lookup(self, code: int) -> str:
        return self._to_str[code]

    def code_of(self, s: str) -> Optional[int]:
        return self._to_code.get(s)

    def __len__(self) -> int:
        return len(self._to_str)


class CatalogShard:
    """One catalog shard: columnar entry store with amortized growth."""

    _INITIAL = 1024

    def __init__(self, shard_id: int, strings: StringTable) -> None:
        self.shard_id = shard_id
        self.strings = strings
        # per-shard change tick: bumped (under the shard lock) by every
        # mutation that lands in THIS shard, so per-shard derived caches
        # (Reports' path index, profile cubes) rebuild only the shards
        # that actually churned — Catalog.version stays the global tick.
        self.version = 0
        self.lock = threading.RLock()
        self._rows: Dict[int, int] = {}          # fid -> row index
        self._free: List[int] = []
        self._n = 0                               # high-water row count
        self._cols: Dict[str, np.ndarray] = {
            name: np.zeros(self._INITIAL, dtype=dt) for name, dt in _NUMERIC_COLUMNS
        }
        self._valid = np.zeros(self._INITIAL, dtype=bool)
        self._names: List[str] = [""] * self._INITIAL
        self._paths: List[str] = [""] * self._INITIAL
        self._xattrs: List[Optional[dict]] = [None] * self._INITIAL
        self._stripes: List[tuple] = [()] * self._INITIAL

    # -- storage management -------------------------------------------------
    def _grow(self) -> None:
        cap = len(self._valid)
        new_cap = cap * 2
        for name in self._cols:
            col = np.zeros(new_cap, dtype=self._cols[name].dtype)
            col[:cap] = self._cols[name]
            self._cols[name] = col
        valid = np.zeros(new_cap, dtype=bool)
        valid[:cap] = self._valid
        self._valid = valid
        self._names.extend([""] * cap)
        self._paths.extend([""] * cap)
        self._xattrs.extend([None] * cap)
        self._stripes.extend([()] * cap)

    def _alloc_row(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n >= len(self._valid):
            self._grow()
        row = self._n
        self._n += 1
        return row

    # -- entry operations ---------------------------------------------------
    def _row_delta(self, row: int) -> Delta:
        c = self._cols
        return (int(c["fid"][row]), int(c["owner"][row]),
                int(c["group"][row]), int(c["type"][row]),
                int(c["size"][row]), int(c["blocks"][row]),
                int(c["hsm_state"][row]), float(c["atime"][row]))

    def _upsert_locked(self, e: Entry) -> Tuple[Optional[Delta], Delta]:
        row = self._rows.get(e.fid)
        old: Optional[Delta] = None
        if row is None:
            row = self._alloc_row()
            self._rows[e.fid] = row
            self._valid[row] = True
        else:
            old = self._row_delta(row)
        c = self._cols
        c["fid"][row] = e.fid
        c["parent_fid"][row] = e.parent_fid
        c["type"][row] = int(e.type)
        c["size"][row] = e.size
        c["blocks"][row] = e.blocks
        c["mode"][row] = e.mode
        c["nlink"][row] = e.nlink
        c["atime"][row] = e.atime
        c["mtime"][row] = e.mtime
        c["ctime"][row] = e.ctime
        c["ost_idx"][row] = e.ost_idx
        c["hsm_state"][row] = int(e.hsm_state)
        c["archive_id"][row] = e.archive_id
        c["owner"][row] = self.strings.intern(e.owner)
        c["group"][row] = self.strings.intern(e.group)
        c["pool"][row] = self.strings.intern(e.pool)
        c["status"][row] = self.strings.intern(e.status)
        c["dirty"][row] = 1 if e.dirty else 0
        self._names[row] = e.name
        self._paths[row] = e.path
        self._xattrs[row] = dict(e.xattrs) if e.xattrs else None
        self._stripes[row] = tuple(e.stripe_osts)
        self.version += 1
        return old, self._row_delta(row)

    def upsert(self, e: Entry) -> Tuple[Optional[Delta], Delta]:
        """Insert or update an entry; returns (old_delta|None, new_delta)."""
        with self.lock:
            return self._upsert_locked(e)

    def upsert_many(self, entries: Sequence[Entry]
                    ) -> List[Tuple[Optional[Delta], Delta]]:
        """Upsert a batch under ONE lock acquisition (the columnar ingest
        commit path) — same per-entry semantics as :meth:`upsert`."""
        with self.lock:
            return [self._upsert_locked(e) for e in entries]

    def update_fields(self, fid: int, **fields) -> Optional[Tuple[Delta, Delta]]:
        """Patch a subset of attributes; returns (old, new) deltas or None."""
        with self.lock:
            row = self._rows.get(fid)
            if row is None:
                return None
            old = self._row_delta(row)
            c = self._cols
            for k, v in fields.items():
                if k in ("name",):
                    self._names[row] = v
                elif k in ("path",):
                    self._paths[row] = v
                elif k == "xattrs":
                    self._xattrs[row] = dict(v) if v else None
                elif k == "stripe_osts":
                    self._stripes[row] = tuple(v)
                elif k in _STRING_FIELDS:
                    c[k][row] = self.strings.intern(v)
                elif k == "hsm_state":
                    c[k][row] = int(v)
                elif k == "type":
                    c[k][row] = int(v)
                elif k == "dirty":
                    c[k][row] = 1 if v else 0
                else:
                    c[k][row] = v
            self.version += 1
            return old, self._row_delta(row)

    def _remove_locked(self, fid: int) -> Optional[Delta]:
        row = self._rows.pop(fid, None)
        if row is None:
            return None
        old = self._row_delta(row)
        self._valid[row] = False
        self._names[row] = self._paths[row] = ""
        self._xattrs[row] = None
        self._stripes[row] = ()
        self._free.append(row)
        self.version += 1
        return old

    def remove(self, fid: int) -> Optional[Delta]:
        with self.lock:
            return self._remove_locked(fid)

    def remove_many(self, fids: Sequence[int]) -> List[Optional[Delta]]:
        """Remove a batch under one lock acquisition; absent fids yield
        ``None`` (a same-batch CREAT→UNLNK annihilation lands here)."""
        with self.lock:
            return [self._remove_locked(f) for f in fids]

    def get(self, fid: int) -> Optional[Entry]:
        with self.lock:
            row = self._rows.get(fid)
            if row is None:
                return None
            return self._entry_at(row)

    def _entry_at(self, row: int) -> Entry:
        c = self._cols
        return Entry(
            fid=int(c["fid"][row]), parent_fid=int(c["parent_fid"][row]),
            name=self._names[row], path=self._paths[row],
            type=FsType(int(c["type"][row])), size=int(c["size"][row]),
            blocks=int(c["blocks"][row]), mode=int(c["mode"][row]),
            nlink=int(c["nlink"][row]), atime=float(c["atime"][row]),
            mtime=float(c["mtime"][row]), ctime=float(c["ctime"][row]),
            ost_idx=int(c["ost_idx"][row]),
            stripe_osts=self._stripes[row],
            pool=self.strings.lookup(int(c["pool"][row])),
            hsm_state=HsmState(int(c["hsm_state"][row])),
            archive_id=int(c["archive_id"][row]),
            owner=self.strings.lookup(int(c["owner"][row])),
            group=self.strings.lookup(int(c["group"][row])),
            status=self.strings.lookup(int(c["status"][row])),
            xattrs=self._xattrs[row] or {},
            dirty=bool(c["dirty"][row]),
        )

    def get_batch(self, fids: Sequence[int]) -> List[Optional[Entry]]:
        """Fetch many entries under a single lock acquisition.

        Columns are gathered vectorized (one fancy-index + tolist per
        column) instead of one scalar read per field per row — the policy
        engine's execution hot path.
        """
        with self.lock:
            rows = [self._rows.get(f) for f in fids]
            hit = [r for r in rows if r is not None]
            if not hit:
                return [None] * len(fids)
            idx = np.asarray(hit, dtype=np.int64)
            c = {name: self._cols[name][idx].tolist() for name in self._cols}
            lookup = self.strings.lookup
            new = Entry.__new__
            entries = []
            for i, row in enumerate(hit):
                # bulk construction bypasses dataclass __init__ (hot path)
                e = new(Entry)
                e.__dict__ = {
                    "fid": c["fid"][i], "parent_fid": c["parent_fid"][i],
                    "name": self._names[row], "path": self._paths[row],
                    "type": _FSTYPE[c["type"][i]], "size": c["size"][i],
                    "blocks": c["blocks"][i], "owner": lookup(c["owner"][i]),
                    "group": lookup(c["group"][i]), "mode": c["mode"][i],
                    "nlink": c["nlink"][i], "atime": c["atime"][i],
                    "mtime": c["mtime"][i], "ctime": c["ctime"][i],
                    "ost_idx": c["ost_idx"][i],
                    "stripe_osts": self._stripes[row],
                    "pool": lookup(c["pool"][i]),
                    "hsm_state": _HSMSTATE[c["hsm_state"][i]],
                    "archive_id": c["archive_id"][i],
                    "status": lookup(c["status"][i]),
                    "xattrs": self._xattrs[row] or {},
                    "dirty": bool(c["dirty"][i]),
                }
                entries.append(e)
        out: List[Optional[Entry]] = []
        it = iter(entries)
        for r in rows:
            out.append(next(it) if r is not None else None)
        return out

    _DELTA_COLS = ("fid", "owner", "group", "type", "size", "blocks",
                   "hsm_state", "atime")
    # fields the vectorized patch can broadcast: plain numeric columns
    # (string-interned / per-row python fields fall back to the scalar loop)
    _VECTOR_FIELDS = frozenset(
        name for name, _ in _NUMERIC_COLUMNS) - frozenset(_STRING_FIELDS)

    def update_fields_batch(self, fids: Sequence[int], fields: dict
                            ) -> List[Optional[Tuple[Delta, Delta]]]:
        """Patch the same field subset on many entries under one lock.

        When every field is a plain numeric column (the dirty-tag path:
        ``dirty=1``), the patch is **vectorized**: one fancy-index
        assignment per field over the present rows instead of a per-fid
        scalar write — and the old/new :class:`Delta` tuples are gathered
        with one fancy-index per delta column. Mixed patches (names,
        paths, xattrs, interned strings) keep the scalar loop.
        """
        if not all(k in self._VECTOR_FIELDS for k in fields):
            with self.lock:
                return [self.update_fields(f, **fields) for f in fids]
        with self.lock:
            rows = [self._rows.get(f) for f in fids]
            hit = [r for r in rows if r is not None]
            if not hit:
                return [None] * len(fids)
            idx = np.asarray(hit, dtype=np.int64)
            c = self._cols
            old_cols = [c[name][idx] for name in self._DELTA_COLS]
            for k, v in fields.items():
                if k == "hsm_state" or k == "type":
                    v = int(v)
                elif k == "dirty":
                    v = 1 if v else 0
                c[k][idx] = v
            new_cols = [c[name][idx] for name in self._DELTA_COLS]
            self.version += 1
            olds = list(zip(*(col.tolist() for col in old_cols)))
            news = list(zip(*(col.tolist() for col in new_cols)))
        out: List[Optional[Tuple[Delta, Delta]]] = []
        it = iter(zip(olds, news))
        for r in rows:
            out.append(next(it) if r is not None else None)
        return out

    # -- vectorized access ----------------------------------------------------
    def snapshot(self, names: Optional[Sequence[str]] = None,
                 with_strings: bool = True
                 ) -> Tuple[Dict[str, np.ndarray],
                            Optional["_StringSnapshot"]]:
        """Consistent columnar snapshot under one lock acquisition.

        Numeric columns are copied (restricted to ``names`` when given —
        aggregation consumers like the profile cube skip the other ~half
        of the column stack); ``_paths``/``_names`` are captured as
        shallow list copies (a C-level pointer copy — cheap) so the
        expensive per-row gather can happen lazily later while staying
        consistent with the numeric rows (in-place shard mutations after
        the snapshot cannot be observed). ``with_strings=False`` skips
        even the pointer copies (the snapshot returns ``None`` strings —
        purely numeric consumers).
        """
        with self.lock:
            valid = self._valid[: self._n]
            cols = {name: self._cols[name][: self._n][valid].copy()
                    for name in (names if names is not None else self._cols)}
            if not with_strings:
                return cols, None
            snap = _StringSnapshot(np.nonzero(valid)[0],
                                   list(self._names), list(self._paths))
            return cols, snap

    def arrays(self) -> Dict[str, np.ndarray]:
        """Columnar views (copies) limited to valid rows, for vector queries."""
        out, snap = self.snapshot()
        out["_paths"] = snap.gather("_paths")   # type: ignore
        out["_names"] = snap.gather("_names")   # type: ignore
        return out

    def _gather(self, fids: Sequence[int], names: Sequence[str]
                ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
        """Lock-held core of the fid-keyed gathers: (cols, safe_idx, present);
        absent fids read row 0 masked to the column dtype's zero."""
        idx = np.array([self._rows.get(f, -1) for f in fids], dtype=np.int64)
        present = idx >= 0
        safe = np.where(present, idx, 0)
        cols = {name: np.where(present, self._cols[name][safe],
                               self._cols[name].dtype.type(0))
                for name in names}
        return cols, safe, present

    def column_slice(self, fids: Sequence[int], names: Sequence[str]
                     ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Gather columns for specific fids without building Entry objects.

        Returns (cols, present): ``cols[name][i]`` is the value for
        ``fids[i]`` (0 where absent) and ``present[i]`` says whether the fid
        exists in this shard.
        """
        with self.lock:
            cols, _safe, present = self._gather(fids, names)
            return cols, present

    def row_slice(self, fids: Sequence[int], with_strings: bool = True
                  ) -> Tuple[Dict[str, np.ndarray], List[str], List[str],
                             np.ndarray]:
        """Full-row gather keyed by fid: every numeric column plus (when
        ``with_strings``) the name/path strings, under one lock acquisition.

        Returns (cols, names, paths, present) aligned with ``fids``; absent
        fids read 0 / "". This is the incremental-match analogue of
        :meth:`column_slice` — dirty rows are re-evaluated from it without
        touching the other ~N rows of the shard.
        """
        with self.lock:
            cols, safe, present = self._gather(fids, list(self._cols))
            if not with_strings:
                return cols, [], [], present
            names = [self._names[i] if p else ""
                     for i, p in zip(safe.tolist(), present.tolist())]
            paths = [self._paths[i] if p else ""
                     for i, p in zip(safe.tolist(), present.tolist())]
            return cols, names, paths, present

    def count(self) -> int:
        with self.lock:
            return len(self._rows)

    def fids(self) -> List[int]:
        with self.lock:
            return list(self._rows.keys())


class Catalog:
    """Sharded catalog facade: routing, hooks, persistence, vector queries."""

    # how often the full host column concat was asked for — the
    # mesh-resident report/profile paths assert this stays flat on warm
    # queries (tests/core/test_mesh_reports.py)
    arrays_calls = counter_attr(
        "catalog_arrays_calls", "full host column concatenations")

    def __init__(self, n_shards: int = 4, db_path: Optional[str] = None,
                 telemetry: Optional[MetricRegistry] = None) -> None:
        # the catalog anchors the deployment's telemetry plane: everything
        # attached to it (device store, reports, engine, pipeline) lands
        # series in this registry, disambiguated by instance labels
        self.telemetry = telemetry if telemetry is not None \
            else MetricRegistry()
        self._tlabels = {"catalog": self.telemetry.instance("catalog")}
        self.strings = StringTable()
        self.shards = [CatalogShard(i, self.strings) for i in range(n_shards)]
        self.n_shards = n_shards
        self._hooks: List[Callable[[Optional[Delta], Optional[Delta]], None]] = []
        self._batch_hooks: Dict[Callable, Callable] = {}
        self._entry_hooks: List[Callable[[Entry], None]] = []
        self.db_path = db_path
        self._db: Optional[sqlite3.Connection] = None
        self._db_lock = threading.Lock()
        self._version = 0
        self._version_lock = threading.Lock()
        self._arrays_cache: Optional[Tuple[int, "LazyColumns"]] = None
        self._arrays_lock = threading.Lock()
        self.arrays_calls = 0
        if db_path:
            self._open_db(db_path)

    # -- change tick ----------------------------------------------------------
    @property
    def version(self) -> int:
        """Monotonic change tick: bumped by every mutating operation.

        Readers (e.g. ``Reports``' sorted path index) cache derived
        structures keyed by it and rebuild only after the catalog changed.
        """
        return self._version

    def _bump(self) -> None:
        # Called AFTER a mutation is applied: a reader that caches under the
        # new version is then guaranteed to have seen the new data (a reader
        # racing the mutation itself caches under the old version and
        # rebuilds on its next check — one redundant rebuild, never stale).
        with self._version_lock:
            self._version += 1

    def sidecar_path(self, suffix: str) -> Optional[str]:
        """Path for a derived artifact stored beside the sqlite mirror
        (``<db_path>.<suffix>``) — e.g. the device store's packed warm
        segments — or ``None`` for an in-memory catalog (callers then
        keep the artifact in host memory instead)."""
        if not self.db_path:
            return None
        return f"{self.db_path}.{suffix}"

    # -- persistence ----------------------------------------------------------
    _SCHEMA = (
        "CREATE TABLE IF NOT EXISTS entries ("
        "fid INTEGER PRIMARY KEY, parent_fid INTEGER, name TEXT, path TEXT,"
        "type INTEGER, size INTEGER, blocks INTEGER, owner TEXT, grp TEXT,"
        "mode INTEGER, nlink INTEGER, atime REAL, mtime REAL, ctime REAL,"
        "ost_idx INTEGER, pool TEXT, hsm_state INTEGER, archive_id INTEGER,"
        "status TEXT, dirty INTEGER)"
    )

    def _open_db(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._db = sqlite3.connect(path, check_same_thread=False)
        self._db.execute("PRAGMA journal_mode=WAL")
        self._db.execute("PRAGMA synchronous=NORMAL")
        self._db.execute(self._SCHEMA)
        self._db.commit()

    def _persist(self, entries: Sequence[Entry], removed: Sequence[int]) -> None:
        if self._db is None:
            return
        with self._db_lock:
            if entries:
                self._db.executemany(
                    "INSERT OR REPLACE INTO entries VALUES "
                    "(?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?,?)",
                    [(e.fid, e.parent_fid, e.name, e.path, int(e.type), e.size,
                      e.blocks, e.owner, e.group, e.mode, e.nlink, e.atime,
                      e.mtime, e.ctime, e.ost_idx, e.pool, int(e.hsm_state),
                      e.archive_id, e.status, int(e.dirty)) for e in entries],
                )
            if removed:
                self._db.executemany("DELETE FROM entries WHERE fid=?",
                                     [(f,) for f in removed])
            self._db.commit()   # durable before changelog ack

    def load_from_db(self) -> int:
        """Crash recovery: repopulate shards from sqlite. Returns #entries."""
        if self._db is None:
            return 0
        n = 0
        with self._db_lock:
            cur = self._db.execute("SELECT * FROM entries")
            rows = cur.fetchall()
        for r in rows:
            e = Entry(fid=r[0], parent_fid=r[1], name=r[2], path=r[3],
                      type=FsType(r[4]), size=r[5], blocks=r[6], owner=r[7],
                      group=r[8], mode=r[9], nlink=r[10], atime=r[11],
                      mtime=r[12], ctime=r[13], ost_idx=r[14], pool=r[15],
                      hsm_state=HsmState(r[16]), archive_id=r[17],
                      status=r[18], dirty=bool(r[19]))
            self.upsert(e, persist=False)
            n += 1
        return n

    # -- hooks (stats aggregators, alerts) -------------------------------------
    def add_delta_hook(self, fn: Callable[[Optional[Delta], Optional[Delta]], None],
                       batch: Optional[Callable[[List[Tuple[Optional[Delta],
                                                            Optional[Delta]]]],
                                                None]] = None) -> None:
        """Register a delta consumer. ``fn(old, new)`` fires per mutation
        on the scalar paths; a consumer that also passes ``batch`` gets
        the whole committed batch in **one** call (``batch(pairs)``) on
        the batched paths instead of N scalar invocations — the single
        fan-out contract of the columnar ingest plane. Consumers without
        a batch variant still see every mutation (the batch dispatcher
        loops their scalar hook), so the two registration styles are
        behaviorally identical, batch-aware ones just pay one call."""
        self._hooks.append(fn)
        if batch is not None:
            self._batch_hooks[fn] = batch

    def remove_delta_hook(self, fn: Callable[[Optional[Delta], Optional[Delta]], None]) -> None:
        """Unregister a delta hook (no-op if absent) — long-lived catalogs
        must not keep feeding consumers that were replaced (e.g. a
        detached DeviceColumnStore)."""
        try:
            self._hooks.remove(fn)
        except ValueError:
            pass
        self._batch_hooks.pop(fn, None)

    def add_entry_hook(self, fn: Callable[[Entry], None]) -> None:
        """Entry-level hook (alerts need names/paths, not just deltas)."""
        self._entry_hooks.append(fn)

    def _fire(self, old: Optional[Delta], new: Optional[Delta]) -> None:
        for fn in self._hooks:
            fn(old, new)

    def _fire_batch(self, pairs: List[Tuple[Optional[Delta],
                                            Optional[Delta]]]) -> None:
        """Dispatch one committed batch to every delta consumer: one call
        for batch-registered hooks, a scalar loop for the rest."""
        if not pairs:
            return
        for fn in self._hooks:
            batch_fn = self._batch_hooks.get(fn)
            if batch_fn is not None:
                batch_fn(pairs)
            else:
                for old, new in pairs:
                    fn(old, new)

    # -- routing ----------------------------------------------------------------
    def _shard_id(self, fid: int) -> int:
        """Single routing authority — every scalar and batch path uses it."""
        return fid % self.n_shards

    def _shard_ids(self, fids: np.ndarray) -> np.ndarray:
        """Vectorized counterpart of :meth:`_shard_id` (same formula)."""
        return fids % self.n_shards

    def shard_of(self, fid: int) -> CatalogShard:
        return self.shards[self._shard_id(fid)]

    # -- operations ---------------------------------------------------------------
    def upsert(self, e: Entry, persist: bool = True) -> None:
        old, new = self.shard_of(e.fid).upsert(e)
        self._bump()
        self._fire(old, new)
        for fn in self._entry_hooks:
            fn(e)
        if persist:
            self._persist([e], [])

    def upsert_batch(self, entries: Sequence[Entry]) -> None:
        """Apply a batch then durably commit — callers ack changelog after."""
        for e in entries:
            old, new = self.shard_of(e.fid).upsert(e)
            self._fire(old, new)
            for fn in self._entry_hooks:
                fn(e)
        self._bump()
        self._persist(entries, [])

    def commit_delta_batch(self, entries: Sequence[Entry],
                           removed: Sequence[int]) -> int:
        """Commit one folded delta batch: shard-grouped upserts and
        removals (one lock acquisition per shard group), ONE durable
        sqlite commit, ONE version bump, and ONE delta fan-out call
        carrying the whole batch (:meth:`add_delta_hook`'s ``batch``
        consumers get a single invocation; scalar hooks still see every
        pair). This is the columnar ingest plane's apply primitive — the
        scalar equivalent (`upsert_batch` + a remove loop) costs N hook
        dispatches and N+1 version bumps for the same state change.

        Removals of absent fids (same-batch CREAT→UNLNK annihilations)
        are no-ops and fire nothing, matching the scalar path. Returns
        the number of removals that actually hit.
        """
        pairs: List[Tuple[Optional[Delta], Optional[Delta]]] = []
        by_shard: Dict[int, List[Entry]] = {}
        for e in entries:
            by_shard.setdefault(self._shard_id(e.fid), []).append(e)
        for sid, group in by_shard.items():
            pairs.extend(self.shards[sid].upsert_many(group))
        rm_by_shard: Dict[int, List[int]] = {}
        for fid in removed:
            rm_by_shard.setdefault(self._shard_id(fid), []).append(fid)
        hit = 0
        removed_present: List[int] = []
        for sid, fids in rm_by_shard.items():
            for fid, old in zip(fids, self.shards[sid].remove_many(fids)):
                if old is not None:
                    pairs.append((old, None))
                    removed_present.append(fid)
                    hit += 1
        self._bump()
        self._persist(entries, removed_present)
        self._fire_batch(pairs)
        if self._entry_hooks:
            for e in entries:
                for fn in self._entry_hooks:
                    fn(e)
        return hit

    def update_fields(self, fid: int, **fields) -> bool:
        res = self.shard_of(fid).update_fields(fid, **fields)
        if res is None:
            return False
        self._bump()
        self._fire(res[0], res[1])
        if self._db is not None:
            e = self.get(fid)
            if e is not None:
                self._persist([e], [])
        return True

    def remove(self, fid: int, persist: bool = True) -> bool:
        old = self.shard_of(fid).remove(fid)
        if old is None:
            return False
        self._bump()
        self._fire(old, None)
        if persist:
            self._persist([], [fid])
        return True

    def get(self, fid: int) -> Optional[Entry]:
        return self.shard_of(fid).get(fid)

    def get_batch(self, fids: Sequence[int]) -> List[Optional[Entry]]:
        """Fetch many entries, grouped by shard so each shard lock is taken
        once per call instead of once per fid. Result aligns with ``fids``."""
        out: List[Optional[Entry]] = [None] * len(fids)
        by_shard: Dict[int, List[int]] = {}
        for pos, fid in enumerate(fids):
            by_shard.setdefault(self._shard_id(fid), []).append(pos)
        for sid, positions in by_shard.items():
            got = self.shards[sid].get_batch([fids[p] for p in positions])
            for p, e in zip(positions, got):
                out[p] = e
        return out

    def update_fields_batch(self, fids: Sequence[int], **fields) -> List[int]:
        """Patch the same fields on many entries; one lock + one durable
        commit per shard group. Fires delta hooks per entry. Returns the
        fids actually updated (present in the catalog)."""
        by_shard: Dict[int, List[int]] = {}
        for fid in fids:
            by_shard.setdefault(self._shard_id(fid), []).append(fid)
        updated: List[int] = []
        pairs: List[Tuple[Optional[Delta], Optional[Delta]]] = []
        for sid, group in by_shard.items():
            results = self.shards[sid].update_fields_batch(group, fields)
            for fid, res in zip(group, results):
                if res is not None:
                    pairs.append(res)
                    updated.append(fid)
        self._fire_batch(pairs)
        if updated:
            self._bump()
        if self._db is not None and updated:
            entries = [e for e in self.get_batch(updated) if e is not None]
            self._persist(entries, [])
        return updated

    def remove_batch(self, fids: Sequence[int]) -> int:
        """Remove many entries; one lock acquisition per shard group, one
        durable commit and one hook fan-out for the whole batch."""
        by_shard: Dict[int, List[int]] = {}
        for fid in fids:
            by_shard.setdefault(self._shard_id(fid), []).append(fid)
        removed: List[int] = []
        pairs: List[Tuple[Optional[Delta], Optional[Delta]]] = []
        for sid, group in by_shard.items():
            for fid, old in zip(group, self.shards[sid].remove_many(group)):
                if old is not None:
                    pairs.append((old, None))
                    removed.append(fid)
        self._fire_batch(pairs)
        if removed:
            self._bump()
            self._persist([], removed)
        return len(removed)

    def column_slice(self, fids: Sequence[int], names: Sequence[str]
                     ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Columnar gather for specific fids (no Entry materialization).

        Returns (cols, present) aligned with ``fids``; absent fids have
        value 0 and ``present[i] == False``.
        """
        n = len(fids)
        out = {name: np.zeros(n, dtype=dict(_NUMERIC_COLUMNS)[name])
               for name in names}
        present = np.zeros(n, dtype=bool)
        by_shard: Dict[int, List[int]] = {}
        for pos, fid in enumerate(fids):
            by_shard.setdefault(self._shard_id(fid), []).append(pos)
        for sid, positions in by_shard.items():
            cols, pres = self.shards[sid].column_slice(
                [fids[p] for p in positions], names)
            idx = np.array(positions, dtype=np.int64)
            present[idx] = pres
            for name in names:
                out[name][idx] = cols[name]
        return out, present

    def gather_rows(self, fids: Sequence[int], with_strings: bool = True
                    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Full-row columnar gather for specific fids (policy re-evaluation
        over dirty rows — no Entry materialization).

        Returns (cols, present) aligned with ``fids``: every numeric column
        plus (when ``with_strings``) ``_names``/``_paths`` string lists,
        shaped like :meth:`arrays` output restricted to the requested fids,
        so ``Expr.mask`` runs on it unchanged (glob predicates included).
        Callers whose criteria hold no glob predicate pass
        ``with_strings=False`` and skip the per-row string gather. Absent
        fids read 0 / "" with ``present[i] == False``.
        """
        n = len(fids)
        fid_arr = np.asarray(fids, dtype=np.int64)
        out: Dict[str, np.ndarray] = {
            name: np.zeros(n, dtype=dt) for name, dt in _NUMERIC_COLUMNS}
        names: List[str] = [""] * n
        paths: List[str] = [""] * n
        present = np.zeros(n, dtype=bool)
        sids = self._shard_ids(fid_arr)
        for sid in range(self.n_shards):
            idx = np.nonzero(sids == sid)[0]
            if not idx.size:
                continue
            cols, snames, spaths, pres = self.shards[sid].row_slice(
                fid_arr[idx].tolist(), with_strings=with_strings)
            present[idx] = pres
            for name, _ in _NUMERIC_COLUMNS:
                out[name][idx] = cols[name]
            if with_strings:
                for p, nm, pth in zip(idx.tolist(), snames, spaths):
                    names[p] = nm
                    paths[p] = pth
        if with_strings:
            out["_names"] = names   # type: ignore[assignment]
            out["_paths"] = paths   # type: ignore[assignment]
        return out, present

    def column_batch(self, fids: Sequence[int], with_strings: bool = False
                     ) -> ColumnBatch:
        """Entry-free row fetch: a :class:`ColumnBatch` over every numeric
        column for the given fids (one lock acquisition per shard group, no
        ``Entry.__init__``). The policy engine's columnar execution path and
        incremental re-evaluation both flow through this.

        ``with_strings=True`` additionally gathers the per-row name/path
        lists (host-side glob predicates need them); interned columns are
        always present as int32 codes and decode lazily via
        :meth:`ColumnBatch.decode`.
        """
        cols, present = self.gather_rows(fids, with_strings=with_strings)
        return ColumnBatch(cols, present, self.strings, catalog=self)

    def __len__(self) -> int:
        return sum(s.count() for s in self.shards)

    def entries(self) -> Iterator[Entry]:
        for s in self.shards:
            for fid in s.fids():
                e = s.get(fid)
                if e is not None:
                    yield e

    # -- vectorized queries ----------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        """Concatenate all shards' columns (the full 'table').

        ``_paths``/``_names`` are **lazy**: the per-row python-list gather
        is only paid when a host-side glob predicate or path report
        actually indexes them. The snapshot is still consistent — each
        shard's string lists are pointer-copied under the same lock as its
        numeric columns.

        The result is **cached per catalog version** (invalidated by
        ``_bump``): two calls with no intervening mutation return the SAME
        object, so the numpy evaluator, reports and plugins stop paying a
        full per-run shard concat on a quiet catalog. Callers must treat
        the returned columns as read-only. The version is read *before*
        the snapshot, so a racing mutation caches newer data under an
        older version — one redundant rebuild later, never a stale serve.
        """
        self.arrays_calls += 1
        with self._arrays_lock:
            cached = self._arrays_cache
        version = self._version
        if cached is not None and cached[0] == version:
            return cached[1]
        cols_and_snaps = [s.snapshot() for s in self.shards]
        out: Dict[str, np.ndarray] = {}
        for name, _ in _NUMERIC_COLUMNS:
            out[name] = np.concatenate([c[name] for c, _s in cols_and_snaps]) \
                if cols_and_snaps else np.zeros(0)
        # keep only the string snapshots alive, not the per-shard numerics
        snaps = [s for _c, s in cols_and_snaps]

        def _loader(attr: str) -> Callable[[], list]:
            def load() -> list:
                parts: list = []
                for snap in snaps:
                    parts.extend(snap.gather(attr))
                return parts
            return load

        result = LazyColumns(out, {"_paths": _loader("_paths"),
                                   "_names": _loader("_names")})
        with self._arrays_lock:
            self._arrays_cache = (version, result)
        return result

    def query_fids(self, mask_fn: Callable[[Dict[str, np.ndarray]], np.ndarray]) -> np.ndarray:
        """Vectorized query: mask_fn(columns)->bool mask; returns matching fids."""
        cols = self.arrays()
        mask = mask_fn(cols)
        return cols["fid"][mask]
