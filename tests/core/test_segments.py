"""PackedSegment: exact round-trip + mmap persistence + demote/promote
byte-identity.

The warm tier only works if decode is *exact* — the streamed window and
the promoted mirrors must be byte-identical to what a resident group
would hold — so the round-trip here is asserted with
``np.array_equal`` + dtype equality, never ``allclose``.
"""
import numpy as np
import pytest

from repro.core.segments import PackedSegment, _min_uint, _unzigzag, _zigzag

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False
needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


# -- codec primitives ---------------------------------------------------------

def test_zigzag_roundtrip_fixed():
    a = np.asarray([0, 1, -1, 2 ** 62, -2 ** 62, 63, -64], np.int64)
    assert np.array_equal(_unzigzag(_zigzag(a)), a)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(st.lists(st.integers(-2 ** 62, 2 ** 62), max_size=50))
    def test_zigzag_roundtrip(vals):
        a = np.asarray(vals, np.int64)
        assert np.array_equal(_unzigzag(_zigzag(a)), a)


def test_min_uint_widths():
    assert _min_uint(0) == np.uint8
    assert _min_uint(255) == np.uint8
    assert _min_uint(256) == np.uint16
    assert _min_uint(2 ** 16) == np.uint32
    assert _min_uint(2 ** 32) == np.uint64


# -- pack/decode round-trip ---------------------------------------------------

_INT_DTYPES = [np.int8, np.int16, np.int32, np.int64,
               np.uint8, np.uint16, np.uint32]
_FLOAT_DTYPES = [np.float32, np.float64]


def _random_column(rng, n, kind, variant):
    if kind == "int":
        dt = _INT_DTYPES[variant % len(_INT_DTYPES)]
        info = np.iinfo(dt)
        # mix of low-cardinality (dict path) and spread (delta path)
        if variant % 2:
            vals = rng.integers(0, min(5, info.max), size=n)
        else:
            vals = rng.integers(info.min, info.max, size=n, endpoint=True)
        return vals.astype(dt)
    if kind == "float":
        dt = _FLOAT_DTYPES[variant % len(_FLOAT_DTYPES)]
        return (rng.standard_normal(n) * 1e6).astype(dt)
    if kind == "str":
        return np.asarray([f"/p/d{int(v)}/f{i}" for i, v in
                           enumerate(rng.integers(0, 7, size=n))])
    return rng.random(n) < 0.5


def _roundtrip(cols):
    seg = PackedSegment.pack(cols, meta={"tag": 1})
    assert seg.n_rows == len(next(iter(cols.values())))
    for name, arr in cols.items():
        dec = seg.decode(name)
        assert dec.dtype == arr.dtype, name
        assert np.array_equal(dec, arr), name
    assert seg.meta == {"tag": 1}


@pytest.mark.parametrize("seed", range(8))
def test_pack_roundtrip_random_columns_fixed(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(0, 200))
    kinds = ["int", "float", "str", "bool"]
    _roundtrip({f"c{i}": _random_column(rng, n, kinds[i % 4],
                                        int(rng.integers(0, 8)))
                for i in range(int(rng.integers(1, 6)))})


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 200), st.integers(1, 5),
           st.lists(st.tuples(st.sampled_from(["int", "float", "str",
                                               "bool"]),
                              st.integers(0, 7)),
                    min_size=5, max_size=5),
           st.integers(0, 2 ** 31))
    def test_pack_roundtrip_random_columns(n, n_cols, specs, seed):
        rng = np.random.default_rng(seed)
        _roundtrip({f"c{i}": _random_column(rng, n, specs[i][0],
                                            specs[i][1])
                    for i in range(n_cols)})


@pytest.mark.parametrize("n", [0, 1])
def test_empty_and_single_row(n):
    cols = {
        "fid": np.arange(n, dtype=np.int64) + 7,
        "size": np.full(n, 3.5, np.float32),
        "owner": np.zeros(n, np.int32),
        "path": np.asarray(["/p/x"] * n),
        "flag": np.ones(n, bool),
    }
    seg = PackedSegment.pack(cols)
    assert seg.n_rows == n
    for name, arr in cols.items():
        dec = seg.decode(name)
        assert dec.dtype == arr.dtype and np.array_equal(dec, arr)


def test_near_sequential_ints_delta_compress():
    fids = np.arange(1, 100_001, dtype=np.int64) * 3
    seg = PackedSegment.pack({"fid": fids})
    assert np.array_equal(seg.decode("fid"), fids)
    # deltas are constant (=3): one byte per row, 8x under raw int64
    assert seg.nbytes < fids.nbytes / 4


def test_low_cardinality_ints_dict_compress():
    owners = np.random.default_rng(0).integers(0, 4, size=50_000)
    seg = PackedSegment.pack({"owner": owners})
    assert np.array_equal(seg.decode("owner"), owners)
    assert seg.decode("owner").dtype == owners.dtype
    assert seg.nbytes < owners.nbytes / 4
    assert seg.decoded_nbytes == owners.nbytes


def test_negative_and_extreme_deltas():
    a = np.asarray([2 ** 62, -2 ** 62, 0, 1, -1, 2 ** 40], np.int64)
    # force the delta path (unique count above the dict threshold needs
    # n//4 < uniq, so small arrays always dict-encode; check both)
    seg = PackedSegment.pack({"a": a})
    assert np.array_equal(seg.decode("a"), a)


def test_unsupported_dtype_and_ragged_rows_raise():
    with pytest.raises(TypeError):
        PackedSegment.pack({"c": np.zeros(3, np.complex64)})
    with pytest.raises(ValueError):
        PackedSegment.pack({"a": np.zeros(3), "b": np.zeros(4)})


def test_columns_cache_and_release():
    seg = PackedSegment.pack({"fid": np.arange(10, dtype=np.int64)})
    first = seg.decode("fid")
    assert seg.decode("fid") is first           # cached
    seg.release()
    assert seg.decode("fid") is not first       # re-decoded
    assert set(seg.columns()) == {"fid"}


# -- persistence --------------------------------------------------------------

@pytest.mark.parametrize("mmap", [False, True])
def test_save_load_roundtrip(tmp_path, mmap):
    rng = np.random.default_rng(3)
    cols = {
        "fid": np.cumsum(rng.integers(1, 9, size=1000)).astype(np.int64),
        "size": (rng.integers(0, 2 ** 12, size=1000) * 1024
                 ).astype(np.float32),
        "atime": rng.random(1000).astype(np.float64) * 1e6,
        "owner": rng.integers(0, 4, size=1000).astype(np.int32),
        "path": np.asarray([f"/p/d{i % 5}/f{i}" for i in range(1000)]),
        "valid": rng.random(1000) < 0.9,
    }
    seg = PackedSegment.pack(cols, meta={"gid": 2, "rows": 1000})
    p = str(tmp_path / "seg.npz")
    seg.save(p)
    back = PackedSegment.load(p, mmap=mmap)
    assert back.n_rows == 1000 and back.meta == {"gid": 2, "rows": 1000}
    assert set(back.names) == set(cols)
    for name, arr in cols.items():
        dec = back.decode(name)
        assert dec.dtype == arr.dtype, name
        assert np.array_equal(dec, arr), name


def test_mmap_load_uses_memmap(tmp_path):
    seg = PackedSegment.pack({"fid": np.arange(5000, dtype=np.int64),
                              "sz": np.ones(5000, np.float32)})
    p = str(tmp_path / "seg.npz")
    seg.save(p)
    back = PackedSegment.load(p, mmap=True)
    assert any(isinstance(a, np.memmap) for a in back._arrays.values())
    assert np.array_equal(back.decode("fid"), np.arange(5000))


def test_load_rejects_foreign_file(tmp_path):
    p = str(tmp_path / "other.npz")
    np.savez(p, __header=np.asarray('{"format": "something-else"}'),
             a=np.zeros(3))
    with pytest.raises(ValueError, match="repro-segment-v1"):
        PackedSegment.load(p)


# -- demote -> promote byte-identity on every plane ---------------------------

def test_demote_promote_mirror_byte_identity():
    """Pack a group-shaped column stack (kernel + reports + cube plane
    mirrors), decode it back, and require byte-identity on every plane —
    the exact contract ``DeviceColumnStore._promote`` relies on."""
    from repro.core.device_store import PLAN_COLUMNS
    rng = np.random.default_rng(11)
    n = 2000
    cols = {name: (rng.integers(0, 2 ** 12, size=n) * 1024
                   ).astype(np.float32) for name in PLAN_COLUMNS}
    cols["fid"] = np.cumsum(rng.integers(1, 5, size=n)).astype(np.int64)
    paths = np.asarray(sorted(f"/p/d{i % 17}/f{i:06d}" for i in range(n)))
    order = rng.permutation(n)
    cols["path"] = paths[order]          # row-aligned paths
    cols["ord"] = order.astype(np.int64)  # row -> sorted-path rank
    cols["cgid"] = rng.integers(0, 40, size=n).astype(np.int64)
    cols["csb"] = rng.integers(0, 10, size=n).astype(np.int64)
    seg = PackedSegment.pack(cols)
    dec = seg.columns()
    for name, arr in cols.items():
        assert dec[name].dtype == arr.dtype, name
        assert np.array_equal(dec[name], arr), name
    # sorted-path reconstruction (what _promote rebuilds spaths from)
    sp = np.empty(n, dtype=dec["path"].dtype)
    sp[dec["ord"]] = dec["path"]
    assert np.array_equal(sp, paths)
