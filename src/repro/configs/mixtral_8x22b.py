"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (per assignment).
[arXiv:2401.04088; hf]
"""
from repro.models.config import (ATTN_LOCAL, FFN_MOE, LayerSpec, ModelConfig,
                                 MoeSpec)

_PATTERN = (LayerSpec(mix=ATTN_LOCAL, ffn=FFN_MOE),)

CONFIG = ModelConfig(
    name="mixtral_8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=16384, vocab=32768,
    pattern=_PATTERN, window=4096, rope_theta=1e6,
    moe=MoeSpec(num_experts=8, top_k=2),
)

SMOKE = ModelConfig(
    name="mixtral_8x22b_smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, head_dim=8,
    d_ff=128, vocab=512,
    pattern=_PATTERN, window=32,
    moe=MoeSpec(num_experts=4, top_k=2),
)
