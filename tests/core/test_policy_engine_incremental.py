"""Incremental match subsystem: dirty-fid sets, cached match tables, flip
scheduling for age predicates, full-scan fallbacks, and watermark triggers
draining exactly the dirty set (paper SII-C: changelogs replace re-scans)."""
import threading

import numpy as np
import pytest

from repro.core import (Catalog, Entry, EventPipeline, FsType,
                        PipelineConfig, PolicyDefinition, PolicyEngine,
                        UsageWatermarkTrigger, parse_expr)
from repro.core.policy import PolicyError
from repro.fs import LustreSim


class Clock:
    def __init__(self, t=1_000_000.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class Recorder:
    def __init__(self):
        self.lock = threading.Lock()
        self.calls = []

    def __call__(self, e, params):
        with self.lock:
            self.calls.append(e.fid)
        return True

    def take(self):
        out, self.calls = self.calls, []
        return out


RULES = [("big", "size > 10k", {"tag": "big"}),
         ("old", "last_access > 500s", {"tag": "old"})]


def _fs_world(clock, n=60):
    fs = LustreSim(n_mdts=1, clock=clock)
    d = fs.mkdir(fs.root_fid(), "dir")
    fids = []
    for i in range(n):
        f = fs.create(d, f"f{i}", owner=f"user{i % 3}")
        fs.write(f, 500 * (i + 1))
        fids.append(f)
        clock.advance(1.0)
    return fs, d, fids


def _engine(cat, clock, action, **kw):
    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name="p", action=action, scope="type == file", rules=RULES,
        mutates=False, **kw))
    return eng


def _oracle_run(cat, clock):
    """Fresh engine, full scan — the reference actioned sequence."""
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    r = eng.run("p", matching="full")
    return r, rec.calls


def test_incremental_equals_full_after_pipeline_churn():
    clock = Clock()
    fs, d, fids = _fs_world(clock)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    eng.subscribe_pipeline(pipe)
    pipe.process_once(100000)

    r1 = eng.run("p")
    assert r1.mode == "full"            # first run: no cached state yet
    rec.take()

    # churn: grow one, make one hot, remove one, create one
    clock.advance(10)
    fs.write(fids[0], 100_000)
    fs.read(fids[30])
    fs.unlink(fids[40])
    nf = fs.create(d, "fresh", owner="user0")
    fs.write(nf, 90_000)
    pipe.process_once(100000)

    r2 = eng.run("p")
    assert r2.mode == "incremental"
    assert 0 < r2.reval <= 6            # only the churned entries
    r_full, oracle = _oracle_run(cat, clock)
    assert rec.take() == oracle
    assert (r2.matched, r2.succeeded, r2.volume) == \
        (r_full.matched, r_full.succeeded, r_full.volume)


def test_time_flip_matches_entries_with_zero_deltas():
    clock = Clock()
    fs, d, fids = _fs_world(clock)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    eng.subscribe_pipeline(pipe)
    pipe.process_once(100000)
    r1 = eng.run("p")
    rec.take()

    # no deltas at all — entries cross the last_access > 500s boundary
    clock.advance(480)                   # some (not all) files become old
    r2 = eng.run("p")
    assert r2.mode == "incremental"
    assert r2.matched > r1.matched       # time alone grew the match set
    _, oracle = _oracle_run(cat, clock)
    assert rec.take() == oracle

    # a quiescent follow-up run re-evaluates only newly-due rows (an entry
    # whose flip instant equals `now` exactly is kept while the clock is
    # frozen, so strict comparisons crossing just after it are not missed)
    r3 = eng.run("p")
    assert r3.mode == "incremental" and r3.reval <= 1
    clock.advance(0.5)                   # time moves: boundary entry spent
    eng.run("p")
    r4 = eng.run("p")
    assert r4.reval == 0


def test_touched_entry_leaves_match_set():
    clock = Clock()
    fs, d, fids = _fs_world(clock)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    eng.subscribe_pipeline(pipe)
    pipe.process_once(100000)
    clock.advance(2000)                  # everything is old now
    r1 = eng.run("p")
    rec.take()
    assert r1.matched == len(fids)

    # atime refreshed via setattr (emits SATTR; plain reads are not logged,
    # as on real Lustre): entry is no longer "old" and too small for "big"
    fs.setattr(fids[5], atime=clock())
    pipe.process_once(100000)
    r2 = eng.run("p")
    assert r2.mode == "incremental"
    acted = rec.take()
    assert fids[5] not in acted          # left the cached match set
    _, oracle = _oracle_run(cat, clock)
    assert acted == oracle


def test_explicit_incremental_without_state_raises():
    clock = Clock()
    cat = Catalog()
    cat.upsert(Entry(fid=1, type=FsType.FILE, size=50_000))
    eng = _engine(cat, clock, Recorder())
    with pytest.raises(PolicyError):
        eng.run("p", matching="incremental")
    # ... also right after invalidation
    eng.subscribe_pipeline(EventPipeline(None, cat, _stream()))
    eng.run("p")
    eng.run("p", matching="incremental")     # now fine
    eng.invalidate("p")
    with pytest.raises(PolicyError):
        eng.run("p", matching="incremental")


def _stream():
    from repro.core import ChangelogStream
    return ChangelogStream()


def test_register_resets_cached_state():
    clock = Clock()
    cat = Catalog()
    cat.upsert(Entry(fid=1, type=FsType.FILE, size=50_000))
    eng = _engine(cat, clock, Recorder())
    eng.enable_incremental()
    eng.run("p")
    assert eng.run("p").mode == "incremental"
    eng.register(PolicyDefinition.from_config(
        name="p", action=Recorder(), scope="type == file",
        rules=[("any", "size > 0", {})], mutates=False))
    assert eng.run("p").mode == "full"       # definition changed: rebuilt


def test_age_equality_predicates_always_full_scan():
    clock = Clock()
    cat = Catalog()
    cat.upsert(Entry(fid=1, type=FsType.FILE, size=50_000, atime=clock()))
    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name="weird", action=Recorder(), scope="true",
        rules=[("exact", "last_access == 500s", {})], mutates=False))
    eng.enable_incremental()
    eng.run("weird")
    assert eng.run("weird").mode == "full"   # no well-defined flip instant
    with pytest.raises(PolicyError):
        eng.run("weird", matching="incremental")


def test_incremental_handles_glob_predicates():
    clock = Clock()
    fs, d, fids = _fs_world(clock, n=30)
    cat = Catalog()
    pipe = EventPipeline(fs, cat, fs.changelog.stream(0), PipelineConfig())
    rec = Recorder()
    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name="glob", action=rec, scope="type == file",
        rules=[("logs", "path == '/dir/f1*'", {})], mutates=False))
    eng.subscribe_pipeline(pipe)
    pipe.process_once(100000)
    eng.run("glob")
    first = rec.take()
    assert first                              # f1, f10..f19
    nf = fs.create(d, "f1x", owner="u")
    fs.write(nf, 10)
    pipe.process_once(100000)
    r = eng.run("glob")
    assert r.mode == "incremental"
    assert rec.take() == first + [nf]        # new path matched incrementally


def test_stream_subscription_trails_pipeline_commit_watermark():
    clock = Clock()
    fs, d, fids = _fs_world(clock, n=20)
    cat = Catalog()
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig())
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    eng.subscribe_stream(stream)
    pipe.process_once(100000)
    eng.run("p")
    rec.take()

    fs.write(fids[0], 100_000)
    # the pipeline has NOT processed the record yet: the engine must not
    # consume it (the catalog doesn't reflect it)
    r = eng.run("p")
    assert r.mode == "incremental" and r.reval == 0
    pipe.process_once(100000)                # now committed + acked
    r2 = eng.run("p")
    assert r2.mode == "incremental" and r2.reval == 1
    _, oracle = _oracle_run(cat, clock)
    rec.take()
    assert (r2.matched, r2.succeeded) == \
        (len(oracle), len(oracle))


def test_stream_subscription_covers_records_emitted_before_subscribe():
    """Records already emitted but not yet pipeline-committed when the
    engine subscribes must still reach the dirty set once committed."""
    clock = Clock()
    fs, d, fids = _fs_world(clock, n=10)
    cat = Catalog()
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig())
    pipe.process_once(100000)
    fs.write(fids[0], 100_000)             # emitted, NOT committed yet
    rec = Recorder()
    eng = _engine(cat, clock, rec)
    eng.subscribe_stream(stream)           # subscribes behind that record
    r1 = eng.run("p")                      # full run on the stale catalog
    assert r1.mode == "full"
    pipe.process_once(100000)              # commit happens after the scan
    r2 = eng.run("p")
    assert r2.mode == "incremental" and r2.reval >= 1
    rec.take()
    _, oracle = _oracle_run(cat, clock)
    assert r2.matched == len(oracle)


def test_two_engines_on_one_stream_get_independent_cursors():
    clock = Clock()
    fs, d, fids = _fs_world(clock, n=10)
    cat = Catalog()
    stream = fs.changelog.stream(0)
    pipe = EventPipeline(fs, cat, stream, PipelineConfig())
    pipe.process_once(100000)
    engines = []
    for _ in range(2):
        eng = _engine(cat, clock, Recorder())
        eng.subscribe_stream(stream)
        eng.run("p")
        engines.append(eng)
    fs.write(fids[0], 100_000)
    pipe.process_once(100000)
    for eng in engines:                    # neither steals the delta
        r = eng.run("p")
        assert r.mode == "incremental" and r.reval == 1


def test_auto_falls_back_to_full_on_large_dirty_set():
    clock = Clock()
    cat = Catalog()
    for i in range(100):
        cat.upsert(Entry(fid=i + 1, type=FsType.FILE, size=50_000))
    eng = _engine(cat, clock, Recorder())
    eng.enable_incremental()
    eng.run("p")
    eng.mark_dirty(range(1, 101))            # 100% churn: scan is cheaper
    r = eng.run("p")
    assert r.mode == "full"
    eng.mark_dirty([1, 2, 3])
    assert eng.run("p").mode == "incremental"


def test_failed_rebuild_never_leaves_valid_empty_cache():
    """A raise during the full-scan rebuild (e.g. bogus sort_by) must not
    mark the cache valid, or later auto runs would silently match nothing."""
    clock = Clock()
    cat = Catalog()
    for i in range(30):
        cat.upsert(Entry(fid=i + 1, type=FsType.FILE, size=50_000))
    rec = Recorder()
    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name="p", action=rec, scope="type == file",
        rules=[("any", "size > 0", {})], sort_by="bogus", mutates=False))
    eng.enable_incremental()
    with pytest.raises(KeyError):
        eng.run("p")
    with pytest.raises(KeyError):
        eng.run("p")                       # still full scan + raise: never
    with pytest.raises(PolicyError):       # a silent empty incremental run
        eng.run("p", matching="incremental")
    eng.register(PolicyDefinition.from_config(
        name="p", action=rec, scope="type == file",
        rules=[("any", "size > 0", {})], sort_by="atime", mutates=False))
    r = eng.run("p")                       # recovers with a full scan
    assert r.mode == "full" and r.matched == 30
    assert eng.run("p").mode == "incremental"


def test_mutating_action_reobserved_next_run():
    """purge-style plugin: removes entries from the catalog directly."""
    clock = Clock()
    cat = Catalog()
    for i in range(40):
        cat.upsert(Entry(fid=i + 1, type=FsType.FILE,
                         size=20_000 if i % 2 else 100))
    eng = PolicyEngine(cat, clock=clock)

    def purge(e, params):
        cat.remove(e.fid)
        return True

    eng.register(PolicyDefinition.from_config(
        name="purge", action=purge, scope="type == file",
        rules=[("big", "size > 10k", {})]))     # mutates=True default
    eng.enable_incremental()
    r1 = eng.run("purge")
    assert r1.succeeded == 20 and len(cat) == 20
    # half the catalog is dirty, so auto would full-rescan; force the path
    r2 = eng.run("purge", matching="incremental")
    assert r2.matched == 0                   # cache dropped the purged fids
    assert r2.reval == 20                    # actioned fids re-observed


# -- watermark triggers over the incremental path ------------------------------

@pytest.mark.parametrize("n_threads", [1, 4, 8])
def test_watermark_drains_dirty_set_to_budget_deterministically(n_threads):
    """A high->low watermark crossing drains exactly the dirty entries that
    (still) match, stops on the budget boundary, and actions an identical
    set regardless of thread count."""
    clock = Clock()
    cat = Catalog()
    n = 400
    for i in range(n):
        cat.upsert(Entry(fid=i + 1, type=FsType.FILE, size=1_000,
                         ost_idx=0, atime=clock() - (i + 1)))
    freed = [0]
    lock = threading.Lock()
    acted = []

    def act(e, params):
        with lock:
            freed[0] += e.size
            acted.append(e.fid)
        return True

    eng = PolicyEngine(cat, clock=clock)
    eng.register(PolicyDefinition.from_config(
        name="p", action=act, scope="type == file",
        rules=[("big", "size > 10k", {})],
        n_threads=n_threads, batch_size=16, mutates=False))
    capacity = 1_000_000
    used0 = 900_000
    eng.add_watermark_trigger("p", UsageWatermarkTrigger(
        usage_fn=lambda: [("ost0", used0 - freed[0], capacity)],
        high_pct=85.0, low_pct=60.0,
        restrict_fn=lambda key: parse_expr("ost_idx == 0")))
    eng.enable_incremental()
    r0 = eng.run("p")
    assert r0.matched == 0                    # nothing big yet; cache primed

    # dirty exactly 80 entries (20% — under the auto rescan threshold):
    # they grow past the rule threshold
    dirty = list(range(1, 81))
    cat.update_fields_batch(dirty, size=20_000)
    eng.mark_dirty(dirty)

    reports = eng.check_triggers()
    assert len(reports) == 1
    r = reports[0]
    assert r.mode == "incremental"
    assert r.reval == len(dirty)              # drained exactly the dirty set
    target = used0 - int(capacity * 0.60)
    assert target <= r.volume < target + 20_000   # budget boundary
    # deterministic plan: LRU prefix of the dirty set, fid tie-break
    sizes = {f: 20_000 for f in dirty}
    atimes = {f: clock.t - f for f in dirty}
    exp = sorted(dirty, key=lambda f: (atimes[f], f))
    k = 0
    vol = 0
    while vol < target:
        vol += sizes[exp[k]]
        k += 1
    assert sorted(acted) == sorted(exp[:k])
    assert r.succeeded == k
    assert not eng.check_triggers()           # back under the high watermark
