"""Multi-stage record-processing pipeline (C4) + async dirty-tag mode (C11).

Paper SIII-A2: record processing is split into steps, one per resource kind
(filesystem lookups vs database commits), serviced by a worker-thread pool;
per-resource concurrency is capped so neither the MDS nor the DB is
overloaded. We reproduce that, plus the paper's *proposed* asynchronous
improvement: changelog processing merely **tags** entries dirty (cheap, acks
fast), and a background pool of *updaters* refreshes tagged entries, folding
repeated changes to one refresh (dedup).

**Columnar hot path (default).** The line-rate ingest plane runs one
sharded reader per MDT stream. Each reader drains records into a columnar
batch (``seq``/``fid``/``type``/``time`` numpy arrays — no per-event
Python dicts from the reader onward), folds the batch with
:func:`fold_columnar` (vectorized last-write-wins via ``np.unique`` on fid
with a reversed-order index: CREAT→UNLNK annihilation, SETATTR storms
deduped and counted), resolves the surviving fids through one batched
``fs.stat_batch``, and lands the whole :class:`DeltaBatch` with ONE
``Catalog.commit_delta_batch`` call — one durable commit, one version
bump, and one delta fan-out that reaches catalog hooks, profile cube,
permission bitmaps and the ``DeviceColumnStore`` in a single dispatch
instead of N listener invocations re-deriving the same classification.

**Adaptive backpressure.** Each reader owns a per-MDT batch quantum in
``[min_batch, max_batch]``, driven by the PR-9 telemetry signals
(``changelog_backlog_mdt*`` / ``changelog_lag_seconds_mdt*`` are computed
from the same cursors the reader consults via ``stream.pending()`` /
``lag_seconds()``): the quantum doubles toward ``max_batch`` while the
backlog exceeds it and lag stays under ``lag_target``, and halves when a
batch's apply latency exceeds ``target_batch_seconds`` (ack latency
degrading). Transitions are visible as ``pipeline_batch_quantum{mdt=}``
gauges and ``pipeline_batch_adaptations{mdt=,direction=}`` counters.

**Differential oracle.** ``PipelineConfig(columnar=False)`` keeps the
record-at-a-time path (reader → batch queue → worker pool): identical
catalog state, actioned fid sets and ack ordering — the property suites
and the tier-2 bench assertion prove the two paths equivalent, including
crash/resume mid-batch.

Stages (synchronous modes):
  changelog record -> [GET_INFO: fs.stat, bounded by fs_concurrency]
                   -> [DB_APPLY: catalog batch upsert, bounded by db_concurrency]
                   -> ack(seq)

Acks are only issued once every record up to ``seq`` is committed (the
catalog's sqlite commit happens inside ``upsert_batch`` /
``commit_delta_batch``), preserving the transactional contract end-to-end.

**Delta fan-out**: downstream consumers (the policy engine's incremental
match state, cache invalidators, ...) can register a listener via
:meth:`EventPipeline.add_delta_listener`; after each batch is committed to
the catalog the listener receives ``(changed_fids, removed_fids)``.
Batch-aware consumers use :meth:`EventPipeline.add_batch_listener` and
receive the full :class:`DeltaBatch` instead. Listeners are notified
*after* the catalog mutation, so re-reading the catalog for a notified fid
always observes at least that change. Within one batch, records are folded
per fid, last-write-wins (one refresh per fid; an ``UNLNK`` arriving after
a ``CREAT`` of the same fid in the same batch wins — the entry is removed,
never materialized, and never reported dirty). The columnar fold emits
changed/removed fids in sorted-fid order (the scalar oracle emits
first-occurrence order); per-fid outcomes are identical.

The same committed mutations also reach every ``Catalog.add_delta_hook``
consumer (each claiming exactly one feed — see the shared fan-out
contract in ``core.device_store`` / ``ProfileCube.claim_delta_feed``):
the :class:`~repro.core.device_store.DeviceColumnStore` drains one dirty
batch into the resident column block, the cube partials, the plane
mirrors **and the permissions-plane bitsets** in a single scatter pass,
so changelog ingestion keeps multi-tenant ``subject=`` serving fresh
without any consumer rescanning the catalog.
"""
from __future__ import annotations

import dataclasses
import heapq
import queue
import threading
import time
from typing import (Callable, Dict, List, NamedTuple, Optional, Set,
                    Tuple, Union)

import numpy as np

from .catalog import Catalog
from .changelog import ChangelogHub, ChangelogStream, ColumnarRecords
from .stats import ChangelogCounters
from .telemetry import counter_attr
from .types import ChangelogRecord, ChangelogType, Entry
from ..fs.base import stat_batch as _fs_stat_batch

_RM = (int(ChangelogType.UNLNK), int(ChangelogType.RMDIR))
_BORN = (int(ChangelogType.CREAT), int(ChangelogType.MKDIR))


@dataclasses.dataclass
class PipelineConfig:
    fs_concurrency: int = 4       # max simultaneous filesystem operations
    db_concurrency: int = 2       # max simultaneous catalog commit batches
    batch_size: int = 256         # records per DB commit batch (start quantum)
    n_workers: int = 4            # oracle-mode worker pool size
    async_updates: bool = False   # dirty-tag + background updaters
    n_updaters: int = 2
    updater_interval: float = 0.002   # kept for config back-compat (unused:
    #                                   updaters block on a Condition now)
    columnar: bool = True         # vectorized fold + single fan-out apply;
    #                               False = record-at-a-time oracle
    min_batch: int = 64           # adaptive quantum floor
    max_batch: int = 8192         # adaptive quantum ceiling
    target_batch_seconds: float = 0.05   # shrink when apply exceeds this
    lag_target: float = 1.0       # grow only while stream lag is under this


class FoldResult(NamedTuple):
    """Vectorized last-write-wins fold of one columnar batch."""
    survivors: np.ndarray    # unique fids whose last op is not a removal
    removed: np.ndarray      # unique fids whose last op is UNLNK/RMDIR
    annihilated: np.ndarray  # ⊆ removed: first op in batch was CREAT/MKDIR
    dedup: int               # records folded away (n_records - n_unique)


def fold_columnar(fid: np.ndarray, typ: np.ndarray) -> FoldResult:
    """Fold a record batch per fid with vectorized last-write-wins.

    ``np.unique`` on the forward fid array yields the sorted unique fids
    plus each fid's FIRST record index; the same call on the reversed
    array yields identical uniques whose first-occurrence indices map to
    the LAST record index (``n-1-rev_idx``). The last op classifies each
    fid as removal vs survivor; a removed fid whose first in-batch op was
    a CREAT/MKDIR was born and died inside the batch — an annihilation
    (the entry must never materialize downstream). Equivalent to the
    scalar record-order fold for every interleaving (property-tested in
    ``tests/core/test_fold_properties.py``).
    """
    n = fid.shape[0]
    uniq, first_idx = np.unique(fid, return_index=True)
    if uniq.size == n:
        last_idx = first_idx               # no duplicates: first == last
    else:
        _, rev_idx = np.unique(fid[::-1], return_index=True)
        last_idx = n - 1 - rev_idx
    last_t = typ[last_idx]
    is_rm = (last_t == _RM[0]) | (last_t == _RM[1])
    first_t = typ[first_idx]
    born = (first_t == _BORN[0]) | (first_t == _BORN[1])
    return FoldResult(survivors=uniq[~is_rm], removed=uniq[is_rm],
                      annihilated=uniq[is_rm & born],
                      dedup=int(n - uniq.size))


@dataclasses.dataclass
class DeltaBatch:
    """One committed columnar batch, as delivered to batch listeners."""
    mdt: int
    seqs: np.ndarray           # acked sequence numbers (contiguous read)
    changed: List[int]         # surviving fids upserted (sorted-fid order)
    removed: List[int]         # fids whose last op removed them (sorted)
    entries: List[Entry]       # the upserted entries, aligned with changed
    dedup: int                 # records folded away by last-write-wins
    annihilated: List[int]     # same-batch CREAT→UNLNK fids (⊆ removed)


class _AckTracker:
    """Tracks per-stream contiguous completion so acks stay in order.

    Completed work arrives as [lo, hi] seq ranges (every read is a
    contiguous run after the cursor), so the heap holds ranges, not
    individual seqs — completing a 8192-record batch is one push, not
    8192 O(log n) pushes."""

    def __init__(self, stream: ChangelogStream) -> None:
        self.stream = stream
        self._lock = threading.Lock()
        self._done: List[Tuple[int, int]] = []   # min-heap of (lo, hi)
        self._acked = stream.acked

    def complete(self, seqs: List[int]) -> None:
        if seqs:
            self.complete_range(min(seqs), max(seqs))

    def complete_range(self, lo: int, hi: int) -> None:
        with self._lock:
            heapq.heappush(self._done, (lo, hi))
            new_ack = self._acked
            while self._done and self._done[0][0] == new_ack + 1:
                new_ack = heapq.heappop(self._done)[1]
            if new_ack != self._acked:
                self._acked = new_ack
                self.stream.ack(new_ack)


class EventPipeline:
    """Consumes one or many changelog streams into the catalog.

    ``stream`` may be a single :class:`ChangelogStream` (back-compat: one
    pipeline per MDT) or a whole :class:`ChangelogHub` — the pipeline then
    runs one sharded reader per MDT stream with independent ack cursors
    and adaptive per-MDT batch quanta.
    """

    # ingest counters, registry-backed (tests read them as plain ints)
    processed = counter_attr(
        "pipeline_records_processed", "changelog records folded into the "
        "catalog")
    dedup_hits = counter_attr(
        "pipeline_dedup_hits", "records folded away before the catalog "
        "(columnar last-write-wins / pending dirty tags)")

    def __init__(self, fs, catalog: Catalog,
                 stream: Union[ChangelogStream, ChangelogHub],
                 config: Optional[PipelineConfig] = None,
                 counters: Optional[ChangelogCounters] = None) -> None:
        self.fs = fs
        self.catalog = catalog
        self.stream = stream
        if isinstance(stream, ChangelogHub):
            self.streams: Dict[int, ChangelogStream] = dict(stream.streams)
        else:
            self.streams = {stream.mdt: stream}
        self.cfg = config or PipelineConfig()
        self.counters = counters
        self.telemetry = catalog.telemetry
        self._tlabels = {"pipeline": catalog.telemetry.instance("pipeline")}
        # the streams' backlog/lag gauges + events counters land in the
        # same registry (first binder wins; a stream shared by several
        # catalogs keeps its first registry)
        for s in self.streams.values():
            if s.telemetry is None:
                s.bind_telemetry(catalog.telemetry)
        self._fs_sem = threading.Semaphore(self.cfg.fs_concurrency)
        self._db_sem = threading.Semaphore(self.cfg.db_concurrency)
        self._acks = {mdt: _AckTracker(s) for mdt, s in self.streams.items()}
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._batches: "queue.Queue[Optional[List[ChangelogRecord]]]" = \
            queue.Queue(maxsize=64)
        self.processed = 0
        self._processed_lock = threading.Lock()
        # batches read but not yet committed+acked (drain must wait on
        # these: stream.pending() covers the pre-ack window, but the async
        # updater pops fids out of _dirty before the refresh lands)
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        # async dirty-tag state; the Condition doubles as the updater
        # wakeup (no interval polling — taggers notify, updaters wait)
        self._dirty: Set[int] = set()
        self._dirty_cv = threading.Condition()
        self._dirty_lock = self._dirty_cv      # back-compat alias
        self.dedup_hits = 0
        # adaptive per-MDT read quantum (columnar backpressure loop)
        self._quantum: Dict[int, int] = {
            mdt: max(self.cfg.min_batch,
                     min(self.cfg.batch_size, self.cfg.max_batch))
            for mdt in self.streams}
        for mdt, q in self._quantum.items():
            self.telemetry.gauge(
                "pipeline_batch_quantum", help="adaptive per-MDT read "
                "quantum", mdt=str(mdt), **self._tlabels).set(q)
        # delta fan-out (policy engine incremental match state, caches, ...)
        self._delta_listeners: List[Callable[[List[int], List[int]], None]] = []
        self._batch_listeners: List[Callable[[DeltaBatch], None]] = []

    # -- delta fan-out ------------------------------------------------------------
    def add_delta_listener(self, fn: Callable[[List[int], List[int]], None]
                           ) -> None:
        """Register ``fn(changed_fids, removed_fids)``, called after each
        batch of records has been committed to the catalog."""
        self._delta_listeners.append(fn)

    def add_batch_listener(self, fn: Callable[[DeltaBatch], None]) -> None:
        """Register a batch-aware consumer: ``fn(delta_batch)`` fires once
        per committed batch with the folded classification (changed /
        removed / annihilated / dedup) already attached — no re-deriving
        it from fid lists."""
        self._batch_listeners.append(fn)

    def _notify(self, changed: List[int], removed: List[int],
                batch: Optional[DeltaBatch] = None) -> None:
        if not (changed or removed):
            return
        self.telemetry.counter(
            "pipeline_deltas_fanned_out", help="fids propagated to "
            "delta listeners after a catalog commit",
            **self._tlabels).inc(len(changed) + len(removed))
        with self.telemetry.trace("pipeline.fanout",
                                  changed=len(changed),
                                  removed=len(removed),
                                  **self._tlabels):
            for fn in self._delta_listeners:
                fn(changed, removed)
            if batch is not None:
                for bfn in self._batch_listeners:
                    bfn(batch)

    # -- in-flight accounting ------------------------------------------------------
    def _inflight_add(self, n: int) -> None:
        with self._inflight_lock:
            self._inflight += n

    # -- columnar apply ------------------------------------------------------------
    def _apply_columnar(self, cb: ColumnarRecords) -> None:
        """Fold → stat_batch → one commit_delta_batch → fan-out → ack."""
        reg = self.telemetry
        n = len(cb)
        with reg.trace("pipeline.apply", records=n, mdt=str(cb.mdt),
                       **self._tlabels):
            if self.counters is not None:
                self.counters.on_records(cb.records)
            with reg.trace("pipeline.fold", **self._tlabels):
                fold = fold_columnar(cb.fid, cb.type)
            entries: List[Entry] = []
            if fold.survivors.size:
                with self._fs_sem:               # bounded FS concurrency
                    with reg.trace("pipeline.stat",
                                   fids=int(fold.survivors.size),
                                   **self._tlabels):
                        entries = [e for e in _fs_stat_batch(
                            self.fs, fold.survivors.tolist())
                            if e is not None]
            removed = fold.removed.tolist()
            with self._db_sem:                    # bounded DB concurrency
                with reg.trace("pipeline.commit", entries=len(entries),
                               removed=len(removed), **self._tlabels):
                    self.catalog.commit_delta_batch(entries, removed)
            with self._processed_lock:
                self.processed += n
                if fold.dedup:
                    self.dedup_hits += fold.dedup
            reg.counter(
                "pipeline_events_folded", help="per-fid folds committed "
                "(records deduped per batch)", **self._tlabels
            ).inc(int(fold.survivors.size + fold.removed.size))
            if fold.annihilated.size:
                reg.counter(
                    "pipeline_annihilations", help="same-batch CREAT→UNLNK "
                    "pairs cancelled before materializing",
                    **self._tlabels).inc(int(fold.annihilated.size))
            batch = DeltaBatch(
                mdt=cb.mdt, seqs=cb.seq,
                changed=[e.fid for e in entries], removed=removed,
                entries=entries, dedup=fold.dedup,
                annihilated=fold.annihilated.tolist())
            self._notify(batch.changed, batch.removed, batch)
            self._acks[cb.mdt].complete_range(int(cb.seq[0]),
                                              int(cb.seq[-1]))

    # -- record -> catalog application (scalar oracle) -----------------------------
    def _apply_records(self, recs: List[ChangelogRecord]) -> None:
        """GET_INFO + DB_APPLY for one batch, then mark complete for ack.

        Records are folded per fid, last-in-record-order wins: repeated
        updates of one entry cost a single ``fs.stat``, and an ``UNLNK``
        following a ``CREAT`` of the same fid inside the batch results in a
        removal only (the short-lived entry is never materialized).
        """
        with self.telemetry.trace("pipeline.apply", records=len(recs),
                                  **self._tlabels):
            is_removal: Dict[int, bool] = {}  # fid -> last op kind, batch order
            for rec in recs:
                if self.counters is not None:
                    self.counters.on_record(rec)
                is_removal[rec.fid] = int(rec.type) in _RM
            entries: List[Entry] = []
            removals: List[int] = []
            for fid, rm in is_removal.items():
                if rm:
                    removals.append(fid)
                    continue
                with self._fs_sem:                   # bounded FS concurrency
                    e = self.fs.stat(fid)
                if e is not None:
                    entries.append(e)
            with self._db_sem:                        # bounded DB concurrency
                if entries:
                    self.catalog.upsert_batch(entries)  # durable before ack
                for fid in removals:
                    self.catalog.remove(fid)
            with self._processed_lock:
                self.processed += len(recs)
            self.telemetry.counter(
                "pipeline_events_folded", help="per-fid folds committed "
                "(records deduped per batch)", **self._tlabels
            ).inc(len(is_removal))
            changed = [e.fid for e in entries]
            batch = None
            if self._batch_listeners:
                batch = DeltaBatch(
                    mdt=recs[0].mdt, seqs=np.array([r.seq for r in recs]),
                    changed=changed, removed=removals, entries=entries,
                    dedup=len(recs) - len(is_removal), annihilated=[])
            self._notify(changed, removals, batch)
            self._acks[recs[0].mdt].complete([r.seq for r in recs])

    def _tag_records(self, recs: List[ChangelogRecord]) -> None:
        """Async mode stage 1: tag dirty + ack immediately after durable tag.

        Removals still apply synchronously (they can't be 'refreshed'
        later). The dirty tags land in the catalog as ONE vectorized
        ``update_fields_batch(dirty=1)`` — one sqlite commit for the whole
        batch instead of a write per record while holding the dirty lock.
        """
        removals = []
        folds = 0                 # committed work: new tags + removals
        with self._dirty_cv:
            new_tags: List[int] = []
            for rec in recs:
                if self.counters is not None:
                    self.counters.on_record(rec)
                if int(rec.type) in _RM:
                    removals.append(rec.fid)
                    self._dirty.discard(rec.fid)      # never refreshed post-rm
                    folds += 1
                elif rec.fid in self._dirty:
                    self.dedup_hits += 1              # folded into pending tag
                else:
                    self._dirty.add(rec.fid)
                    new_tags.append(rec.fid)
                    folds += 1
            if new_tags:
                # durable tag under the dirty lock (an updater must never
                # refresh-and-clear a fid whose tag hasn't landed), but
                # batched: one vectorized patch + one commit
                self.catalog.update_fields_batch(new_tags, dirty=1)
            self._dirty_cv.notify_all()               # wake updaters
        with self._db_sem:
            for fid in removals:
                self.catalog.remove(fid)
        with self._processed_lock:
            self.processed += len(recs)
        self.telemetry.counter(
            "pipeline_events_folded", help="per-fid folds committed "
            "(records deduped per batch)", **self._tlabels).inc(folds)
        # changed fids are notified by the updater after the actual refresh
        self._notify([], removals)
        self._acks[recs[0].mdt].complete([r.seq for r in recs])

    def _take_dirty(self) -> List[int]:
        """Pop one updater batch; counts it in-flight while held."""
        take = list(self._dirty)[: self.cfg.batch_size]
        if take:
            for fid in take:
                self._dirty.discard(fid)
            self._inflight_add(1)
        return take

    def _refresh(self, take: List[int]) -> None:
        """Updater stage 2: re-stat + upsert a popped dirty batch."""
        try:
            entries = []
            with self._fs_sem:
                for e in _fs_stat_batch(self.fs, take):
                    if e is not None:
                        e.dirty = False
                        entries.append(e)
            with self._db_sem:
                if entries:
                    self.catalog.upsert_batch(entries)
            self._notify([e.fid for e in entries], [])
        finally:
            self._inflight_add(-1)

    def _updater(self) -> None:
        """Background refresh of dirty-tagged entries (paper's 'updaters').

        Blocks on the dirty Condition — zero wakeups while the pipeline
        is idle (asserted via the span histograms in the tests) instead
        of the old fixed-interval polling.
        """
        while True:
            with self._dirty_cv:
                self._dirty_cv.wait_for(
                    lambda: self._dirty or self._stop.is_set())
                take = self._take_dirty()
            if not take:
                if self._stop.is_set():
                    return
                continue
            self.telemetry.counter(
                "pipeline_wakeups", help="reader/updater loop iterations "
                "that found work", thread="updater", **self._tlabels).inc()
            self._refresh(take)

    # -- driver ------------------------------------------------------------------
    def _handler(self) -> Tuple[Callable, bool]:
        """Active record handler + whether it takes ColumnarRecords."""
        if self.cfg.async_updates:
            return self._tag_records, False
        if self.cfg.columnar:
            return self._apply_columnar, True
        return self._apply_records, False

    def _adapt_quantum(self, mdt: int, stream: ChangelogStream,
                       apply_seconds: float) -> None:
        """Backpressure loop: one adjustment per applied batch, driven by
        the same cursor state the telemetry gauges export."""
        q = self._quantum[mdt]
        direction = None
        if apply_seconds > self.cfg.target_batch_seconds \
                and q > self.cfg.min_batch:
            q = max(self.cfg.min_batch, q // 2)     # ack latency degrading
            direction = "shrink"
        elif stream.pending() > q and q < self.cfg.max_batch \
                and stream.lag_seconds() <= self.cfg.lag_target:
            q = min(self.cfg.max_batch, q * 2)      # backlog rising, lag ok
            direction = "grow"
        if direction is not None:
            self._quantum[mdt] = q
            self.telemetry.gauge(
                "pipeline_batch_quantum", help="adaptive per-MDT read "
                "quantum", mdt=str(mdt), **self._tlabels).set(q)
            self.telemetry.counter(
                "pipeline_batch_adaptations", help="adaptive quantum "
                "transitions", mdt=str(mdt), direction=direction,
                **self._tlabels).inc()

    def _reader_columnar(self, mdt: int, stream: ChangelogStream) -> None:
        """One sharded reader per MDT: read → apply inline → adapt.

        Applying on the reader thread is the backpressure: the reader
        cannot read faster than the catalog commits, so the only queue in
        the system is the changelog itself (bounded by its ack cursor).
        """
        handler, takes_columnar = self._handler()
        wakeups = self.telemetry.counter(
            "pipeline_wakeups", help="reader/updater loop iterations that "
            "found work", thread=f"reader_mdt{mdt}", **self._tlabels)
        while True:
            cb = stream.read_columnar(max_records=self._quantum[mdt],
                                      timeout=60.0, stop=self._stop)
            if cb is None:
                if self._stop.is_set():
                    return
                continue
            wakeups.inc()
            self._inflight_add(1)
            try:
                t0 = time.perf_counter()
                handler(cb if takes_columnar else cb.records)
                dt = time.perf_counter() - t0
            finally:
                self._inflight_add(-1)
            self._adapt_quantum(mdt, stream, dt)

    def _reader(self, mdt: int, stream: ChangelogStream) -> None:
        """Oracle-mode reader: blocking read → bounded batch queue."""
        while not self._stop.is_set():
            recs = stream.read(max_records=self.cfg.batch_size,
                               timeout=60.0, stop=self._stop)
            if recs:
                self._batches.put(recs)

    def _worker(self) -> None:
        handler = self._tag_records if self.cfg.async_updates \
            else self._apply_records
        while True:
            recs = self._batches.get()
            if recs is None:                      # shutdown sentinel
                self._batches.task_done()
                return
            self._inflight_add(1)
            try:
                handler(recs)
            finally:
                self._inflight_add(-1)
                self._batches.task_done()

    def start(self) -> None:
        if self.cfg.columnar:
            # sharded per-MDT readers apply inline (tag_records in async
            # mode) — no intermediate batch queue, no worker pool
            self._threads = [
                threading.Thread(target=self._reader_columnar,
                                 args=(mdt, s), daemon=True)
                for mdt, s in self.streams.items()]
        else:
            self._threads = [
                threading.Thread(target=self._reader, args=(mdt, s),
                                 daemon=True)
                for mdt, s in self.streams.items()]
            self._threads += [threading.Thread(target=self._worker,
                                               daemon=True)
                              for _ in range(self.cfg.n_workers)]
        if self.cfg.async_updates:
            self._threads += [threading.Thread(target=self._updater,
                                               daemon=True)
                              for _ in range(self.cfg.n_updaters)]
        for t in self._threads:
            t.start()

    def total_pending(self) -> int:
        return sum(s.pending() for s in self.streams.values())

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every emitted record has been processed and acked.

        The in-flight counter closes the drain race: a worker holding a
        popped batch, or an updater holding fids it removed from
        ``_dirty`` before the refresh commits, keeps ``_inflight`` > 0 —
        ``pending()==0 and _batches.empty() and not _dirty`` alone would
        report drained while that refresh is still in flight.
        """
        deadline = time.time() + timeout
        while time.time() < deadline:
            if self.total_pending() == 0 and self._batches.empty() \
                    and not self._dirty and self._inflight == 0:
                return True
            time.sleep(0.01)
        return False

    def stop(self) -> None:
        self._stop.set()
        for s in self.streams.values():
            s.wake()                            # unblock condition reads
        if not self.cfg.columnar:
            for _ in range(self.cfg.n_workers):
                self._batches.put(None)         # one sentinel per worker
        with self._dirty_cv:
            self._dirty_cv.notify_all()         # unblock updaters
        for t in self._threads:
            t.join(timeout=5)

    def process_once(self, max_records: int = 4096) -> int:
        """Synchronous single-shot processing (no threads) — for tests.

        With a hub attached, streams are drained via the fair round-robin
        sweep (one quantum per MDT per pass)."""
        handler, takes_columnar = self._handler()
        total = 0
        while total < max_records:
            quantum = min(max_records - total, self.cfg.batch_size)
            if isinstance(self.stream, ChangelogHub):
                batches = self.stream.read_round_robin(quantum=quantum)
            else:
                cb = self.stream.read_columnar(max_records=quantum)
                batches = [cb] if cb is not None else []
            if not batches:
                break
            for cb in batches:
                handler(cb if takes_columnar else cb.records)
                total += len(cb)
        if self.cfg.async_updates:
            # run one updater sweep inline
            while self._dirty:
                with self._dirty_cv:
                    take = self._take_dirty()
                if take:
                    self._refresh(take)
        return total
