"""Transactional, persistent changelog streams — MDT ChangeLog analogue (C3).

Contract reproduced from the paper (SII-C2):

* records are appended to a per-MDT stream with monotonically increasing
  sequence numbers and kept on persistent storage;
* a consumer registers, reads batches, and **acks** a sequence number only
  after the corresponding change has been committed to its own database;
* records are purged only once acked, so no event is ever lost — even if the
  consumer crashes mid-processing, unacked records are re-delivered on
  restart.

Persistence is an append-only JSONL file per stream (fsync on append batch)
plus a tiny ack cursor file. DNE is modelled by running one stream per MDT.
"""
from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional

from .types import ChangelogRecord, ChangelogType


class ChangelogStream:
    """One MDT's changelog: producer side (append) + consumer side (read/ack)."""

    def __init__(self, mdt: int = 0, persist_dir: Optional[str] = None,
                 fsync: bool = False) -> None:
        self.mdt = mdt
        self._lock = threading.Condition()
        self._records: Deque[ChangelogRecord] = deque()
        self._next_seq = 1
        self._acked = 0                  # highest acked seq
        self._read_cursor = 0            # highest seq handed to the consumer
        self._persist_dir = persist_dir
        self._fsync = fsync
        self._fh = None
        self._closed = False
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)
            self._log_path = os.path.join(persist_dir, f"changelog_mdt{mdt}.jsonl")
            self._ack_path = os.path.join(persist_dir, f"changelog_mdt{mdt}.ack")
            self._recover()
            self._fh = open(self._log_path, "a", encoding="utf-8")

    # -- persistence -----------------------------------------------------------
    def _recover(self) -> None:
        """Reload unacked records after a crash (paper: no event loss)."""
        acked = 0
        if os.path.exists(self._ack_path):
            with open(self._ack_path, "r", encoding="utf-8") as f:
                txt = f.read().strip()
                acked = int(txt) if txt else 0
        self._acked = acked
        if os.path.exists(self._log_path):
            with open(self._log_path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    d = json.loads(line)
                    rec = ChangelogRecord(
                        seq=d["seq"], type=ChangelogType(d["type"]),
                        fid=d["fid"], parent_fid=d.get("parent_fid", -1),
                        name=d.get("name", ""), time=d.get("time", 0.0),
                        uid=d.get("uid", ""), jobid=d.get("jobid", ""),
                        mdt=self.mdt, attrs=d.get("attrs"))
                    if rec.seq > acked:
                        self._records.append(rec)
                    self._next_seq = max(self._next_seq, rec.seq + 1)
        # re-delivery: reader starts from the oldest unacked record
        self._read_cursor = acked

    def _persist_records(self, recs: List[ChangelogRecord]) -> None:
        if self._fh is None:
            return
        for r in recs:
            self._fh.write(json.dumps({
                "seq": r.seq, "type": int(r.type), "fid": r.fid,
                "parent_fid": r.parent_fid, "name": r.name, "time": r.time,
                "uid": r.uid, "jobid": r.jobid, "attrs": r.attrs}) + "\n")
        self._fh.flush()
        if self._fsync:
            os.fsync(self._fh.fileno())

    # -- producer ----------------------------------------------------------------
    def emit(self, type: ChangelogType, fid: int, **kw) -> ChangelogRecord:
        with self._lock:
            rec = ChangelogRecord(seq=self._next_seq, type=type, fid=fid,
                                  mdt=self.mdt, **kw)
            self._next_seq += 1
            self._records.append(rec)
            self._persist_records([rec])
            self._lock.notify_all()
            return rec

    def emit_batch(self, recs: Iterable[ChangelogRecord]) -> None:
        with self._lock:
            out = []
            for r in recs:
                r.seq = self._next_seq
                r.mdt = self.mdt
                self._next_seq += 1
                self._records.append(r)
                out.append(r)
            self._persist_records(out)
            self._lock.notify_all()

    # -- consumer -----------------------------------------------------------------
    def read(self, max_records: int = 1024, timeout: Optional[float] = None
             ) -> List[ChangelogRecord]:
        """Read the next batch past the read cursor (does NOT ack)."""
        with self._lock:
            if timeout is not None:
                self._lock.wait_for(
                    lambda: self._closed or any(
                        r.seq > self._read_cursor for r in self._records),
                    timeout=timeout)
            out = [r for r in self._records if r.seq > self._read_cursor]
            out = out[:max_records]
            if out:
                self._read_cursor = out[-1].seq
            return out

    @property
    def acked(self) -> int:
        """Highest acknowledged sequence number (consumer progress)."""
        with self._lock:
            return self._acked

    def ack(self, seq: int) -> None:
        """Acknowledge every record up to ``seq``; they are then purged."""
        with self._lock:
            self._acked = max(self._acked, seq)
            while self._records and self._records[0].seq <= self._acked:
                self._records.popleft()
            if self._persist_dir:
                tmp = self._ack_path + ".tmp"
                with open(tmp, "w", encoding="utf-8") as f:
                    f.write(str(self._acked))
                os.replace(tmp, self._ack_path)

    def reset_cursor(self) -> None:
        """Simulate consumer restart: unacked records are re-delivered."""
        with self._lock:
            self._read_cursor = self._acked

    def pending(self) -> int:
        with self._lock:
            return sum(1 for r in self._records if r.seq > self._acked)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            self._lock.notify_all()


class ChangelogHub:
    """All MDT streams of a (possibly DNE) filesystem."""

    def __init__(self, n_mdts: int = 1, persist_dir: Optional[str] = None,
                 fsync: bool = False) -> None:
        self.streams: Dict[int, ChangelogStream] = {
            i: ChangelogStream(i, persist_dir, fsync) for i in range(n_mdts)
        }

    def stream(self, mdt: int = 0) -> ChangelogStream:
        return self.streams[mdt]

    def total_pending(self) -> int:
        return sum(s.pending() for s in self.streams.values())

    def close(self) -> None:
        for s in self.streams.values():
            s.close()
