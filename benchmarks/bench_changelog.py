"""Paper SII-C2 + SIII-A2: changelog processing rate, sync vs async
dirty-tag (the paper's proposed improvement, implemented), and vs rescan.
"""
from __future__ import annotations

import time

from repro.core import Catalog, EventPipeline, PipelineConfig, Scanner
from repro.fs import LustreSim


def _workload(n_files=800, updates_per_file=5):
    fs = LustreSim()
    d = fs.mkdir(fs.root_fid(), "hot")
    fids = [fs.create(d, f"f{i}", owner="u") for i in range(n_files)]
    # drain creation events first
    cat = Catalog()
    EventPipeline(fs, cat, fs.changelog.stream(0),
                  PipelineConfig()).process_once(10 ** 6)
    # hot-file workload: repeated writes (dedup-friendly, paper SIII-A2)
    for r in range(updates_per_file):
        for f in fids:
            fs.write(f, 100)
    return fs, cat, n_files * updates_per_file


def run() -> list:
    rows = []
    for mode in ("sync", "async_dirty_tag"):
        fs, cat, n_events = _workload()
        cfg = PipelineConfig(async_updates=(mode != "sync"), batch_size=512)
        pipe = EventPipeline(fs, cat, fs.changelog.stream(0), cfg)
        t0 = time.perf_counter()
        n = pipe.process_once(10 ** 7)
        dt = time.perf_counter() - t0
        extra = f"_dedup_{pipe.dedup_hits}" if mode != "sync" else ""
        rows.append((f"changelog_{mode}", 1e6 * dt / max(1, n),
                     f"{n/dt:.0f}_records_per_s{extra}"))
    # the alternative the paper kills: full rescan to refresh the mirror
    fs, cat, _ = _workload()
    t0 = time.perf_counter()
    Scanner(fs, cat, n_threads=4).scan()
    dt = time.perf_counter() - t0
    rows.append(("full_rescan_equivalent", 1e6 * dt / fs.count(),
                 f"{fs.count()/dt:.0f}_entries_per_s"))
    return rows
