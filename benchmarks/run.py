"""Benchmark harness: one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (harness contract). Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]
                                            [--json OUT]

``--smoke`` shrinks problem sizes (CI budget: whole suite < 2 min);
``--json OUT`` additionally writes a BENCH_*.json-shaped dict so runs can
be tracked as a perf trajectory over PRs.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

MODULES = [
    "bench_scan",        # Fig. 3: parallel DFS + multi-client scan
    "bench_changelog",   # SII-C2/SIII-A2: changelog rates, async dirty-tag
    "bench_stats",       # SII-B3: O(1) pre-aggregated reports
    "bench_policy",      # SII-B1: policy matching (4 evaluators + engine)
    "bench_find_du",     # SII-B4: find/du clones vs POSIX walk
    "bench_kvtier",      # adapted C7/C8: KV-page tiering + paged serving
    "roofline_report",   # SRoofline summary rows from the dry-run artifacts
]


def _call_run(mod, smoke: bool) -> list:
    """Pass smoke= only to modules that accept it (older ones don't)."""
    sig = inspect.signature(mod.run)
    if "smoke" in sig.parameters:
        return mod.run(smoke=smoke)
    return mod.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink sizes for a <2 min CI run")
    ap.add_argument("--json", dest="json_out", default=None, metavar="OUT",
                    help="also write a BENCH_*.json-shaped result dict")
    args = ap.parse_args()
    if args.only and args.only not in MODULES:
        ap.error(f"unknown module {args.only!r} (choose from {MODULES})")
    print("name,us_per_call,derived")
    failed = 0
    results = []
    t_start = time.time()
    for name in MODULES:
        if args.only and args.only != name:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in _call_run(mod, args.smoke):
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}", flush=True)
                results.append({"name": n, "us_per_call": float(us),
                                "derived": str(derived), "module": name})
        except Exception as e:
            failed += 1
            print(f"{name},NaN,ERROR_{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.json_out:
        payload = {
            "suite": "benchmarks.run",
            "smoke": bool(args.smoke),
            "elapsed_s": round(time.time() - t_start, 3),
            "failed_modules": failed,
            "rows": results,
        }
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
