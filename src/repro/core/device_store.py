"""Device-resident sharded column store for mesh-parallel policy matching.

The paper's core scaling claim (SII-B1, SIII-B) is that policy runs over
billions of entries must never re-read the namespace. The engine's kernel
path used to violate that in two ways every run: ``Catalog.arrays()``
concatenated every shard's columns on the host, and ``match_programs``
re-stacked and re-uploaded the full f32 column stack host→device — all of
it landing on ONE device even though the catalog is already sharded. This
module keeps the kernel's column stacks *resident* on a device mesh and
maintains them by deltas, so a warm policy run uploads only the rows that
actually churned.

Residency model
---------------
Catalog shards are folded onto the 1-D ``("shards",)`` mesh (see
``launch.mesh.make_shards_mesh``): shard ``s`` belongs to **shard group**
``s % D`` for a D-device mesh, and each group's rows (the concatenation of
its member shards' valid-row snapshots) live on exactly one device as an
``(n_cols+1, Rp)`` float32 block — ``KERNEL_COLUMNS`` in kernel order plus
a trailing 0/1 row-validity column. Every group is padded to the same
``Rp`` (a kernel-tile multiple, allocated with growth headroom) so the
per-device blocks assemble zero-copy into one global ``(D, n_cols+1, Rp)``
array sharded along ``"shards"`` — the operand
:func:`~repro.kernels.policy_scan.ops.mesh_policy_scan_batch` consumes
under ``shard_map``. Matching therefore moves **no column data at all**:
only the (R, P) programs go up, and only the program-0 mask, the
first-match-wins rule attribution, and the psum-combined (R, N_AGG)
aggregates come back.

Beside each device block the store keeps a **host mirror** of the group:
the row-aligned ``fid`` array plus every kernel column in its native dtype.
The mirror is what translates matched local row indices back to fids and
serves exact int64/float64 ``size``/sort-key values to the engine's
planner — it is maintained by the same deltas as the device block, so no
post-match catalog gather is needed.

Version keying and refresh
--------------------------
Freshness is keyed by the existing per-shard change ticks
(:attr:`CatalogShard.version`): a group is *stale* when any member shard's
tick moved past the value recorded at its last upload, or when delta hooks
flagged pending changes. The store registers a
:meth:`Catalog.add_delta_hook` at attach time and classifies every delta:

* in-place update (old and new both present)  -> the fid joins the group's
  **dirty set**; refresh scatters just those rows — one
  :meth:`Catalog.gather_rows` host gather, one vectorized
  ``block.at[:, rows].set(vals)`` on the owning device (row positions are
  stable under pure updates, so the scatter is exact);
* insert or remove (``old is None`` / ``new is None``) -> the group is
  flagged **structural** and falls back to a full re-upload (snapshot →
  restack → ``device_put``), because row positions shift;
* dirty set larger than ``refresh_frac`` of the group's rows -> full
  re-upload too (documented churn threshold: past it one contiguous upload
  beats that many scattered rows);
* shard tick moved with *no* recorded deltas (store attached late, hooks
  bypassed) -> full re-upload, never a stale serve.

Version ticks are read *before* the snapshot/gather (the catalog's own
``_bump`` discipline), so a racing mutation can only make the next refresh
redundant, never leave the device block stale. A group whose row count
outgrows ``Rp`` forces a global re-pad (all groups re-upload at the new
``Rp``).

Analytics planes (mesh-resident reports + profile cube)
-------------------------------------------------------
Beyond the kernel columns, each device block can carry extra **analytics
rows** maintained by the very same upload/scatter paths:

* **reports plane** (:meth:`DeviceColumnStore.enable_reports_plane`):
  one ``ord`` row — each row's rank in its group's *sorted-path* order.
  ``rbh-du`` becomes two host binary searches into the group's sorted
  path mirror plus one fused on-device range aggregate
  (:func:`~repro.kernels.policy_scan.ops.mesh_range_aggregate`);
  ``rbh-find`` is a mesh program match whose winners translate to paths
  through the mirror; top-N listings run a two-pass on-device top-k
  (:func:`~repro.kernels.policy_scan.ops.mesh_column_topk` to find the
  exact k-th-best threshold, then a threshold mask to recover every
  boundary tie). A *rename* (path change on a pure update) shifts the
  sorted order, so it degrades that group to a full re-upload exactly
  like a structural change.
* **cube plane** (:meth:`DeviceColumnStore.enable_cube_plane`): three
  rows — dense profile group id (``core.profiles.GroupIndex``), size
  bucket and age bucket (bucketized exactly on the host at scatter
  time). Each device additionally keeps a flat **partial profile cube**
  of its resident rows, built in one
  :func:`~repro.kernels.profile_cube.ops.mesh_profile_cube` launch and
  maintained by O(dirty) *signed* scatter-adds from the same delta
  batches that refresh the columns; queries psum-combine the resident
  partials (:func:`~repro.kernels.profile_cube.ops.mesh_cube_combine`)
  — after the cold build no profile query re-reads host columns. Age
  buckets reference the store-wide ``_cube_ref`` instant; per-row flip
  schedules (mirroring ``core.profiles._ShardCube``) advance only the
  due rows when queries move ``now`` forward.
* **permissions plane**
  (:meth:`DeviceColumnStore.enable_permissions_plane`): per-subject
  visibility pre-materialized as packed ``uint32`` bitsets over local
  row ids — one ``(1, Sp, Rp/32)`` buffer per device beside the column
  block (bit ``b`` of word ``w``, LSB first, covers local row
  ``w*32+b``). Visibility comes from a
  :class:`~repro.core.grants.GrantTable`: uid/gid ownership via the
  interned owner/group codes, directory-subtree grants resolved through
  the reports plane's sorted-path mirrors (the same rank-range shape as
  ``du`` — enabling this plane forces the reports plane on). Scoped
  queries (``subject=`` on :meth:`match` / :meth:`find_paths` /
  :meth:`top_files` / :meth:`du` / :meth:`analytics_cube`) assemble the
  sharded perm array and pass a traced subject id; the kernels unpack
  that one subject's bitset and AND it into the match mask — tenant
  scoping is one fused AND, never a second scan. Maintenance follows
  the column contract: pure updates re-derive only the dirty rows'
  visibility and scatter just the *changed packed words* into the
  resident buffer; structural churn / renames / re-pads invalidate the
  group's bitset alongside its block, and any
  :attr:`~repro.core.grants.GrantTable.version` tick (new subject or
  grant change) re-materializes on the next scoped query.

Shared delta fan-out contract
-----------------------------
One catalog mutation fans out to every derived structure through
*independent* :meth:`Catalog.add_delta_hook` subscriptions, and each
consumer must apply it **exactly once**:

* this store's hook feeds the per-group dirty sets; a refresh drains a
  dirty *set* (duplicate updates to one fid collapse) and applies the
  column scatter, the analytics-row scatter and the signed cube move in
  the same drain — never separately;
* the cube's signed move subtracts the *mirror* state (what the resident
  cube actually holds) and adds the freshly gathered state, so collapsed
  multi-updates net out exactly;
* a :class:`~repro.core.profiles.ProfileCube` that attached this store
  (``ProfileCube.attach_device_store``) claims the cube's single delta
  feed and makes its own ``on_delta`` a no-op — wiring both its host
  hook and the store plane would double-count every mutation (the same
  single-feed contract as ``ProfileCube.attach`` vs a cube-backed
  ``StatsAggregator``);
* the policy engine's incremental state consumes the same deltas via
  ``note_touched``; a mesh full scan primes that cache through
  :meth:`MeshMatch.cache_arrays` (mirror-served, no catalog re-read).

f32 envelope
------------
Device blocks are float32, exactly like the single-device kernel path:
sizes above 2**24 bytes land on the nearest representable f32 (~one part
in 16M — entries within one ulp of a size cutoff may flip vs the int64
numpy path) and epoch-second timestamps carry ~64 s resolution. The host
mirror keeps native dtypes, so fids, budget sizes and sort keys returned
to the planner are exact; only predicate evaluation lives in the f32
envelope. The same envelope bounds the analytics planes: partial-cube
cells and ``du`` aggregates accumulate in f32 (exact for integer sums
below 2**24 times the value granularity), and path ranks are exact below
2**24 rows per group. Differential tests pin the envelope with f32-exact
catalogs; the host folds remain the differential oracles.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog, Delta
from .policy import KERNEL_COLUMNS, PolicyError, compile_programs

_VALID_COL = len(KERNEL_COLUMNS)          # trailing 0/1 row-validity column

# analytics rows appended after the validity row when a plane is enabled
# (all four are allocated together; a disabled plane's rows stay zero)
_ORD_COL = _VALID_COL + 1                 # sorted-path rank (reports plane)
_GID_COL = _VALID_COL + 2                 # dense profile group id (cube)
_SB_COL = _VALID_COL + 3                  # size-profile bucket (cube)
_AB_COL = _VALID_COL + 4                  # age bucket as of _cube_ref (cube)
_N_ANALYTICS = 4

# columns the host mirror serves to the planner (fids + kernel columns);
# a policy sorting by anything else (e.g. parent_fid) cannot plan from the
# store and raises PolicyError -> the engine falls back to a host scan
PLAN_COLUMNS = ("fid",) + KERNEL_COLUMNS


class _RepadNeeded(Exception):
    """Internal: a group's snapshot outgrew the padded row capacity
    mid-refresh (concurrent inserts); refresh() re-pads and retries."""

    def __init__(self, rows: int) -> None:
        super().__init__(rows)
        self.rows = rows

_SCATTER_FN = None                        # lazily-jitted dirty-row scatter


def _scatter_rows(buf, rows: np.ndarray, vals: np.ndarray):
    """Scatter (C, k) dirty-row values into a resident (1, C+1, Rp) block.

    Jitted with the block donated (in-place on its own device) and k
    padded to power-of-two buckets by the caller, so XLA compiles one
    executable per (bucket, device) instead of one per distinct dirty-row
    count — the scatter itself is O(k), never O(Rp).
    """
    global _SCATTER_FN
    if _SCATTER_FN is None:
        import jax

        def fn(buf, rows, vals):
            return buf.at[0, : vals.shape[0], rows].set(vals.T)

        _SCATTER_FN = jax.jit(fn, donate_argnums=(0,))
    return _SCATTER_FN(buf, rows, vals)


def _pad_bucket(rows: np.ndarray, vals: np.ndarray, min_bucket: int = 64
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a scatter to the next power-of-two size with idempotent
    duplicates of row 0 (same index, same values -> deterministic).

    Safe for scatter-SET only: duplicated (index, value) pairs write the
    same value twice. A scatter-ADD must pad with *zero-valued* deltas
    instead (:func:`_pad_zero`) or padding would double-apply.
    """
    bucket = min_bucket
    while bucket < rows.size:
        bucket *= 2
    pad = bucket - rows.size
    if not pad:
        return rows, vals
    return (np.concatenate([rows, np.full(pad, rows[0], rows.dtype)]),
            np.concatenate([vals, np.repeat(vals[:, :1], pad, axis=1)],
                           axis=1))


def _pad_zero(flat: np.ndarray, vals: np.ndarray, min_bucket: int = 64
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Power-of-two padding for scatter-ADD: pad cells target index 0
    with all-zero deltas (adding 0 is the idempotent no-op here)."""
    bucket = min_bucket
    while bucket < flat.size:
        bucket *= 2
    pad = bucket - flat.size
    if not pad:
        return flat, vals
    return (np.concatenate([flat, np.zeros(pad, flat.dtype)]),
            np.concatenate([vals, np.zeros((vals.shape[0], pad),
                                           vals.dtype)], axis=1))


_SCATTER_ROW_FN = None                    # lazily-jitted single-row scatter


def _scatter_row(buf, row: int, rows: np.ndarray, vals: np.ndarray):
    """Scatter values into ONE block row (age-bucket rollovers touch only
    the ``_AB_COL`` row). Donated + bucket-padded like :func:`_scatter_rows`;
    the row index is static (one executable per analytics row)."""
    global _SCATTER_ROW_FN
    if _SCATTER_ROW_FN is None:
        import jax

        def fn(buf, rows, vals, *, row):
            return buf.at[0, row, rows].set(vals)

        _SCATTER_ROW_FN = jax.jit(fn, static_argnames=("row",),
                                  donate_argnums=(0,))
    return _SCATTER_ROW_FN(buf, rows, vals, row=row)


_CUBE_SCATTER_FN = None                   # lazily-jitted cube scatter-add


def _cube_scatter(buf, flat: np.ndarray, vals: np.ndarray):
    """Signed scatter-add of (3, k) measure deltas into a resident
    (1, 3, M) flat partial cube at flat cell indices ``flat``. Donated
    (in-place on the partial's own device); callers pad with
    :func:`_pad_zero` so duplicate pad cells add nothing."""
    global _CUBE_SCATTER_FN
    if _CUBE_SCATTER_FN is None:
        import jax

        def fn(buf, flat, vals):
            return buf[0].at[:, flat].add(vals)[None]

        _CUBE_SCATTER_FN = jax.jit(fn, donate_argnums=(0,))
    return _CUBE_SCATTER_FN(buf, flat, vals)


class MeshMatch:
    """Result of one mesh-parallel program-batch evaluation.

    Holds the per-group matched local row indices (already nonzero'd on the
    host from the program-0 mask) plus the store's host mirrors; ``plan``
    gathers the planner arrays without touching the catalog. A delta
    refresh mutates the mirrors in place, so ``plan`` takes the store lock
    and raises :class:`PolicyError` when the store refreshed since this
    match (a stale plan would mix pre-churn masks with post-churn values)
    — call it before the next refresh, as the engine does.
    """

    def __init__(self, store: "DeviceColumnStore", epoch: int,
                 mirrors: List[Tuple[np.ndarray, Dict[str, np.ndarray]]],
                 group_idx: List[np.ndarray], group_rule: List[np.ndarray],
                 agg: dict, reval: int) -> None:
        self._store = store
        self._epoch = epoch                # store mutation tick at match
        self._mirrors = mirrors            # per group: (fids, cols) refs
        self._group_idx = group_idx        # per group: matched local rows
        self._group_rule = group_rule      # per group: rule idx at those rows
        self.agg = agg
        self.reval = reval                 # valid rows evaluated on-device

    @property
    def matched(self) -> int:
        return int(sum(ix.size for ix in self._group_idx))

    def plan(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """(fids, sizes, sort_keys, rule_idx) of matched rows, native
        dtypes from the host mirror (exact budgets/ordering)."""
        if sort_by not in PLAN_COLUMNS:
            raise PolicyError(
                f"sort_by {sort_by!r} is not in the device-store host "
                f"mirror (available: fid + kernel columns)")
        with self._store._lock:
            if self._store._epoch != self._epoch:
                raise PolicyError(
                    "stale MeshMatch: the device store refreshed since "
                    "this match — re-match before planning")
            return self._plan_locked(sort_by)

    def _plan_locked(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray]:
        fids, sizes, keys, rules = [], [], [], []
        for (gfids, gcols), idx, rl in zip(self._mirrors, self._group_idx,
                                           self._group_rule):
            fids.append(gfids[idx])
            sizes.append(gcols["size"][idx])
            keys.append(np.asarray(gcols[sort_by][idx], dtype=np.float64))
            rules.append(rl)
        return (np.concatenate(fids) if fids else np.zeros(0, np.int64),
                np.concatenate(sizes) if sizes else np.zeros(0, np.int64),
                np.concatenate(keys) if keys else np.zeros(0),
                np.concatenate(rules) if rules else np.zeros(0, np.int32))

    def cache_arrays(self, sort_by: str, age_preds, now: float
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray, np.ndarray, np.ndarray]:
        """Plan arrays + the age-flip schedule that primes the engine's
        incremental match cache from this mesh full scan.

        Returns ``(fids, sizes, sort_keys, rule_idx, flip_fids, flips)``:
        the first four are :meth:`plan`'s exact output; the last two cover
        **every** mirrored row whose age predicates flip at a finite
        future instant (``time_col + threshold``, boundary kept — the
        same semantics as ``policy_engine._next_flips`` over a host
        snapshot), so a currently-unmatched row that ages into scope is
        still re-evaluated on time. Everything is served from the host
        mirrors — the catalog columns are never touched.
        """
        if sort_by not in PLAN_COLUMNS:
            raise PolicyError(
                f"sort_by {sort_by!r} is not in the device-store host "
                f"mirror (available: fid + kernel columns)")
        with self._store._lock:
            if self._store._epoch != self._epoch:
                raise PolicyError(
                    "stale MeshMatch: the device store refreshed since "
                    "this match — re-match before planning")
            fids, sizes, keys, rules = self._plan_locked(sort_by)
            ffids, flips = [], []
            for gfids, gcols in self._mirrors:
                if not gfids.size or not age_preds:
                    continue
                nxt = np.full(gfids.size, np.inf)
                for time_col, thr in age_preds:
                    cand = np.asarray(gcols[time_col],
                                      dtype=np.float64) + thr
                    np.minimum(nxt, np.where(cand >= now, cand, np.inf),
                               out=nxt)
                keep = np.isfinite(nxt)
                ffids.append(gfids[keep])
                flips.append(nxt[keep])
            return (fids, sizes, keys, rules,
                    np.concatenate(ffids) if ffids
                    else np.zeros(0, np.int64),
                    np.concatenate(flips) if flips else np.zeros(0))


class _ShardGroup:
    """One device's slice of the catalog: host mirror + freshness state.

    Beside the kernel-column mirror, a group carries the analytics-plane
    mirrors: ``offsets`` (member-shard row starts — find/top-N results
    re-emit in catalog ``arrays()`` order through them), the reports
    plane's row-aligned ``paths`` / sorted ``spaths`` / rank ``ord``, and
    the cube plane's per-row group id / size bucket / age bucket / next
    flip instant (``cgid``/``csb``/``cab``/``cflip``, ``cmin_flip`` the
    cheap due-rollover bound).
    """

    __slots__ = ("gid", "shard_ids", "fids", "cols", "rows", "versions",
                 "dirty", "structural", "uploaded", "_order",
                 "offsets", "paths", "spaths", "ord",
                 "cgid", "csb", "cab", "cflip", "cmin_flip", "vis")

    def __init__(self, gid: int, shard_ids: List[int]) -> None:
        self.gid = gid
        self.shard_ids = shard_ids
        self.fids = np.zeros(0, np.int64)
        self.cols: Dict[str, np.ndarray] = {}
        self.rows = 0                      # valid rows (<= Rp)
        self.versions: Dict[int, int] = {}  # shard id -> tick at last upload
        self.dirty: set = set()
        self.structural = False
        self.uploaded = False
        self._order: Optional[np.ndarray] = None   # argsort(fids), lazy
        self.offsets = np.zeros(1, np.int64)       # member-shard row starts
        self.paths: Optional[list] = None          # row-aligned (reports)
        self.spaths: Optional[np.ndarray] = None   # sorted paths (reports)
        self.ord: Optional[np.ndarray] = None      # row -> sorted-path rank
        self.cgid: Optional[np.ndarray] = None     # cube: dense group id
        self.csb: Optional[np.ndarray] = None      # cube: size bucket
        self.cab: Optional[np.ndarray] = None      # cube: age bucket @ ref
        self.cflip: Optional[np.ndarray] = None    # cube: next flip instant
        self.cmin_flip = np.inf
        self.vis: Optional[np.ndarray] = None      # perms: (Sp, rows) bool

    def locate(self, fids: np.ndarray) -> Optional[np.ndarray]:
        """Local row index per fid; None when any fid is not in the mirror
        (caller falls back to a full re-upload)."""
        if not self.rows:
            return None
        if self._order is None:
            self._order = np.argsort(self.fids, kind="stable")
        sorted_fids = self.fids[self._order]
        pos = np.searchsorted(sorted_fids, fids)
        pos = np.clip(pos, 0, sorted_fids.size - 1)
        rows = self._order[pos]
        if not (self.fids[rows] == fids).all():
            return None
        return rows


class DeviceColumnStore:
    """Per-shard-group kernel column stacks held resident on a jax mesh.

    See the module docstring for the residency / refresh / envelope
    contracts. Construction registers a delta hook on the catalog and
    uploads lazily: the first :meth:`refresh` (or :meth:`match`) pays the
    cold full upload, warm calls scatter only churned rows.
    """

    def __init__(self, catalog: Catalog, mesh=None,
                 refresh_frac: float = 0.25, tile: int = 0,
                 headroom: float = 1.25) -> None:
        import jax
        from ..kernels.policy_scan.kernel import LANE
        if mesh is None:
            from ..launch.mesh import make_shards_mesh
            mesh = make_shards_mesh()
        if "shards" not in mesh.axis_names:
            raise PolicyError('device store needs a mesh with a "shards" '
                              f"axis, got {mesh.axis_names}")
        self.catalog = catalog
        self.mesh = mesh
        self.devices = list(np.asarray(mesh.devices).reshape(-1))
        self.n_devices = len(self.devices)
        self.refresh_frac = refresh_frac
        self.tile = tile or 8 * LANE
        self.headroom = headroom
        self._lock = threading.RLock()
        self._groups = [
            _ShardGroup(g, [s for s in range(catalog.n_shards)
                            if s % self.n_devices == g])
            for g in range(self.n_devices)]
        self._rp = 0                        # padded rows per device block
        self._bufs: List[Optional["jax.Array"]] = [None] * self.n_devices
        self._global = None                 # assembled (D, C+1, Rp) array
        self._epoch = 0                     # bumped by every mirror mutation
        # analytics planes (see module docstring): off until enabled
        self._plane_reports = False
        self._plane_cube = False
        self._cube_groups = None            # shared core.profiles.GroupIndex
        self._cube_clock = None
        self._cube_ref = 0.0                # age reference of resident cab
        self._cube_bp = 0                   # padded group capacity on device
        self._cube_bufs = None              # per-device (1, 3, bp*S*A) f32
        self._cube_partials = None          # assembled (D, 3, bp*S*A) array
        self._cube_cache = None             # host int64 (3, bp, S, A) cache
        self._cube_stale = True             # partials need a full rebuild
        self._plane_perm = False
        self._grants = None                 # shared core.grants.GrantTable
        self._grants_version = -1           # table version at materialization
        self._perm_sp = 0                   # padded subject capacity
        self._perm_bufs = None              # per-device (1, Sp, Rp/32) u32
        self._perm_global = None            # assembled (D, Sp, Rp/32) array
        # perf counters (benchmarks / tests assert the refresh mode taken)
        self.full_uploads = 0
        self.delta_refreshes = 0
        self.rows_scattered = 0
        self.cube_rebuilds = 0
        self.rollovers = 0                  # age-bucket moves served on-device
        self.store_queries = 0              # report queries served resident
        self.perm_materializations = 0      # per-group bitset (re)builds
        self.perm_word_scatters = 0         # warm packed-word scatters
        catalog.add_delta_hook(self._on_delta)

    # -- analytics planes ------------------------------------------------------
    def _block_rows(self) -> int:
        """Device-block row count: kernel columns + validity, plus the
        analytics rows once any plane is enabled."""
        extra = _N_ANALYTICS if (self._plane_reports or self._plane_cube) \
            else 0
        return len(KERNEL_COLUMNS) + 1 + extra

    def _drop_device_state(self) -> None:
        """Invalidate every resident block (block layout changed): the
        next refresh re-uploads at the new row count. Lock held."""
        self._bufs = [None] * self.n_devices
        self._global = None
        self._cube_bufs = None
        self._cube_partials = None
        self._cube_cache = None
        self._cube_stale = True
        self._perm_bufs = None
        self._perm_global = None
        self._epoch += 1
        for group in self._groups:
            group.uploaded = False
            group.vis = None

    def enable_reports_plane(self) -> None:
        """Add the sorted-path-rank row + path mirrors to every block so
        ``find``/``top_files``/``du`` serve from the resident mesh.
        Idempotent; the next refresh pays one full re-upload."""
        with self._lock:
            if self._plane_reports:
                return
            self._plane_reports = True
            self._drop_device_state()

    def enable_cube_plane(self, groups, clock) -> None:
        """Add the gid/size-bucket/age-bucket rows plus the per-device
        partial profile cubes. ``groups`` is the shared
        :class:`~repro.core.profiles.GroupIndex` (report masks read its
        key columns) and ``clock`` supplies the age reference. Idempotent
        for the same index; a different index raises."""
        with self._lock:
            if self._plane_cube:
                if groups is not self._cube_groups:
                    raise PolicyError(
                        "cube plane already enabled with a different "
                        "GroupIndex")
                return
            self._plane_cube = True
            self._cube_groups = groups
            self._cube_clock = clock
            self._cube_ref = float(clock())
            self._drop_device_state()

    def enable_permissions_plane(self, grants) -> None:
        """Add the per-subject packed visibility bitsets (multi-tenant
        ``subject=`` scoping). ``grants`` is the shared
        :class:`~repro.core.grants.GrantTable`; subtree grants resolve
        through the sorted-path mirrors, so this forces the reports plane
        on. Idempotent for the same table; a different table raises."""
        with self._lock:
            if self._plane_perm:
                if grants is not self._grants:
                    raise PolicyError(
                        "permissions plane already enabled with a "
                        "different GrantTable")
                return
            if self.tile % 32:
                raise PolicyError(
                    "permissions plane packs rows into uint32 words; the "
                    f"block tile must be a multiple of 32, got {self.tile}")
            self._plane_perm = True
            self._grants = grants
            self._grants_version = -1
            self._plane_reports = True
            self._drop_device_state()

    def detach(self) -> None:
        """Unregister from the catalog's delta hooks and drop the device
        blocks. A store that is replaced (mesh resize, re-attach) must be
        detached, or the long-lived catalog keeps feeding its dirty sets
        forever. A detached store can still match, but without delta
        intake every refresh is a cold full upload (the hook-less
        version-drift fallback) — detach is for decommissioning."""
        self.catalog.remove_delta_hook(self._on_delta)
        with self._lock:
            self._drop_device_state()
            for group in self._groups:
                group.dirty = set()
                group.structural = False
                group.fids = np.zeros(0, np.int64)
                group.cols = {}
                group.rows = 0
                group.offsets = np.zeros(1, np.int64)
                group.paths = group.spaths = group.ord = None
                group.cgid = group.csb = group.cab = group.cflip = None
                group.cmin_flip = np.inf
                group.vis = None
            self._rp = 0

    # -- delta intake (catalog mutation hooks) --------------------------------
    def _on_delta(self, old: Optional[Delta], new: Optional[Delta]) -> None:
        ref = new if new is not None else old
        if ref is None:
            return
        fid = int(ref[0])
        group = self._groups[self.catalog._shard_id(fid) % self.n_devices]
        if old is None or new is None:      # insert / remove: rows shift
            group.structural = True
        else:
            group.dirty.add(fid)

    # -- freshness ------------------------------------------------------------
    def _shard_versions(self, group: _ShardGroup) -> Dict[int, int]:
        return {s: self.catalog.shards[s].version for s in group.shard_ids}

    def _stale(self, group: _ShardGroup) -> bool:
        if not group.uploaded or group.structural or group.dirty:
            return True
        return self._shard_versions(group) != group.versions

    # -- upload paths ----------------------------------------------------------
    def _snapshot_group(self, group: _ShardGroup
                        ) -> Tuple[Dict[str, int], np.ndarray,
                                   Dict[str, np.ndarray], list, np.ndarray]:
        """(versions-before, fids, native column dict, paths, offsets)
        for a full upload. Paths are gathered only when the reports plane
        is on; ``offsets`` records each member shard's row start (the
        group's row order is the concat of member-shard snapshots, so
        results re-emit in catalog ``arrays()`` order through it)."""
        versions = self._shard_versions(group)   # BEFORE the snapshot reads
        names = ("fid",) + KERNEL_COLUMNS
        with_paths = self._plane_reports
        parts, paths, counts = [], [], []
        for s in group.shard_ids:
            cols_s, snap = self.catalog.shards[s].snapshot(
                names=names, with_strings=with_paths)
            parts.append(cols_s)
            counts.append(cols_s["fid"].size)
            if with_paths:
                paths.extend(snap.gather("_paths"))
        if parts:
            cols = {n: np.concatenate([p[n] for p in parts]) for n in names}
        else:
            cols = {n: np.zeros(0, dtype=np.int64) for n in names}
        # fid stays IN the mirror dict (it is a valid plan sort key)
        cols["fid"] = fids = cols["fid"].astype(np.int64, copy=False)
        offsets = np.concatenate([[0], np.cumsum(np.asarray(counts,
                                                            np.int64))])
        return versions, fids, cols, paths, offsets

    def _refresh_plane_mirrors(self, group: _ShardGroup,
                               paths: list) -> None:
        """Recompute a group's analytics mirrors after a full snapshot."""
        n = group.rows
        if self._plane_reports:
            group.paths = paths
            parr = np.asarray(paths) if paths else np.zeros(0, dtype="<U1")
            order = np.argsort(parr, kind="stable")
            group.spaths = parr[order]
            rank = np.empty(n, np.int64)
            rank[order] = np.arange(n)
            group.ord = rank
        if self._plane_cube:
            from .profiles import (_FLIP_EDGES, age_buckets_np,
                                   size_buckets_np)
            cols = group.cols
            group.cgid = self._cube_groups.get_or_add_many(
                cols["owner"], cols["group"], cols["type"],
                cols["hsm_state"])
            group.csb = size_buckets_np(np.asarray(cols["size"], np.int64))
            stamps = np.asarray(cols["atime"], np.float64)
            group.cab = age_buckets_np(self._cube_ref - stamps)
            group.cflip = stamps + _FLIP_EDGES[group.cab]
            finite = np.isfinite(group.cflip)
            group.cmin_flip = float(group.cflip[finite].min()) \
                if finite.any() else np.inf

    def _stack_f32(self, group: _ShardGroup, rp: int) -> np.ndarray:
        """(n_rows, rp) f32 device-block staging from the host mirror."""
        out = np.zeros((self._block_rows(), rp), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            out[i, : group.rows] = group.cols[name]
        out[_VALID_COL, : group.rows] = 1.0
        if self._plane_reports and group.ord is not None:
            out[_ORD_COL, : group.rows] = group.ord
        if self._plane_cube and group.cgid is not None:
            out[_GID_COL, : group.rows] = group.cgid
            out[_SB_COL, : group.rows] = group.csb
            out[_AB_COL, : group.rows] = group.cab
        return out

    def _full_upload(self, group: _ShardGroup, rp: int) -> None:
        import jax
        versions, fids, cols, paths, offsets = self._snapshot_group(group)
        if fids.size > rp:
            # a concurrent insert grew the group past the capacity check
            # at the top of refresh(): re-pad and retry instead of serving
            # a truncated block (or crashing the stack staging)
            raise _RepadNeeded(fids.size)
        group.fids, group.cols, group.rows = fids, cols, fids.size
        group._order = None
        group.offsets = offsets
        self._refresh_plane_mirrors(group, paths)
        stack = self._stack_f32(group, rp)
        self._bufs[group.gid] = jax.device_put(
            stack[None], self.devices[group.gid])
        group.versions = versions
        group.dirty = set()
        group.structural = False
        group.uploaded = True
        self._global = None
        self._epoch += 1
        self.full_uploads += 1
        if self._plane_perm:
            # row positions changed: the group's resident bitset indexes
            # stale local rows — re-materialize on the next scoped query
            group.vis = None
            if self._perm_bufs is not None:
                self._perm_bufs[group.gid] = None
            self._perm_global = None
        if self._plane_cube:
            # row positions changed: this group's resident partial cube
            # no longer matches the block — rebuild on next cube query
            self._cube_stale = True
            self._cube_cache = None

    def _delta_refresh(self, group: _ShardGroup) -> bool:
        """Scatter just the dirty rows into the resident block; returns
        False when the group needs the full-upload fallback instead."""
        # swap the dirty set out BEFORE reading versions: a hook landing
        # after the swap goes to the fresh set and keeps the group stale
        # (re-scattered next refresh), so a concurrent mutation can delay
        # a row's upload by one refresh but never lose it — and the
        # fromiter below never races a growing set
        dirty_set, group.dirty = group.dirty, set()
        versions = self._shard_versions(group)   # BEFORE the row gather
        dirty = np.fromiter(dirty_set, dtype=np.int64, count=len(dirty_set))
        rows = group.locate(dirty)
        if rows is None:
            group.dirty |= dirty_set
            return False                    # unseen fid: rows shifted
        cols, present = self.catalog.gather_rows(
            dirty.tolist(), with_strings=self._plane_reports)
        if not bool(present.all()):
            group.dirty |= dirty_set
            return False                    # raced a remove: restack
        if self._plane_reports:
            # a rename shifts the group's sorted-path order (every rank
            # after the move changes): degrade to a full re-upload, the
            # same fallback as a structural change
            if any(group.paths[r] != p
                   for r, p in zip(rows.tolist(), cols["_paths"])):
                group.dirty |= dirty_set
                group.structural = True
                return False
        cube_live = (self._plane_cube and self._cube_bufs is not None
                     and not self._cube_stale)
        if cube_live:
            # capture the OLD cube cells before the mirror updates — the
            # signed move subtracts exactly what the resident cube holds
            old_cells = (group.cgid[rows].copy(), group.csb[rows].copy(),
                         group.cab[rows].copy(),
                         np.asarray(group.cols["size"][rows], np.float32),
                         np.asarray(group.cols["blocks"][rows], np.float32))
        vals = np.zeros((self._block_rows(), dirty.size), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            group.cols[name][rows] = cols[name]      # host mirror first
            vals[i] = cols[name]
        vals[_VALID_COL] = 1.0               # pure updates: rows stay valid
        if self._plane_reports:
            vals[_ORD_COL] = group.ord[rows]  # paths unchanged: ranks stay
        if self._plane_cube:
            from .profiles import (_FLIP_EDGES, age_buckets_np,
                                   size_buckets_np)
            ngid = self._cube_groups.get_or_add_many(
                cols["owner"], cols["group"], cols["type"],
                cols["hsm_state"])
            nsb = size_buckets_np(np.asarray(cols["size"], np.int64))
            stamps = np.asarray(cols["atime"], np.float64)
            nab = age_buckets_np(self._cube_ref - stamps)
            nflip = stamps + _FLIP_EDGES[nab]
            group.cgid[rows] = ngid
            group.csb[rows] = nsb
            group.cab[rows] = nab
            group.cflip[rows] = nflip
            finite = np.isfinite(nflip)
            if finite.any():
                group.cmin_flip = min(group.cmin_flip,
                                      float(nflip[finite].min()))
            vals[_GID_COL] = ngid
            vals[_SB_COL] = nsb
            vals[_AB_COL] = nab
        # release the assembled global BEFORE the scatter: it holds the
        # only other reference to the block, which must drop for the
        # donated in-place update to actually donate
        self._global = None
        # the scatter runs on the block's own device (donated buffer); the
        # validity row is re-asserted to 1 (pure updates never change
        # which rows exist) and the op is bucket-padded for executable
        # reuse
        prows, pvals = _pad_bucket(rows.astype(np.int32), vals)
        self._bufs[group.gid] = _scatter_rows(self._bufs[group.gid],
                                              prows, pvals)
        if self._plane_cube and cube_live:
            if len(self._cube_groups) > self._cube_bp:
                # a delta minted more groups than the partials can hold:
                # full cube rebuild on the next query
                self._cube_stale = True
                self._cube_cache = None
            else:
                ogid, osb, oab, osize, oblocks = old_cells
                from .profiles import A as _A, S as _S
                flat = np.concatenate([
                    (ogid * _S + osb) * _A + oab,
                    (ngid * _S + nsb) * _A + nab]).astype(np.int32)
                ones = np.ones(dirty.size, np.float32)
                cvals = np.stack([
                    np.concatenate([-ones, ones]),
                    np.concatenate([-osize,
                                    np.asarray(cols["size"], np.float32)]),
                    np.concatenate([-oblocks,
                                    np.asarray(cols["blocks"],
                                               np.float32)])])
                # drop the assembled partials (same donation discipline
                # as the column global above)
                self._cube_partials = None
                self._cube_cache = None
                pflat, pcvals = _pad_zero(flat, cvals)
                self._cube_bufs[group.gid] = _cube_scatter(
                    self._cube_bufs[group.gid], pflat, pcvals)
        if self._plane_perm:
            perm_live = (group.vis is not None
                         and self._perm_bufs is not None
                         and self._perm_bufs[group.gid] is not None
                         and self._grants.version == self._grants_version)
            if perm_live:
                # pure updates keep row positions and paths, so only the
                # ownership grants of the dirty rows can flip: re-derive
                # just those rows' visibility and scatter the changed
                # packed words (scatter-SET, idempotent under dup pad)
                nvis = self._vis_rows(
                    group, np.asarray(cols["owner"], np.int64),
                    np.asarray(cols["group"], np.int64), group.ord[rows])
                if not np.array_equal(nvis, group.vis[:, rows]):
                    group.vis[:, rows] = nvis
                    words = np.unique(rows // 32)
                    wvals = self._pack_words(group, words)
                    self._perm_global = None
                    pw, pv = _pad_bucket(words.astype(np.int32), wvals)
                    self._perm_bufs[group.gid] = _scatter_rows(
                        self._perm_bufs[group.gid], pw, pv)
                    self.perm_word_scatters += 1
            else:
                # grants ticked (or the bitset never materialized): a
                # row-granular patch could miss a new subject's row —
                # drop the group's bitset, rebuilt on the next scoped
                # query by _ensure_perms
                group.vis = None
        group.versions = versions
        self._epoch += 1
        self.delta_refreshes += 1
        self.rows_scattered += int(dirty.size)
        return True

    def _round_up(self, n: int) -> int:
        return -(-max(n, 1) // self.tile) * self.tile

    def refresh(self) -> Dict[str, int]:
        """Bring every stale shard group up to date; returns counters of
        the refresh modes taken (``full``/``delta``/``fresh`` groups)."""
        with self._lock:
            stats = {"full": 0, "delta": 0, "fresh": 0}
            stale = [g for g in self._groups if self._stale(g)]
            stats["fresh"] = self.n_devices - len(stale)
            if not stale:
                return stats
            # a grown group forces a global re-pad: every block re-uploads
            # at the new Rp so the global array stays rectangular
            need = max((sum(self.catalog.shards[s].count()
                            for s in g.shard_ids) for g in self._groups),
                       default=1)
            repad = need > self._rp or self._rp == 0
            if repad:
                self._rp = self._round_up(int(need * self.headroom))
            # bounded retry: a concurrent insert can outgrow the capacity
            # check mid-refresh (_full_upload raises _RepadNeeded) — re-pad
            # and re-upload everything rather than serve a truncated block
            for _attempt in range(8):
                if repad:
                    stale = list(self._groups)
                    stats = {"full": 0, "delta": 0, "fresh": 0}
                try:
                    for group in stale:
                        churn_ok = (not repad and group.uploaded
                                    and not group.structural and group.dirty
                                    and len(group.dirty)
                                    <= self.refresh_frac
                                    * max(1, group.rows))
                        if churn_ok and self._delta_refresh(group):
                            stats["delta"] += 1
                        else:
                            self._full_upload(group, self._rp)
                            stats["full"] += 1
                    return stats
                except _RepadNeeded as grown:
                    self._rp = self._round_up(
                        int(grown.rows * self.headroom))
                    repad = True
            raise PolicyError(
                "device store could not settle a refresh: the catalog "
                "grew on every re-pad attempt")

    # -- permissions plane (per-subject packed visibility bitsets) -------------
    def _require_permissions_plane(self) -> None:
        if not self._plane_perm:
            raise PolicyError(
                "permissions plane not enabled "
                "(DeviceColumnStore.enable_permissions_plane)")

    def _subject_id(self, subject: str) -> int:
        # unknown subjects raise KeyError, NOT PolicyError: a host
        # fallback would fail identically, so degrading serves nothing
        return int(self._grants.subject_id(subject))

    def _vis_rows(self, group: _ShardGroup, owner: np.ndarray,
                  grp: np.ndarray, rank: np.ndarray) -> np.ndarray:
        """(Sp, k) bool visibility of k group rows (given their interned
        owner/group codes and sorted-path ranks) for every registered
        subject — rows past the registry stay all-False pad. Mirrors
        :meth:`GrantTable.visible_mask` exactly: ownership via code
        membership, subtrees via the same rank-range searches ``du``
        uses on the sorted-path mirror. Lock held."""
        strings = self.catalog.strings
        subjects = self._grants.subjects()
        out = np.zeros((self._perm_sp, owner.size), dtype=bool)
        sp = group.spaths if group.spaths is not None \
            else np.zeros(0, dtype="<U1")
        for sid, s in enumerate(subjects):
            v = out[sid]
            ocodes = [c for c in (strings.code_of(u) for u in s.owners)
                      if c is not None]
            if ocodes:
                v |= np.isin(owner, ocodes)
            gcodes = [c for c in (strings.code_of(g) for g in s.groups)
                      if c is not None]
            if gcodes:
                v |= np.isin(grp, gcodes)
            for pref in s.subtrees:
                lo = np.searchsorted(sp, pref + "/", side="left")
                hi = np.searchsorted(sp, pref + "0", side="left")
                lo2 = np.searchsorted(sp, pref, side="left")
                hi2 = np.searchsorted(sp, pref, side="right")
                v |= ((rank >= lo) & (rank < hi)) \
                    | ((rank >= lo2) & (rank < hi2))
        return out

    def _pack_group(self, group: _ShardGroup) -> np.ndarray:
        """Pack a group's full (Sp, rows) visibility into the (Sp, Rp/32)
        uint32 bit layout: bit b of word w (LSB first) = local row
        w*32+b; pad rows read 0 (invisible, like the validity row)."""
        full = np.zeros((self._perm_sp, self._rp), dtype=bool)
        if group.rows:
            full[:, : group.rows] = group.vis
        return np.packbits(full, axis=1,
                           bitorder="little").view(np.uint32)

    def _pack_words(self, group: _ShardGroup,
                    words: np.ndarray) -> np.ndarray:
        """(Sp, k) packed uint32 values of k whole words re-read from the
        group's visibility mirror (rows past ``group.rows`` pack to 0) —
        the warm-scatter payload after a dirty-row visibility change."""
        rows = (words[:, None] * 32 + np.arange(32)).reshape(-1)
        sub = np.zeros((self._perm_sp, rows.size), dtype=bool)
        inside = rows < group.rows
        sub[:, inside] = group.vis[:, rows[inside]]
        return np.packbits(sub, axis=1, bitorder="little").view(np.uint32)

    def _ensure_perms(self) -> None:
        """Materialize / refresh the resident bitsets. Lock held; call
        AFTER :meth:`refresh` (full uploads invalidate group bitsets).
        Any :attr:`GrantTable.version` tick or subject-capacity overflow
        re-materializes every group; otherwise only groups whose bitset
        was invalidated (structural churn, re-pad) rebuild."""
        import jax
        g = self._grants
        if (g.version != self._grants_version or self._perm_bufs is None
                or len(g) > self._perm_sp):
            # subject axis padded like the group axis of the cube plane:
            # headroom + sublane multiple, so new subjects keep landing
            # without an immediate re-materialization
            self._perm_sp = max(
                -(-int(max(len(g), 1) * self.headroom) // 8) * 8, 8)
            self._grants_version = g.version
            self._perm_bufs = [None] * self.n_devices
            self._perm_global = None
            for group in self._groups:
                group.vis = None
        changed = False
        for group in self._groups:
            if group.vis is not None \
                    and self._perm_bufs[group.gid] is not None:
                continue
            if group.rows:
                owner = np.asarray(group.cols["owner"], np.int64)
                grp = np.asarray(group.cols["group"], np.int64)
                rank = group.ord
            else:
                owner = grp = np.zeros(0, np.int64)
                rank = np.zeros(0, np.int64)
            group.vis = self._vis_rows(group, owner, grp, rank)
            self._perm_bufs[group.gid] = jax.device_put(
                self._pack_group(group)[None], self.devices[group.gid])
            self.perm_materializations += 1
            changed = True
        if changed:
            self._perm_global = None
            self._epoch += 1

    def _assemble_perm(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self._perm_global is None:
            shape = (self.n_devices, self._perm_sp, self._rp // 32)
            self._perm_global = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(self.mesh, P("shards")),
                self._perm_bufs)
        return self._perm_global

    def _resolve_subject(self, subject: Optional[str]):
        """(perm array, traced subject id) for a scoped query, or
        (None, None) unscoped. Lock held, AFTER refresh()."""
        if subject is None:
            return None, None
        self._require_permissions_plane()
        self._ensure_perms()
        sid = np.int32(self._subject_id(subject))
        return self._assemble_perm(), sid

    # -- matching --------------------------------------------------------------
    def _assemble(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self._global is None:
            shape = (self.n_devices, self._block_rows(), self._rp)
            self._global = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(self.mesh, P("shards")), self._bufs)
        return self._global

    def match(self, exprs: Sequence, now: float,
              use_kernel: Optional[bool] = None,
              with_agg: bool = True,
              subject: Optional[str] = None) -> MeshMatch:
        """Evaluate ``[combined criteria] + per-rule conditions`` over the
        resident mesh; see :class:`MeshMatch`. Raises PolicyError on glob
        (host-only) predicates — callers fall back to the numpy path.
        ``with_agg=False`` skips the fused size-profile aggregation (the
        engine's match path needs only mask + attribution; ``.agg`` then
        reads all-zero). ``subject=`` ANDs that subject's permission
        bitset into the match (permissions plane required)."""
        # the lock is held for the WHOLE match (launch included): a
        # concurrent refresh would donate the resident blocks out from
        # under the in-flight launch and mutate the host mirrors this
        # match translates through — concurrent matches serialize instead
        with self._lock:
            return self._match_locked(exprs, now, use_kernel, with_agg,
                                      subject)

    def _match_locked(self, exprs: Sequence, now: float,
                      use_kernel: Optional[bool] = None,
                      with_agg: bool = True,
                      subject: Optional[str] = None) -> MeshMatch:
        import jax
        from ..kernels.policy_scan.ops import (_agg_dict, _on_tpu,
                                               _program_tuples,
                                               mesh_policy_scan_batch)
        ops, colidx, operands = compile_programs(exprs, self.catalog.strings,
                                                 now)
        ops_t, colidx_t = _program_tuples(ops, colidx)
        if use_kernel is None:
            use_kernel = _on_tpu()
        self.refresh()
        perm, sid = self._resolve_subject(subject)
        global_cols = self._assemble()
        snap = [(g.gid, g.fids, g.cols, g.rows) for g in self._groups]
        mask, rule, agg = mesh_policy_scan_batch(
            global_cols, operands, mesh=self.mesh, ops_t=ops_t,
            colidx_t=colidx_t, size_col=KERNEL_COLUMNS.index("size"),
            blocks_col=KERNEL_COLUMNS.index("blocks"),
            valid_col=_VALID_COL, use_kernel=bool(use_kernel),
            tile=self.tile, with_agg=with_agg, perm=perm, subject=sid)
        # only mask + attribution cross device→host, never the columns
        mask_np = np.asarray(jax.device_get(mask))
        rule_np = np.asarray(jax.device_get(rule))
        per_rule = np.asarray(jax.device_get(agg))
        mirrors, group_idx, group_rule = [], [], []
        for gid, gfids, gcols, grows in snap:
            idx = np.nonzero(mask_np[gid, :grows] > 0.5)[0]
            mirrors.append((gfids, gcols))
            group_idx.append(idx)
            group_rule.append(rule_np[gid, idx].astype(np.int32))
        reval = int(sum(s[3] for s in snap))
        return MeshMatch(self, self._epoch, mirrors, group_idx,
                         group_rule, _agg_dict(per_rule[0], per_rule),
                         reval)

    def scan(self, expr, now: float,
             use_kernel: Optional[bool] = None) -> Tuple[np.ndarray, dict]:
        """Single-expression mesh scan: (matching fids, aggregate dict) —
        the device-resident analogue of ``ops.scan_catalog``."""
        match = self.match([expr], now, use_kernel=use_kernel)
        fids, _sizes, _sort, _ridx = match.plan("size")
        return fids, match.agg

    # -- resident profile cube -------------------------------------------------
    def _assemble_cube(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..kernels.profile_cube.ref import (A_BUCKETS, N_MEASURES,
                                                S_BUCKETS)
        if self._cube_partials is None:
            shape = (self.n_devices, N_MEASURES,
                     self._cube_bp * S_BUCKETS * A_BUCKETS)
            self._cube_partials = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(self.mesh, P("shards")),
                self._cube_bufs)
        return self._cube_partials

    def _advance_cube_ref(self, now: float,
                          update_partials: bool = True) -> int:
        """Advance the age reference: re-bucket only the rows whose next
        flip instant passed (block ``_AB_COL`` scatter + mirror update;
        when the partials are live, a signed cube move too). Mirrors
        ``core.profiles._ShardCube.sweep``. Lock held."""
        if now <= self._cube_ref:
            return 0
        from .profiles import _FLIP_EDGES, age_buckets_np, A as _A, S as _S
        moved = 0
        for group in self._groups:
            if not group.rows or group.cflip is None \
                    or group.cmin_flip > now:
                continue
            due = np.nonzero(group.cflip <= now)[0]
            if due.size:
                stamps = np.asarray(group.cols["atime"][due], np.float64)
                new_ab = age_buckets_np(now - stamps)
                if update_partials and self._cube_bufs is not None \
                        and not self._cube_stale:
                    gid, sb = group.cgid[due], group.csb[due]
                    flat = np.concatenate([
                        (gid * _S + sb) * _A + group.cab[due],
                        (gid * _S + sb) * _A + new_ab]).astype(np.int32)
                    ones = np.ones(due.size, np.float32)
                    size = np.asarray(group.cols["size"][due], np.float32)
                    blocks = np.asarray(group.cols["blocks"][due],
                                        np.float32)
                    cvals = np.stack([
                        np.concatenate([-ones, ones]),
                        np.concatenate([-size, size]),
                        np.concatenate([-blocks, blocks])])
                    self._cube_partials = None
                    self._cube_cache = None
                    pflat, pcvals = _pad_zero(flat, cvals)
                    self._cube_bufs[group.gid] = _cube_scatter(
                        self._cube_bufs[group.gid], pflat, pcvals)
                group.cab[due] = new_ab
                group.cflip[due] = stamps + _FLIP_EDGES[new_ab]
                # scatter the new age buckets into the resident block so a
                # later full cube rebuild reads current codes
                self._global = None
                prows, pvals = _pad_bucket(
                    due.astype(np.int32),
                    new_ab[None].astype(np.float32))
                self._bufs[group.gid] = _scatter_row(
                    self._bufs[group.gid], _AB_COL, prows, pvals[0])
                moved += int(due.size)
            finite = np.isfinite(group.cflip)
            group.cmin_flip = float(group.cflip[finite].min()) \
                if finite.any() else np.inf
        self._cube_ref = now
        self.rollovers += moved
        return moved

    def _rebuild_cube(self, now: float) -> None:
        """Cold/fallback path: one ``mesh_profile_cube`` launch rebuilds
        every device's partial from its resident block. Lock held; blocks
        must be fresh (call after :meth:`refresh`)."""
        import jax
        from ..kernels.profile_cube.ops import mesh_profile_cube
        self._advance_cube_ref(now, update_partials=False)
        b = max(len(self._cube_groups), 1)
        # group-axis capacity: headroom + f32 sublane multiple, so newly
        # minted groups keep scatter-adding without an immediate rebuild
        self._cube_bp = max(-(-int(b * self.headroom) // 8) * 8, 8)
        partials, combined = mesh_profile_cube(
            self._assemble(), mesh=self.mesh, n_groups=self._cube_bp,
            gid_col=_GID_COL, size_col=KERNEL_COLUMNS.index("size"),
            blocks_col=KERNEL_COLUMNS.index("blocks"), sb_col=_SB_COL,
            ab_col=_AB_COL, valid_col=_VALID_COL, use_kernel=False,
            tile=self.tile)
        by_dev = {s.device: s.data for s in partials.addressable_shards}
        self._cube_bufs = [by_dev[d] for d in self.devices]
        self._cube_partials = partials
        self._cube_cache = np.rint(
            np.asarray(jax.device_get(combined))).astype(np.int64)
        self._cube_stale = False
        self.cube_rebuilds += 1

    def _ensure_cube(self, now: float) -> None:
        if not self._plane_cube:
            raise PolicyError("cube plane not enabled "
                              "(DeviceColumnStore.enable_cube_plane)")
        if (self._cube_bufs is None or self._cube_stale
                or len(self._cube_groups) > self._cube_bp):
            self._rebuild_cube(now)
        else:
            self._advance_cube_ref(now, update_partials=True)

    def invalidate_cube(self) -> None:
        """Force a full on-device cube rebuild on the next query (the
        store-backed analogue of ``ProfileCube.rebuild``)."""
        with self._lock:
            self._cube_stale = True
            self._cube_cache = None

    def analytics_cube(self, now: Optional[float] = None,
                       subject: Optional[str] = None) -> np.ndarray:
        """Merged (N_MEASURES, B, S, A) int64 cube as of ``now``, served
        from the resident partials: refresh scatters churned rows, due
        age rollovers move on-device, and the only cross-device traffic
        is the psum of the partial cubes. ``subject=`` bins only rows
        that subject may see — one fused :func:`mesh_scoped_cube` launch
        over the resident block + bitsets (no resident scoped partials;
        the rollover advance above keeps the block's age codes exact as
        of ``now``, so the scoped cube matches the host oracle)."""
        import jax
        from ..kernels.profile_cube.ops import mesh_cube_combine
        from ..kernels.profile_cube.ref import (A_BUCKETS, N_MEASURES,
                                                S_BUCKETS)
        with self._lock:
            if not self._plane_cube:
                raise PolicyError("cube plane not enabled "
                                  "(DeviceColumnStore.enable_cube_plane)")
            now = float(self._cube_clock()) if now is None else float(now)
            self.refresh()
            self._ensure_cube(now)
            self.store_queries += 1
            if subject is not None:
                from ..kernels.profile_cube.ops import mesh_scoped_cube
                self._require_permissions_plane()
                self._ensure_perms()
                sid = np.int32(self._subject_id(subject))
                cube = mesh_scoped_cube(
                    self._assemble(), self._assemble_perm(), sid,
                    mesh=self.mesh, n_groups=self._cube_bp,
                    gid_col=_GID_COL,
                    size_col=KERNEL_COLUMNS.index("size"),
                    blocks_col=KERNEL_COLUMNS.index("blocks"),
                    sb_col=_SB_COL, ab_col=_AB_COL, valid_col=_VALID_COL)
                b = min(len(self._cube_groups), self._cube_bp)
                return np.rint(np.asarray(jax.device_get(cube))).astype(
                    np.int64)[:, :b]
            if self._cube_cache is None:
                combined = mesh_cube_combine(self._assemble_cube(),
                                             mesh=self.mesh)
                self._cube_cache = np.rint(
                    np.asarray(jax.device_get(combined))).astype(
                        np.int64).reshape(N_MEASURES, self._cube_bp,
                                          S_BUCKETS, A_BUCKETS)
            b = min(len(self._cube_groups), self._cube_bp)
            return self._cube_cache[:, :b]

    # -- resident report queries (rbh-find / top-N / rbh-du) -------------------
    def _require_reports_plane(self) -> None:
        if not self._plane_reports:
            raise PolicyError("reports plane not enabled "
                              "(DeviceColumnStore.enable_reports_plane)")

    def _arrays_positions(self, group: _ShardGroup,
                          idx: np.ndarray) -> np.ndarray:
        """Map group-local row indices to catalog ``arrays()`` positions
        (the host oracle's row order) for tie-exact result ordering."""
        counts = {}
        for g in self._groups:
            for p, sid in enumerate(g.shard_ids):
                counts[sid] = int(g.offsets[p + 1] - g.offsets[p])
        base = np.concatenate(
            [[0], np.cumsum([counts.get(s, 0)
                             for s in range(self.catalog.n_shards)])])
        seg = np.searchsorted(group.offsets, idx, side="right") - 1
        sids = np.asarray(group.shard_ids, np.int64)[seg]
        return base[sids] + (idx - group.offsets[seg])

    def find_paths(self, expr, now: float, limit: int = 0,
                   subject: Optional[str] = None) -> List[str]:
        """``rbh-find`` from the resident mesh: one program match, then
        winning rows translate to paths through the host path mirrors —
        emitted in catalog ``arrays()`` order (byte-identical to the host
        fold). Raises PolicyError on glob predicates (host fallback).
        ``subject=`` lists only rows that subject may see."""
        with self._lock:
            self._require_reports_plane()
            match = self._match_locked([expr], now, with_agg=False,
                                       subject=subject)
            self.store_queries += 1
            out: List[str] = []
            for sid in range(self.catalog.n_shards):
                group = self._groups[sid % self.n_devices]
                p = sid // self.n_devices
                lo = int(group.offsets[p])
                hi = int(group.offsets[p + 1])
                idx = match._group_idx[group.gid]
                seg = idx[(idx >= lo) & (idx < hi)]
                out.extend(group.paths[i] for i in seg.tolist())
                if limit and len(out) >= limit:
                    return out[:limit]
            return out

    def top_files(self, by: str = "size", k: int = 10, desc: bool = True,
                  now: float = 0.0,
                  subject: Optional[str] = None) -> List[dict]:
        """Top-N listing from the resident mesh, two passes: per-device
        top-k finds the exact global k-th-best value (the union of
        per-device top-k's contains the global top-k), then a threshold
        mask recovers every candidate incl. cross-device ties; the final
        order sorts candidates by native mirror values with the host
        oracle's exact tie semantics (stable argsort + reversal)."""
        import jax
        from .types import FsType
        from ..kernels.policy_scan.ops import (mesh_column_topk,
                                               mesh_threshold_rows)
        if by not in KERNEL_COLUMNS:
            raise PolicyError(f"top_files by {by!r} is not a kernel column")
        with self._lock:
            self._require_reports_plane()
            self.refresh()
            self.store_queries += 1
            if k <= 0 or not any(g.rows for g in self._groups):
                return []
            perm, sid = self._resolve_subject(subject)
            global_cols = self._assemble()
            col = KERNEL_COLUMNS.index(by)
            type_col = KERNEL_COLUMNS.index("type")
            file_code = float(int(FsType.FILE))
            kd = min(k, self._rp)
            vals, _idx = mesh_column_topk(
                global_cols, mesh=self.mesh, col=col, k=kd, desc=desc,
                valid_col=_VALID_COL, type_col=type_col,
                file_code=file_code, perm=perm, subject=sid)
            merged = np.asarray(jax.device_get(vals)).ravel()
            merged = merged[np.isfinite(merged)]
            if merged.size == 0:
                return []
            merged.sort()                     # ascending
            kk = min(k, merged.size)
            thr = float(merged[-kk] if desc else merged[kk - 1])
            mask = mesh_threshold_rows(
                global_cols, thr, mesh=self.mesh, col=col, ge=desc,
                valid_col=_VALID_COL, type_col=type_col,
                file_code=file_code, perm=perm, subject=sid)
            mask_np = np.asarray(jax.device_get(mask))
            cand_vals, cand_pos, cand_paths, cand_fids = [], [], [], []
            for group in self._groups:
                rows = np.nonzero(mask_np[group.gid, :group.rows] > 0.5)[0]
                if not rows.size:
                    continue
                cand_vals.append(group.cols[by][rows])
                cand_pos.append(self._arrays_positions(group, rows))
                cand_fids.append(group.fids[rows])
                cand_paths.extend(group.paths[i] for i in rows.tolist())
            values = np.concatenate(cand_vals)
            pos = np.concatenate(cand_pos)
            fids = np.concatenate(cand_fids)
            # host tie semantics: stable ascending argsort (ties by
            # arrays position), reversed wholesale for descending
            order = np.lexsort((pos, values))
            order = order[::-1][:kk] if desc else order[:kk]
            return [{"path": cand_paths[o], by: float(values[o]),
                     "fid": int(fids[o])} for o in order.tolist()]

    def du(self, path_prefix: str, subject: Optional[str] = None) -> dict:
        """``rbh-du -s`` from the resident mesh: two host binary searches
        per group into the sorted path mirror produce rank bounds; one
        fused on-device range aggregate psum-combines
        [count, files, volume, spc_used] — no row leaves a device.
        ``subject=`` counts only rows that subject may see."""
        import jax
        from .types import FsType
        from ..kernels.policy_scan.ops import mesh_range_aggregate
        with self._lock:
            self._require_reports_plane()
            self.refresh()
            self.store_queries += 1
            perm, sid = self._resolve_subject(subject)
            prefix = path_prefix.rstrip("/")
            bounds = np.zeros((self.n_devices, 4), np.float32)
            for group in self._groups:
                sp = group.spaths if group.spaths is not None \
                    else np.zeros(0, dtype="<U1")
                bounds[group.gid] = (
                    np.searchsorted(sp, prefix + "/", side="left"),
                    np.searchsorted(sp, prefix + "0", side="left"),
                    np.searchsorted(sp, prefix, side="left"),
                    np.searchsorted(sp, prefix, side="right"))
            agg = mesh_range_aggregate(
                self._assemble(), bounds, mesh=self.mesh,
                ord_col=_ORD_COL, type_col=KERNEL_COLUMNS.index("type"),
                size_col=KERNEL_COLUMNS.index("size"),
                blocks_col=KERNEL_COLUMNS.index("blocks"),
                valid_col=_VALID_COL, file_code=float(int(FsType.FILE)),
                perm=perm, subject=sid)
            r = np.asarray(jax.device_get(agg))
            return {"count": int(round(float(r[0]))),
                    "files": int(round(float(r[1]))),
                    "volume": int(round(float(r[2]))),
                    "spc_used": int(round(float(r[3])))}
