"""Device-resident sharded column store for mesh-parallel policy matching.

The paper's core scaling claim (SII-B1, SIII-B) is that policy runs over
billions of entries must never re-read the namespace. The engine's kernel
path used to violate that in two ways every run: ``Catalog.arrays()``
concatenated every shard's columns on the host, and ``match_programs``
re-stacked and re-uploaded the full f32 column stack host→device — all of
it landing on ONE device even though the catalog is already sharded. This
module keeps the kernel's column stacks *resident* on a device mesh and
maintains them by deltas, so a warm policy run uploads only the rows that
actually churned.

Residency model
---------------
Catalog shards are folded onto the 1-D ``("shards",)`` mesh (see
``launch.mesh.make_shards_mesh``): shard ``s`` belongs to **shard group**
``s % D`` for a D-device mesh, and each group's rows (the concatenation of
its member shards' valid-row snapshots) live on exactly one device as an
``(n_cols+1, Rp)`` float32 block — ``KERNEL_COLUMNS`` in kernel order plus
a trailing 0/1 row-validity column. Every group is padded to the same
``Rp`` (a kernel-tile multiple, allocated with growth headroom) so the
per-device blocks assemble zero-copy into one global ``(D, n_cols+1, Rp)``
array sharded along ``"shards"`` — the operand
:func:`~repro.kernels.policy_scan.ops.mesh_policy_scan_batch` consumes
under ``shard_map``. Matching therefore moves **no column data at all**:
only the (R, P) programs go up, and only the program-0 mask, the
first-match-wins rule attribution, and the psum-combined (R, N_AGG)
aggregates come back.

Beside each device block the store keeps a **host mirror** of the group:
the row-aligned ``fid`` array plus every kernel column in its native dtype.
The mirror is what translates matched local row indices back to fids and
serves exact int64/float64 ``size``/sort-key values to the engine's
planner — it is maintained by the same deltas as the device block, so no
post-match catalog gather is needed.

Version keying and refresh
--------------------------
Freshness is keyed by the existing per-shard change ticks
(:attr:`CatalogShard.version`): a group is *stale* when any member shard's
tick moved past the value recorded at its last upload, or when delta hooks
flagged pending changes. The store registers a
:meth:`Catalog.add_delta_hook` at attach time and classifies every delta:

* in-place update (old and new both present)  -> the fid joins the group's
  **dirty set**; refresh scatters just those rows — one
  :meth:`Catalog.gather_rows` host gather, one vectorized
  ``block.at[:, rows].set(vals)`` on the owning device (row positions are
  stable under pure updates, so the scatter is exact);
* insert or remove (``old is None`` / ``new is None``) -> the group is
  flagged **structural** and falls back to a full re-upload (snapshot →
  restack → ``device_put``), because row positions shift;
* dirty set larger than ``refresh_frac`` of the group's rows -> full
  re-upload too (documented churn threshold: past it one contiguous upload
  beats that many scattered rows);
* shard tick moved with *no* recorded deltas (store attached late, hooks
  bypassed) -> full re-upload, never a stale serve.

Version ticks are read *before* the snapshot/gather (the catalog's own
``_bump`` discipline), so a racing mutation can only make the next refresh
redundant, never leave the device block stale. A group whose row count
outgrows ``Rp`` forces a global re-pad (all groups re-upload at the new
``Rp``).

f32 envelope
------------
Device blocks are float32, exactly like the single-device kernel path:
sizes above 2**24 bytes land on the nearest representable f32 (~one part
in 16M — entries within one ulp of a size cutoff may flip vs the int64
numpy path) and epoch-second timestamps carry ~64 s resolution. The host
mirror keeps native dtypes, so fids, budget sizes and sort keys returned
to the planner are exact; only predicate evaluation lives in the f32
envelope. Differential tests pin the envelope with f32-exact catalogs.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog import Catalog, Delta
from .policy import KERNEL_COLUMNS, PolicyError, compile_programs

_VALID_COL = len(KERNEL_COLUMNS)          # trailing 0/1 row-validity column

# columns the host mirror serves to the planner (fids + kernel columns);
# a policy sorting by anything else (e.g. parent_fid) cannot plan from the
# store and raises PolicyError -> the engine falls back to a host scan
PLAN_COLUMNS = ("fid",) + KERNEL_COLUMNS


class _RepadNeeded(Exception):
    """Internal: a group's snapshot outgrew the padded row capacity
    mid-refresh (concurrent inserts); refresh() re-pads and retries."""

    def __init__(self, rows: int) -> None:
        super().__init__(rows)
        self.rows = rows

_SCATTER_FN = None                        # lazily-jitted dirty-row scatter


def _scatter_rows(buf, rows: np.ndarray, vals: np.ndarray):
    """Scatter (C, k) dirty-row values into a resident (1, C+1, Rp) block.

    Jitted with the block donated (in-place on its own device) and k
    padded to power-of-two buckets by the caller, so XLA compiles one
    executable per (bucket, device) instead of one per distinct dirty-row
    count — the scatter itself is O(k), never O(Rp).
    """
    global _SCATTER_FN
    if _SCATTER_FN is None:
        import jax

        def fn(buf, rows, vals):
            return buf.at[0, : vals.shape[0], rows].set(vals.T)

        _SCATTER_FN = jax.jit(fn, donate_argnums=(0,))
    return _SCATTER_FN(buf, rows, vals)


def _pad_bucket(rows: np.ndarray, vals: np.ndarray, min_bucket: int = 64
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a scatter to the next power-of-two size with idempotent
    duplicates of row 0 (same index, same values -> deterministic)."""
    bucket = min_bucket
    while bucket < rows.size:
        bucket *= 2
    pad = bucket - rows.size
    if not pad:
        return rows, vals
    return (np.concatenate([rows, np.full(pad, rows[0], rows.dtype)]),
            np.concatenate([vals, np.repeat(vals[:, :1], pad, axis=1)],
                           axis=1))


class MeshMatch:
    """Result of one mesh-parallel program-batch evaluation.

    Holds the per-group matched local row indices (already nonzero'd on the
    host from the program-0 mask) plus the store's host mirrors; ``plan``
    gathers the planner arrays without touching the catalog. A delta
    refresh mutates the mirrors in place, so ``plan`` takes the store lock
    and raises :class:`PolicyError` when the store refreshed since this
    match (a stale plan would mix pre-churn masks with post-churn values)
    — call it before the next refresh, as the engine does.
    """

    def __init__(self, store: "DeviceColumnStore", epoch: int,
                 mirrors: List[Tuple[np.ndarray, Dict[str, np.ndarray]]],
                 group_idx: List[np.ndarray], group_rule: List[np.ndarray],
                 agg: dict, reval: int) -> None:
        self._store = store
        self._epoch = epoch                # store mutation tick at match
        self._mirrors = mirrors            # per group: (fids, cols) refs
        self._group_idx = group_idx        # per group: matched local rows
        self._group_rule = group_rule      # per group: rule idx at those rows
        self.agg = agg
        self.reval = reval                 # valid rows evaluated on-device

    @property
    def matched(self) -> int:
        return int(sum(ix.size for ix in self._group_idx))

    def plan(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """(fids, sizes, sort_keys, rule_idx) of matched rows, native
        dtypes from the host mirror (exact budgets/ordering)."""
        if sort_by not in PLAN_COLUMNS:
            raise PolicyError(
                f"sort_by {sort_by!r} is not in the device-store host "
                f"mirror (available: fid + kernel columns)")
        with self._store._lock:
            if self._store._epoch != self._epoch:
                raise PolicyError(
                    "stale MeshMatch: the device store refreshed since "
                    "this match — re-match before planning")
            return self._plan_locked(sort_by)

    def _plan_locked(self, sort_by: str) -> Tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray]:
        fids, sizes, keys, rules = [], [], [], []
        for (gfids, gcols), idx, rl in zip(self._mirrors, self._group_idx,
                                           self._group_rule):
            fids.append(gfids[idx])
            sizes.append(gcols["size"][idx])
            keys.append(np.asarray(gcols[sort_by][idx], dtype=np.float64))
            rules.append(rl)
        return (np.concatenate(fids) if fids else np.zeros(0, np.int64),
                np.concatenate(sizes) if sizes else np.zeros(0, np.int64),
                np.concatenate(keys) if keys else np.zeros(0),
                np.concatenate(rules) if rules else np.zeros(0, np.int32))


class _ShardGroup:
    """One device's slice of the catalog: host mirror + freshness state."""

    __slots__ = ("gid", "shard_ids", "fids", "cols", "rows", "versions",
                 "dirty", "structural", "uploaded", "_order")

    def __init__(self, gid: int, shard_ids: List[int]) -> None:
        self.gid = gid
        self.shard_ids = shard_ids
        self.fids = np.zeros(0, np.int64)
        self.cols: Dict[str, np.ndarray] = {}
        self.rows = 0                      # valid rows (<= Rp)
        self.versions: Dict[int, int] = {}  # shard id -> tick at last upload
        self.dirty: set = set()
        self.structural = False
        self.uploaded = False
        self._order: Optional[np.ndarray] = None   # argsort(fids), lazy

    def locate(self, fids: np.ndarray) -> Optional[np.ndarray]:
        """Local row index per fid; None when any fid is not in the mirror
        (caller falls back to a full re-upload)."""
        if not self.rows:
            return None
        if self._order is None:
            self._order = np.argsort(self.fids, kind="stable")
        sorted_fids = self.fids[self._order]
        pos = np.searchsorted(sorted_fids, fids)
        pos = np.clip(pos, 0, sorted_fids.size - 1)
        rows = self._order[pos]
        if not (self.fids[rows] == fids).all():
            return None
        return rows


class DeviceColumnStore:
    """Per-shard-group kernel column stacks held resident on a jax mesh.

    See the module docstring for the residency / refresh / envelope
    contracts. Construction registers a delta hook on the catalog and
    uploads lazily: the first :meth:`refresh` (or :meth:`match`) pays the
    cold full upload, warm calls scatter only churned rows.
    """

    def __init__(self, catalog: Catalog, mesh=None,
                 refresh_frac: float = 0.25, tile: int = 0,
                 headroom: float = 1.25) -> None:
        import jax
        from ..kernels.policy_scan.kernel import LANE
        if mesh is None:
            from ..launch.mesh import make_shards_mesh
            mesh = make_shards_mesh()
        if "shards" not in mesh.axis_names:
            raise PolicyError('device store needs a mesh with a "shards" '
                              f"axis, got {mesh.axis_names}")
        self.catalog = catalog
        self.mesh = mesh
        self.devices = list(np.asarray(mesh.devices).reshape(-1))
        self.n_devices = len(self.devices)
        self.refresh_frac = refresh_frac
        self.tile = tile or 8 * LANE
        self.headroom = headroom
        self._lock = threading.RLock()
        self._groups = [
            _ShardGroup(g, [s for s in range(catalog.n_shards)
                            if s % self.n_devices == g])
            for g in range(self.n_devices)]
        self._rp = 0                        # padded rows per device block
        self._bufs: List[Optional["jax.Array"]] = [None] * self.n_devices
        self._global = None                 # assembled (D, C+1, Rp) array
        self._epoch = 0                     # bumped by every mirror mutation
        # perf counters (benchmarks / tests assert the refresh mode taken)
        self.full_uploads = 0
        self.delta_refreshes = 0
        self.rows_scattered = 0
        catalog.add_delta_hook(self._on_delta)

    def detach(self) -> None:
        """Unregister from the catalog's delta hooks and drop the device
        blocks. A store that is replaced (mesh resize, re-attach) must be
        detached, or the long-lived catalog keeps feeding its dirty sets
        forever. A detached store can still match, but without delta
        intake every refresh is a cold full upload (the hook-less
        version-drift fallback) — detach is for decommissioning."""
        self.catalog.remove_delta_hook(self._on_delta)
        with self._lock:
            self._bufs = [None] * self.n_devices
            self._global = None
            self._epoch += 1
            for group in self._groups:
                group.uploaded = False
                group.dirty = set()
                group.structural = False
                group.fids = np.zeros(0, np.int64)
                group.cols = {}
                group.rows = 0
            self._rp = 0

    # -- delta intake (catalog mutation hooks) --------------------------------
    def _on_delta(self, old: Optional[Delta], new: Optional[Delta]) -> None:
        ref = new if new is not None else old
        if ref is None:
            return
        fid = int(ref[0])
        group = self._groups[self.catalog._shard_id(fid) % self.n_devices]
        if old is None or new is None:      # insert / remove: rows shift
            group.structural = True
        else:
            group.dirty.add(fid)

    # -- freshness ------------------------------------------------------------
    def _shard_versions(self, group: _ShardGroup) -> Dict[int, int]:
        return {s: self.catalog.shards[s].version for s in group.shard_ids}

    def _stale(self, group: _ShardGroup) -> bool:
        if not group.uploaded or group.structural or group.dirty:
            return True
        return self._shard_versions(group) != group.versions

    # -- upload paths ----------------------------------------------------------
    def _snapshot_group(self, group: _ShardGroup
                        ) -> Tuple[Dict[str, int], np.ndarray,
                                   Dict[str, np.ndarray]]:
        """(versions-before, fids, native column dict) for a full upload."""
        versions = self._shard_versions(group)   # BEFORE the snapshot reads
        names = ("fid",) + KERNEL_COLUMNS
        parts = [self.catalog.shards[s].snapshot(names=names,
                                                 with_strings=False)[0]
                 for s in group.shard_ids]
        if parts:
            cols = {n: np.concatenate([p[n] for p in parts]) for n in names}
        else:
            cols = {n: np.zeros(0, dtype=np.int64) for n in names}
        # fid stays IN the mirror dict (it is a valid plan sort key)
        cols["fid"] = fids = cols["fid"].astype(np.int64, copy=False)
        return versions, fids, cols

    def _stack_f32(self, group: _ShardGroup, rp: int) -> np.ndarray:
        """(C+1, rp) f32 device-block staging from the host mirror."""
        out = np.zeros((len(KERNEL_COLUMNS) + 1, rp), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            out[i, : group.rows] = group.cols[name]
        out[_VALID_COL, : group.rows] = 1.0
        return out

    def _full_upload(self, group: _ShardGroup, rp: int) -> None:
        import jax
        versions, fids, cols = self._snapshot_group(group)
        if fids.size > rp:
            # a concurrent insert grew the group past the capacity check
            # at the top of refresh(): re-pad and retry instead of serving
            # a truncated block (or crashing the stack staging)
            raise _RepadNeeded(fids.size)
        group.fids, group.cols, group.rows = fids, cols, fids.size
        group._order = None
        stack = self._stack_f32(group, rp)
        self._bufs[group.gid] = jax.device_put(
            stack[None], self.devices[group.gid])
        group.versions = versions
        group.dirty = set()
        group.structural = False
        group.uploaded = True
        self._global = None
        self._epoch += 1
        self.full_uploads += 1

    def _delta_refresh(self, group: _ShardGroup) -> bool:
        """Scatter just the dirty rows into the resident block; returns
        False when the group needs the full-upload fallback instead."""
        # swap the dirty set out BEFORE reading versions: a hook landing
        # after the swap goes to the fresh set and keeps the group stale
        # (re-scattered next refresh), so a concurrent mutation can delay
        # a row's upload by one refresh but never lose it — and the
        # fromiter below never races a growing set
        dirty_set, group.dirty = group.dirty, set()
        versions = self._shard_versions(group)   # BEFORE the row gather
        dirty = np.fromiter(dirty_set, dtype=np.int64, count=len(dirty_set))
        rows = group.locate(dirty)
        if rows is None:
            group.dirty |= dirty_set
            return False                    # unseen fid: rows shifted
        cols, present = self.catalog.gather_rows(dirty.tolist(),
                                                 with_strings=False)
        if not bool(present.all()):
            group.dirty |= dirty_set
            return False                    # raced a remove: restack
        vals = np.empty((len(KERNEL_COLUMNS), dirty.size), dtype=np.float32)
        for i, name in enumerate(KERNEL_COLUMNS):
            group.cols[name][rows] = cols[name]      # host mirror first
            vals[i] = cols[name]
        # release the assembled global BEFORE the scatter: it holds the
        # only other reference to the block, which must drop for the
        # donated in-place update to actually donate
        self._global = None
        # the scatter runs on the block's own device (donated buffer); the
        # validity row is untouched (pure updates never change which rows
        # exist) and the op is bucket-padded for executable reuse
        prows, pvals = _pad_bucket(rows.astype(np.int32), vals)
        self._bufs[group.gid] = _scatter_rows(self._bufs[group.gid],
                                              prows, pvals)
        group.versions = versions
        self._epoch += 1
        self.delta_refreshes += 1
        self.rows_scattered += int(dirty.size)
        return True

    def _round_up(self, n: int) -> int:
        return -(-max(n, 1) // self.tile) * self.tile

    def refresh(self) -> Dict[str, int]:
        """Bring every stale shard group up to date; returns counters of
        the refresh modes taken (``full``/``delta``/``fresh`` groups)."""
        with self._lock:
            stats = {"full": 0, "delta": 0, "fresh": 0}
            stale = [g for g in self._groups if self._stale(g)]
            stats["fresh"] = self.n_devices - len(stale)
            if not stale:
                return stats
            # a grown group forces a global re-pad: every block re-uploads
            # at the new Rp so the global array stays rectangular
            need = max((sum(self.catalog.shards[s].count()
                            for s in g.shard_ids) for g in self._groups),
                       default=1)
            repad = need > self._rp or self._rp == 0
            if repad:
                self._rp = self._round_up(int(need * self.headroom))
            # bounded retry: a concurrent insert can outgrow the capacity
            # check mid-refresh (_full_upload raises _RepadNeeded) — re-pad
            # and re-upload everything rather than serve a truncated block
            for _attempt in range(8):
                if repad:
                    stale = list(self._groups)
                    stats = {"full": 0, "delta": 0, "fresh": 0}
                try:
                    for group in stale:
                        churn_ok = (not repad and group.uploaded
                                    and not group.structural and group.dirty
                                    and len(group.dirty)
                                    <= self.refresh_frac
                                    * max(1, group.rows))
                        if churn_ok and self._delta_refresh(group):
                            stats["delta"] += 1
                        else:
                            self._full_upload(group, self._rp)
                            stats["full"] += 1
                    return stats
                except _RepadNeeded as grown:
                    self._rp = self._round_up(
                        int(grown.rows * self.headroom))
                    repad = True
            raise PolicyError(
                "device store could not settle a refresh: the catalog "
                "grew on every re-pad attempt")

    # -- matching --------------------------------------------------------------
    def _assemble(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self._global is None:
            shape = (self.n_devices, len(KERNEL_COLUMNS) + 1, self._rp)
            self._global = jax.make_array_from_single_device_arrays(
                shape, NamedSharding(self.mesh, P("shards")), self._bufs)
        return self._global

    def match(self, exprs: Sequence, now: float,
              use_kernel: Optional[bool] = None,
              with_agg: bool = True) -> MeshMatch:
        """Evaluate ``[combined criteria] + per-rule conditions`` over the
        resident mesh; see :class:`MeshMatch`. Raises PolicyError on glob
        (host-only) predicates — callers fall back to the numpy path.
        ``with_agg=False`` skips the fused size-profile aggregation (the
        engine's match path needs only mask + attribution; ``.agg`` then
        reads all-zero)."""
        import jax
        from ..kernels.policy_scan.ops import (_agg_dict, _on_tpu,
                                               _program_tuples,
                                               mesh_policy_scan_batch)
        ops, colidx, operands = compile_programs(exprs, self.catalog.strings,
                                                 now)
        ops_t, colidx_t = _program_tuples(ops, colidx)
        if use_kernel is None:
            use_kernel = _on_tpu()
        # the lock is held for the WHOLE match (launch included): a
        # concurrent refresh would donate the resident blocks out from
        # under the in-flight launch and mutate the host mirrors this
        # match translates through — concurrent matches serialize instead
        with self._lock:
            self.refresh()
            global_cols = self._assemble()
            snap = [(g.gid, g.fids, g.cols, g.rows) for g in self._groups]
            mask, rule, agg = mesh_policy_scan_batch(
                global_cols, operands, mesh=self.mesh, ops_t=ops_t,
                colidx_t=colidx_t, size_col=KERNEL_COLUMNS.index("size"),
                blocks_col=KERNEL_COLUMNS.index("blocks"),
                valid_col=_VALID_COL, use_kernel=bool(use_kernel),
                tile=self.tile, with_agg=with_agg)
            # only mask + attribution cross device→host, never the columns
            mask_np = np.asarray(jax.device_get(mask))
            rule_np = np.asarray(jax.device_get(rule))
            per_rule = np.asarray(jax.device_get(agg))
            mirrors, group_idx, group_rule = [], [], []
            for gid, gfids, gcols, grows in snap:
                idx = np.nonzero(mask_np[gid, :grows] > 0.5)[0]
                mirrors.append((gfids, gcols))
                group_idx.append(idx)
                group_rule.append(rule_np[gid, idx].astype(np.int32))
            reval = int(sum(s[3] for s in snap))
            return MeshMatch(self, self._epoch, mirrors, group_idx,
                             group_rule, _agg_dict(per_rule[0], per_rule),
                             reval)

    def scan(self, expr, now: float,
             use_kernel: Optional[bool] = None) -> Tuple[np.ndarray, dict]:
        """Single-expression mesh scan: (matching fids, aggregate dict) —
        the device-resident analogue of ``ops.scan_catalog``."""
        match = self.match([expr], now, use_kernel=use_kernel)
        fids, _sizes, _sort, _ridx = match.plan("size")
        return fids, match.agg
