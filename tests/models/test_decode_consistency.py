"""Strongest model-correctness test: prefill + step decode == full forward.

Validates every cache type (full KV, ring-window KV, RG-LRU state, RWKV6
state, cross-attn KV) against the sequence path. MoE archs use dropless
capacity (capacity-token dropping legitimately differs between a
full-sequence dispatch and single-token decode — verified exact when
dropless)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model

KEY = jax.random.PRNGKey(3)
B, S, P = 2, 24, 20


def _prep(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)
            / cfg.moe.top_k))
    m = Model(cfg, kv_chunk=8)
    params = m.init(KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                              cfg.vocab)
    extras = None
    if cfg.encoder is not None:
        extras = {"frames": jax.random.normal(
            KEY, (B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16) * 0.1}
    if cfg.n_img_tokens:
        extras = {"img": jax.random.normal(
            KEY, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16) * 0.1}
    return cfg, m, params, toks, extras


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg, m, params, toks, extras = _prep(arch)
    full, _, _ = m.forward(params, toks, extras)
    logits_p, cache = m.prefill(params, toks[:, :P], cache_len=S,
                                extras=extras)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    errs = [float(jnp.max(jnp.abs(logits_p[:, -1] - full[:, P - 1])))]
    for t in range(P, S):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 0.05 * scale + 0.05, f"{arch}: {errs}"


def test_ring_cache_wraps_correctly():
    """Decode far past the window: ring slots must hold the right tokens."""
    cfg = get_config("mixtral_8x22b", smoke=True)   # SWA window 32
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=2.0))
    m = Model(cfg, kv_chunk=8)
    params = m.init(KEY)
    long_toks = jax.random.randint(KEY, (1, 3 * cfg.window), 0, cfg.vocab)
    full, _, _ = m.forward(params, long_toks)
    # prefill all but last token, decode the last one
    n = long_toks.shape[1]
    _, cache = m.prefill(params, long_toks[:, :n - 1], cache_len=cfg.window)
    lg, _ = m.decode_step(params, cache, long_toks[:, n - 1:], jnp.int32(n - 1))
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full)))
    assert err < 0.05 * scale + 0.05
