"""Mesh-resident analytics plane: store-backed reports == host oracle.

Differential contract (ISSUE 6 / PR 6): with a DeviceColumnStore attached,
``Reports.find``/``top_files``/``du`` and every ``ProfileCube`` report
answer from device-resident tensors — byte-identical to the host folds,
across churn rounds and age rollovers, without calling
``Catalog.arrays()`` on the warm path. A mesh full scan must also leave
the engine's incremental match cache valid (primed, not invalidated).
"""
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState,
                        PolicyDefinition, PolicyEngine)
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports

NOW = float(2 ** 20)          # f32-exact "now"


def _shards_mesh():
    from repro.launch.mesh import make_shards_mesh
    return make_shards_mesh()


def _entry(rng, i, **over):
    kw = dict(
        fid=i + 1, name=f"f{i + 1}", path=f"/p/d{i % 5}/f{i + 1}",
        type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
        size=int(rng.integers(0, 2 ** 12)) * 1024,       # narrow: many ties
        blocks=int(rng.integers(0, 2 ** 10)),
        owner=f"user{int(rng.integers(0, 4))}",
        group=f"grp{int(rng.integers(0, 3))}",
        hsm_state=HsmState(int(rng.integers(0, 5))),
        atime=NOW - float(rng.integers(0, 10_000)),      # f32-exact
        mtime=NOW - float(rng.integers(0, 10_000)))
    kw.update(over)
    return Entry(**kw)


def _random_catalog(rng, n, n_shards=8):
    cat = Catalog(n_shards=n_shards)
    cat.upsert_batch([_entry(rng, i) for i in range(n)])
    return cat


def _churn(cat, rng, n_total, k):
    for f in rng.choice(np.arange(1, n_total + 1), size=k, replace=False):
        cat.upsert(_entry(rng, int(f) - 1,
                          size=int(rng.integers(0, 2 ** 12)) * 1024,
                          atime=NOW - float(rng.integers(0, 10_000))))


class _Clock:
    def __init__(self, t=NOW):
        self.t = t

    def __call__(self):
        return self.t


# -- find / top_files / du: store == host oracle ------------------------------

FIND_CRITERIA = [
    "size > 2M",
    "size <= 1M and owner == 'user1'",
    "type == file and last_access > 1000s",
    "hsm_state == archived or size > 3M",
    "not (size <= 1M or last_access <= 500s)",
]


@pytest.mark.parametrize("seed", [0, 1])
def test_reports_differential_across_churn_rounds(seed):
    rng = np.random.default_rng(seed)
    cat = _random_catalog(rng, 400)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    r_host = Reports(cat, clock=clock)
    for round_ in range(3):
        for crit in FIND_CRITERIA:
            assert r_store.find(crit) == r_host.find(crit), crit
        assert r_store.find("size > 1M", limit=7) \
            == r_host.find("size > 1M", limit=7)
        for by in ("size", "atime"):
            for desc in (True, False):
                for k in (1, 10, 64):
                    assert r_store.top_files(by=by, k=k, desc=desc) \
                        == r_host.top_files(by=by, k=k, desc=desc), (by, k)
        for p in ("/p/d0", "/p/d1/", "/p", "/nope", "/p/d4"):
            assert r_store.du(p) == r_host.du(p), p
        assert r_store.du_many(["/p/d0", "/p/d2"]) \
            == r_host.du_many(["/p/d0", "/p/d2"])
        _churn(cat, rng, 400, 40)
    assert r_store.last_fallback_reason is None
    assert r_store.host_served == 0 and r_store.store_served > 0


def test_top_files_tie_storm_matches_host_order():
    """Every file the same size: candidate recovery crosses all devices
    and ordering falls back to the host's stable-argsort tie semantics."""
    rng = np.random.default_rng(7)
    cat = Catalog(n_shards=8)
    cat.upsert_batch([_entry(rng, i, type=FsType.FILE, size=1024 * 1024)
                      for i in range(100)])
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    r_host = Reports(cat, clock=clock)
    for desc in (True, False):
        assert r_store.top_files(k=10, desc=desc) \
            == r_host.top_files(k=10, desc=desc)


def test_find_glob_predicate_falls_back_to_host():
    rng = np.random.default_rng(3)
    cat = _random_catalog(rng, 60)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    out = r_store.find("name == 'f7'")
    assert out == Reports(cat, clock=clock).find("name == 'f7'")
    assert r_store.last_fallback_reason is not None
    assert "find" in r_store.last_fallback_reason
    assert r_store.host_served == 1


def test_warm_reports_never_touch_host_columns():
    """The acceptance counter: after the cold upload, serving find/
    top_files/du + profile reports does not call Catalog.arrays()."""
    rng = np.random.default_rng(5)
    cat = _random_catalog(rng, 300)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    pc = ProfileCube(cat, clock=clock).attach_device_store(store)
    r_store.find("size > 2M")                     # cold upload happens here
    baseline = cat.arrays_calls
    for _ in range(2):
        r_store.find("size > 1M")
        r_store.top_files(k=5)
        r_store.du("/p/d1")
        pc.report_user("user1", NOW)
        pc.top_users("volume", 3, NOW)
        _churn(cat, rng, 300, 10)                 # warm scatter, not arrays()
    assert cat.arrays_calls == baseline
    assert store.store_queries > 0


# -- profile cube plane -------------------------------------------------------

def test_profile_reports_differential_with_rollovers():
    rng = np.random.default_rng(11)
    cat = _random_catalog(rng, 350)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    pc = ProfileCube(cat, clock=clock).attach_device_store(store)

    def oracle(now):
        o = ProfileCube(cat, clock=lambda: now)
        o.rebuild(now=now)
        return o

    for dt in (0.0, 5000.0, 50_000.0):            # crosses age-bucket edges
        now = NOW + dt
        clock.t = now
        o = oracle(now)
        for u in ("user0", "user1", "user2", "user3"):
            assert pc.report_user(u, now) == o.report_user(u, now)
            assert pc.user_size_profile(u, now) == o.user_size_profile(u, now)
        assert pc.report_types(now) == o.report_types(now)
        assert pc.report_hsm(now) == o.report_hsm(now)
        assert pc.age_profile(now=now) == o.age_profile(now=now)
        assert pc.top_users("volume", 5, now) == o.top_users("volume", 5, now)
        assert pc.totals() == o.totals()
        _churn(cat, rng, 350, 30)
    assert store.cube_rebuilds == 1               # warm rounds scatter-add
    assert store.rollovers > 0


def test_cube_rebuild_is_invalidation_and_group_growth_rebuilds():
    rng = np.random.default_rng(13)
    cat = _random_catalog(rng, 200)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    pc = ProfileCube(cat, clock=clock).attach_device_store(store)
    pc.cube(NOW)
    assert store.cube_rebuilds == 1
    pc.rebuild()                                  # = invalidate, not host work
    pc.cube(NOW)
    assert store.cube_rebuilds == 2
    # minting more groups than the padded axis forces a resized rebuild
    cat.upsert_batch([_entry(rng, 200 + i, owner=f"newuser{i}")
                      for i in range(len(pc.groups) + 8)])
    o = ProfileCube(cat, clock=clock)
    o.rebuild(now=NOW)
    assert pc.totals() == o.totals()
    assert store.cube_rebuilds >= 3


def test_delta_feed_claimed_once():
    """One pipeline delta batch updates columns + cube + mirrors exactly
    once: the store owns the single catalog hook, the cube's own hook is
    dead, and a second feed claim raises."""
    rng = np.random.default_rng(17)
    cat = _random_catalog(rng, 120)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    pc = ProfileCube(cat, clock=clock).attach_device_store(store)
    with pytest.raises(ValueError):
        pc.attach()                               # feed already claimed
    pc.cube(NOW)
    # exactly one delta application: totals track a batch that rewrites
    # the same fid twice in one pipeline flush (no double-fold)
    cat.upsert(_entry(rng, 0, size=2048 * 1024, type=FsType.FILE))
    cat.upsert(_entry(rng, 0, size=1024 * 1024, type=FsType.FILE))
    o = ProfileCube(cat, clock=clock)
    o.rebuild(now=NOW)
    assert pc.totals() == o.totals()
    # cube's own shard buffers stayed empty: the store path fed the plane
    assert all(len(s.pending) == 0 if hasattr(s, "pending") else True
               for s in pc._shards)


# -- mesh full scan primes the incremental cache ------------------------------

def test_mesh_scan_primes_incremental_cache():
    rng = np.random.default_rng(19)
    cat = _random_catalog(rng, 300)
    clock = _Clock()
    pol = PolicyDefinition.from_config(
        name="p", action=lambda e, p: True, scope="type == file",
        rules=[("r0", "size > 2M and last_access > 1000s", {})],
        sort_by="atime", n_threads=1, batch_size=64, mutates=False,
        dry_run=True)
    eng = PolicyEngine(cat, clock=clock)
    eng.register(pol)
    eng.enable_incremental()
    eng.attach_device_store(DeviceColumnStore(cat, _shards_mesh()))
    r1 = eng.run("p", evaluator="policy_scan_mesh")
    assert r1.evaluator == "policy_scan_mesh" and r1.mode == "full"
    assert not r1.fallback_reason
    r2 = eng.run("p")                             # primed: no rebuild
    assert r2.mode == "incremental"
    assert r2.matched == r1.matched
    assert eng._inc["p"].full_rebuilds == 1


def test_mesh_primed_cache_identical_to_host_primed():
    """The cache a mesh full scan leaves behind matches what a host full
    scan of the same state builds — same matched table, same flips."""
    def scenario(prime_mesh):
        rng = np.random.default_rng(23)
        cat = _random_catalog(rng, 300)
        clock = _Clock()
        pol = PolicyDefinition.from_config(
            name="p", action=lambda e, p: True, scope="type == file",
            rules=[("r0", "size > 2M and last_access > 1000s", {})],
            sort_by="atime", n_threads=1, batch_size=64, mutates=False,
            dry_run=True)
        eng = PolicyEngine(cat, clock=clock)
        eng.register(pol)
        eng.enable_incremental()
        if prime_mesh:
            eng.attach_device_store(DeviceColumnStore(cat, _shards_mesh()))
            eng.run("p", evaluator="policy_scan_mesh")
        else:
            eng.run("p", evaluator="numpy", matching="full")
        st = eng._inc["p"]
        fids, sizes, sorts, rules = st.plan_arrays()
        ffids, fcols = st.flips.live()
        order, forder = np.argsort(fids), np.argsort(ffids)
        return (fids[order].tolist(), sizes[order].tolist(),
                sorts[order].tolist(), rules[order].tolist(),
                ffids[forder].tolist(), fcols["flip"][forder].tolist())

    assert scenario(True) == scenario(False)


def test_mesh_scan_with_extra_criteria_does_not_corrupt_cache():
    from repro.core import parse_expr
    rng = np.random.default_rng(29)
    cat = _random_catalog(rng, 200)
    clock = _Clock()
    pol = PolicyDefinition.from_config(
        name="p", action=lambda e, p: True, scope="type == file",
        rules=[("r0", "size > 1M", {})], sort_by="size", n_threads=1,
        batch_size=64, mutates=False, dry_run=True)
    eng = PolicyEngine(cat, clock=clock)
    eng.register(pol)
    eng.enable_incremental()
    eng.attach_device_store(DeviceColumnStore(cat, _shards_mesh()))
    eng.run("p", evaluator="policy_scan_mesh")    # primes
    rebuilds = eng._inc["p"].full_rebuilds
    r = eng.run("p", evaluator="policy_scan_mesh", matching="full",
                extra_criteria=parse_expr("size > 2M"))
    assert r.evaluator == "policy_scan_mesh"
    assert eng._inc["p"].full_rebuilds == rebuilds   # no partial-scope prime
    r3 = eng.run("p")
    assert r3.mode == "incremental"               # cache still valid


# -- structural fallbacks -----------------------------------------------------

def test_rename_degrades_to_full_reupload_and_stays_correct():
    """A path change shifts sorted-path ranks: the warm scatter must not
    serve stale du ranges — the group re-uploads instead."""
    rng = np.random.default_rng(31)
    cat = _random_catalog(rng, 150)
    clock = _Clock()
    store = DeviceColumnStore(cat, _shards_mesh())
    r_store = Reports(cat, clock=clock).attach_device_store(store)
    r_host = Reports(cat, clock=clock)
    assert r_store.du("/p/d1") == r_host.du("/p/d1")
    e = cat.get(7)
    cat.upsert(Entry(fid=7, name=e.name, path="/q/moved/f7", type=e.type,
                     size=e.size, blocks=e.blocks, owner=e.owner,
                     group=e.group, hsm_state=e.hsm_state, atime=e.atime,
                     mtime=e.mtime))
    assert r_store.du("/q/moved") == r_host.du("/q/moved")
    assert r_store.du("/p/d2") == r_host.du("/p/d2")


# -- multi-device (subprocess: 8 fake XLA devices) ----------------------------

@pytest.mark.slow
def test_mesh_reports_differential_on_eight_devices():
    out = run_subprocess("""
import numpy as np
from repro.core import (Catalog, DeviceColumnStore, Entry, FsType, HsmState)
from repro.core.profiles import ProfileCube
from repro.core.reports import Reports
from repro.launch.mesh import make_shards_mesh

NOW = float(2 ** 20)
rng = np.random.default_rng(0)
cat = Catalog(n_shards=16)
cat.upsert_batch([Entry(
    fid=i + 1, name=f"f{i + 1}", path=f"/p/d{i % 7}/f{i + 1}",
    type=FsType.FILE if rng.random() < 0.9 else FsType.DIR,
    size=int(rng.integers(0, 2 ** 12)) * 1024,
    blocks=int(rng.integers(0, 2 ** 10)),
    owner=f"user{i % 4}", group=f"grp{i % 3}",
    hsm_state=HsmState(int(rng.integers(0, 5))),
    atime=NOW - float(rng.integers(0, 10_000)),
    mtime=NOW - float(rng.integers(0, 10_000))) for i in range(3000)])
clock = lambda: NOW
mesh = make_shards_mesh(8)
assert mesh.devices.size == 8
store = DeviceColumnStore(cat, mesh)
rs = Reports(cat, clock=clock).attach_device_store(store)
rh = Reports(cat, clock=clock)
pc = ProfileCube(cat, clock=clock).attach_device_store(store)
oracle = ProfileCube(cat, clock=clock)
oracle.rebuild(now=NOW)
assert rs.find("size > 2M") == rh.find("size > 2M")
assert rs.top_files(k=25) == rh.top_files(k=25)
assert rs.top_files(by="atime", k=25, desc=False) \\
    == rh.top_files(by="atime", k=25, desc=False)
for p in ("/p/d0", "/p/d3", "/nope"):
    assert rs.du(p) == rh.du(p)
for u in ("user0", "user1"):
    assert pc.report_user(u, NOW) == oracle.report_user(u, NOW)
assert pc.totals() == oracle.totals()
# warm churn touching every device's group, then re-verify
cat.update_fields_batch(list(range(1, 3000, 31)), size=3 << 20)
assert rs.find("size > 2M") == rh.find("size > 2M")
assert rs.top_files(k=25) == rh.top_files(k=25)
assert rs.du("/p/d5") == rh.du("/p/d5")
oracle2 = ProfileCube(cat, clock=clock)
oracle2.rebuild(now=NOW)
assert pc.totals() == oracle2.totals()
assert store.delta_refreshes >= 8 and store.cube_rebuilds == 1
assert rs.last_fallback_reason is None and rs.host_served == 0
print("OK", len(rs.find("size > 2M")))
""")
    assert "OK" in out
